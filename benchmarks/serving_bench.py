"""Closed-loop load generator for the continuous-batching SortServer.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
        [--inject-faults] [--out BENCH_serving.json]

Drives synthetic heavy traffic at a live ``SortServer`` and records the
tail-latency/robustness numbers the serving tier claims (EXPERIMENTS.md
§Serving): Poisson arrivals (seeded, reproducible), a mixed-(N, d)
problem population exercising the shape-bucket compile cache, a
closed-loop outstanding-request window so the generator applies
backpressure-aware load rather than unbounded open-loop pile-up, and —
with ``--inject-faults`` — deterministic worker failures and straggler
delays injected at exact dispatch indices via
``runtime.fault_tolerance.FaultInjector``.

Four scenarios per run:

  * ``steady``    — in-budget load, no perturbations: the baseline
    p50/p99 and goodput row.
  * ``faults``    — same load with injected dispatch failures and one
    injected straggler delay; the row proves recovery (every fault is
    retried from the last committed round boundary; ``recoveries``
    counts requests that completed after >= 1 failed dispatch).
  * ``overload``  — arrival rate above service rate into a shallow
    queue with tight deadlines: the row shows load shedding doing its
    job (``queue_rejected`` + ``deadline_missed`` > 0) while admitted,
    in-deadline requests still complete.
  * ``preempt``   — warm restart under load: the server is killed
    (``close(drain=False)``) once a quarter of the traffic has
    completed, and a successor adopts the in-flight requests from
    their last committed round boundaries.  The row proves
    exactly-once accounting ACROSS server generations
    (``completed_gen1 + completed_gen2 == completed``) and that every
    preempted request was resumed (``resumed_requests ==
    preempted_inflight``) — EXPERIMENTS.md §Robustness.
  * ``capacity``  — elastic capacity loss (needs >= 8 devices, e.g.
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``): Poisson
    load against an 8-device mesh while a ``FaultInjector`` takes two
    devices down mid-run and brings one back; the DeviceHealthMonitor
    evicts, the server re-shards at rung boundaries, and the armed
    brownout ladder degrades deadline-bound admissions instead of
    shedding.  The row commits goodput / miss-rate / the degradation
    mix, and ``tools/check_bench.py`` gates zero lost futures,
    ``reshards == evictions``, and (non-smoke) brownout p50 <= 2x the
    steady row's p50.

Every request is accounted for exactly once:

    completed + failed + deadline_missed + queue_rejected == offered

which ``tools/check_bench.py`` gates on the committed
``BENCH_serving.json`` — a row that leaks a request fails CI.  On a
non-TPU backend the per-cell ``wall_clock`` label is "emulated"
(forced-host CPU timings are scheduling-overhead signals, not TPU
serving numbers); counters, accounting, and rates are exact anywhere.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    run_round_segment,
)
from repro.launch.serve import (
    DeadlineExceeded,
    QueueFull,
    RequestFailed,
    ServerClosed,
    SortServer,
)
from repro.runtime.fault_tolerance import FaultInjector, RetryPolicy
from repro.runtime.straggler import StragglerMonitor


# (hw, d) mix: two shape buckets so every scenario exercises the
# pad-to-bucket compile cache across mixed traffic.
SHAPES = (((4, 4), 2), ((8, 8), 2))


def _gen_problems(rng, count):
    probs = []
    for i in range(count):
        hw, d = SHAPES[i % len(SHAPES)]
        probs.append((hw, d,
                      rng.rand(hw[0] * hw[1], d).astype(np.float32)))
    return probs


def _percentile(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else 0.0


_WARMED: set = set()


def _warm_compile_cache(cfg, seg_len, max_batch, meshes=(None,),
                        adaptive=False):
    """Pre-trace every (shape, pow2-bucket) program the scenario can
    dispatch, directly against the engine, so the recorded latencies
    measure scheduling and annealing rather than XLA compiles (compile
    amortization is a given in a long-lived server; a fresh-process
    benchmark has to buy it explicitly).  ``meshes`` lists every device
    layout the scenario will dispatch on (the capacity scenario knows
    its eviction schedule, so it warms the survivor meshes too);
    ``adaptive=True`` additionally warms the controller-driven dispatch
    the brownout ladder degrades requests onto."""
    import dataclasses
    acfg = dataclasses.replace(cfg, schedule="adaptive")
    for mesh in meshes:
        mesh_key = (None if mesh is None
                    else tuple(dv.id for dv in mesh.devices.flat))
        for hw, d in SHAPES:
            n = hw[0] * hw[1]
            b = 1
            while b <= max_batch:
                xs = np.zeros((b, n, d), np.float32)
                orders = np.tile(np.arange(n, dtype=np.int32), (b, 1))
                keys = np.ones((b, 2), np.uint32)
                norms = np.ones(b, np.float32)
                progress = np.zeros(b, np.int64)
                sig = (hw, d, b, seg_len, cfg, mesh_key)
                if sig not in _WARMED:
                    _WARMED.add(sig)
                    run_round_segment(xs, orders, keys, norms, progress,
                                      seg_len, hw=hw, cfg=cfg, mesh=mesh)
                sig_a = sig + ("adaptive",)
                if adaptive and sig_a not in _WARMED:
                    _WARMED.add(sig_a)
                    run_round_segment(xs, orders, keys, norms, progress,
                                      seg_len, hw=hw, cfg=acfg, mesh=mesh,
                                      regime="dense", with_w=True)
                b *= 2


def run_scenario(name, cfg, *, requests, rate_hz, window,
                 queue_depth, max_batch, deadline_s=None,
                 fail_every=0, delay_call=None, seed=0):
    """Offer ``requests`` Poisson arrivals at ``rate_hz`` to a fresh
    server; returns the metrics cell."""
    inject = fail_every > 0 or delay_call is not None
    fail_calls = set(range(fail_every, 10_000, fail_every)) \
        if fail_every else set()
    delay_calls = {delay_call: 0.25} if delay_call is not None else {}
    engine = FaultInjector(run_round_segment, fail_calls=fail_calls,
                           delay_calls=delay_calls)
    hw0, d0 = SHAPES[0]
    server = SortServer(
        hw0, d=d0, cfg=cfg, max_batch=max_batch, max_wait_ms=2.0,
        queue_depth=queue_depth, seed=seed,
        retry=RetryPolicy(max_retries=4, backoff_base_s=0.01,
                          backoff_max_s=0.1),
        straggler=StragglerMonitor(z=4.0, min_ratio=2.0, warmup=8),
        engine_fn=engine if inject else run_round_segment)
    _warm_compile_cache(cfg, server.seg_len, max_batch)

    rng = np.random.RandomState(seed)
    problems = _gen_problems(rng, requests)
    gaps = rng.exponential(1.0 / rate_hz, size=requests)

    futs, rejected = [], 0
    t_start = time.perf_counter()
    next_at = t_start
    for i, (hw, d, x) in enumerate(problems):
        next_at += gaps[i]
        pause = next_at - time.perf_counter()
        if pause > 0:
            time.sleep(pause)
        # closed loop: never more than ``window`` requests outstanding
        while sum(not f.done() for f in futs) >= window:
            time.sleep(0.005)
        try:
            futs.append(server.submit(x, hw=hw, priority=i % 3,
                                      deadline_s=deadline_s))
        except QueueFull:
            rejected += 1
    outcomes = {"completed": 0, "failed": 0, "deadline_missed": 0}
    for f in futs:
        try:
            f.result(timeout=600)
            outcomes["completed"] += 1
        except DeadlineExceeded:
            outcomes["deadline_missed"] += 1
        except (RequestFailed, ServerClosed):
            outcomes["failed"] += 1
    wall = time.perf_counter() - t_start
    server.close()

    st = server.stats
    assert st["queue_rejected"] == rejected, (st["queue_rejected"], rejected)
    lat = st["latencies_ms"]
    cell = {
        "scenario": name,
        "requests": requests,
        "arrival_rate_hz": rate_hz,
        "deadline_s": deadline_s,
        "shapes": [[list(hw), d] for hw, d in SHAPES],
        "rounds": cfg.rounds,
        "wall_clock": ("measured" if jax.default_backend() == "tpu"
                       else "emulated"),
        "wall_s": wall,
        "completed": outcomes["completed"],
        "failed": outcomes["failed"],
        "deadline_missed": outcomes["deadline_missed"],
        "queue_rejected": rejected,
        "goodput_rps": outcomes["completed"] / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50),
        "p99_ms": _percentile(lat, 99),
        "deadline_miss_rate": outcomes["deadline_missed"] / requests,
        "retries": st["retries"],
        "recoveries": st["recoveries"],
        "stragglers": st["stragglers"],
        "batches": st["batches"],
        "mean_batch": (float(np.mean(st["batch_sizes"]))
                       if st["batch_sizes"] else 0.0),
        "compile_programs": len(st["compile_keys"]),
        "injected_faults": engine.faults if inject else 0,
        "injected_delays": engine.delays if inject else 0,
    }
    # cross-check the server ledger against the client-observed outcomes
    assert st["completed"] == outcomes["completed"], (st, outcomes)
    assert (cell["completed"] + cell["failed"] + cell["deadline_missed"]
            + cell["queue_rejected"]) == requests, cell
    return cell


def run_preempt_scenario(cfg, *, requests, rate_hz, window, queue_depth,
                         max_batch, seed=0):
    """Kill-and-resume under load: offer Poisson traffic to generation-1,
    preempt it (``close(drain=False)``) once a quarter of the requests
    completed, hand the in-flight requests to generation-2, and account
    for every future exactly once across both servers."""
    def make(resume=None):
        hw0, d0 = SHAPES[0]
        return SortServer(
            hw0, d=d0, cfg=cfg, max_batch=max_batch, max_wait_ms=2.0,
            queue_depth=queue_depth, seed=seed,
            retry=RetryPolicy(max_retries=4, backoff_base_s=0.01,
                              backoff_max_s=0.1),
            straggler=StragglerMonitor(z=4.0, min_ratio=2.0, warmup=8),
            resume=resume)

    server = make()
    _warm_compile_cache(cfg, server.seg_len, max_batch)
    rng = np.random.RandomState(seed)
    problems = _gen_problems(rng, requests)
    gaps = rng.exponential(1.0 / rate_hz, size=requests)

    futs, rejected = [], 0
    t_start = time.perf_counter()
    next_at = t_start
    for i, (hw, d, x) in enumerate(problems):
        next_at += gaps[i]
        pause = next_at - time.perf_counter()
        if pause > 0:
            time.sleep(pause)
        while sum(not f.done() for f in futs) >= window:
            time.sleep(0.005)
        try:
            futs.append(server.submit(x, hw=hw, priority=i % 3))
        except QueueFull:
            rejected += 1
    # preempt once a quarter of the offered load has completed but
    # in-flight traffic remains (deadline: everything finished first)
    quarter = max(1, requests // 4)
    while (server.stats["completed"] < quarter
           and any(not f.done() for f in futs)):
        time.sleep(0.002)
    handoff = server.close(drain=False)
    gen1 = dict(server.stats)

    server2 = make(resume=handoff)
    outcomes = {"completed": 0, "failed": 0, "deadline_missed": 0}
    for f in futs:
        try:
            f.result(timeout=600)
            outcomes["completed"] += 1
        except DeadlineExceeded:
            outcomes["deadline_missed"] += 1
        except (RequestFailed, ServerClosed):
            outcomes["failed"] += 1
    wall = time.perf_counter() - t_start
    server2.close()
    gen2 = server2.stats

    lat = gen1["latencies_ms"] + gen2["latencies_ms"]
    cell = {
        "scenario": "preempt",
        "requests": requests,
        "arrival_rate_hz": rate_hz,
        "deadline_s": None,
        "shapes": [[list(hw), d] for hw, d in SHAPES],
        "rounds": cfg.rounds,
        "wall_clock": ("measured" if jax.default_backend() == "tpu"
                       else "emulated"),
        "wall_s": wall,
        "completed": outcomes["completed"],
        "failed": outcomes["failed"],
        "deadline_missed": outcomes["deadline_missed"],
        "queue_rejected": rejected,
        "goodput_rps": outcomes["completed"] / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50),
        "p99_ms": _percentile(lat, 99),
        "deadline_miss_rate": outcomes["deadline_missed"] / requests,
        "retries": gen1["retries"] + gen2["retries"],
        "recoveries": gen1["recoveries"] + gen2["recoveries"],
        "stragglers": gen1["stragglers"] + gen2["stragglers"],
        "batches": gen1["batches"] + gen2["batches"],
        "mean_batch": (float(np.mean(gen1["batch_sizes"]
                                     + gen2["batch_sizes"]))
                       if gen1["batch_sizes"] + gen2["batch_sizes"]
                       else 0.0),
        "compile_programs": len(gen1["compile_keys"]
                                | gen2["compile_keys"]),
        "injected_faults": 0,
        "injected_delays": 0,
        # warm-restart accounting (gated by tools/check_bench.py)
        "preempted_inflight": len(handoff.requests),
        "resumed_requests": gen2["resumed"],
        "completed_gen1": gen1["completed"],
        "completed_gen2": gen2["completed"],
    }
    assert (cell["completed"] + cell["failed"] + cell["deadline_missed"]
            + cell["queue_rejected"]) == requests, cell
    assert cell["completed_gen1"] + cell["completed_gen2"] \
        == cell["completed"], cell
    assert cell["resumed_requests"] == cell["preempted_inflight"], cell
    return cell


def run_capacity_scenario(cfg, *, requests, rate_hz, window, queue_depth,
                          max_batch, deadline_s, seed=0):
    """Elastic capacity loss under Poisson load: serve from an 8-device
    mesh, take two devices down mid-run (the health layer evicts and
    re-shards over the survivors at rung boundaries), bring one back,
    and let the armed brownout ladder degrade deadline-bound admissions
    instead of shedding them.  The server runs a 2-restart tournament
    so the ladder's first rung ("culled" — keep only the best restart
    at cull edges) deterministically fires while any device is out;
    requests carry a deadline inside the policy's full-level slack
    band so they take the full ladder level.  The cell commits the
    goodput/miss-rate and the full degradation mix; every offered
    future must still resolve exactly once (``lost_futures == 0``)."""
    from repro.launch.mesh import make_sort_mesh
    from repro.launch.serve import BrownoutPolicy
    from repro.runtime.straggler import DeviceHealthMonitor

    mesh = make_sort_mesh(8)
    devs = list(mesh.devices.flat)
    lose_a, lose_b = devs[3].id, devs[5].id
    engine = FaultInjector(run_round_segment,
                           device_loss={1: lose_a, 3: lose_b},
                           device_return={8: lose_a})
    hw0, d0 = SHAPES[0]
    server = SortServer(
        hw0, d=d0, cfg=cfg, max_batch=max_batch, max_wait_ms=2.0,
        queue_depth=queue_depth, seed=seed, mesh=mesh,
        n_restarts=2, tournament_rungs=2, cull_fraction=0.25,
        retry=RetryPolicy(max_retries=4, backoff_base_s=0.01,
                          backoff_max_s=0.1),
        straggler=StragglerMonitor(z=4.0, min_ratio=2.0, warmup=8),
        engine_fn=engine,
        brownout=BrownoutPolicy(slack_full_s=10.0),
        device_health=DeviceHealthMonitor(lost_after=1,
                                          probe=engine.healthy))
    # Warm every device layout the eviction schedule will produce
    # (8 -> minus A -> minus A,B -> A returns: minus B), and the
    # adaptive dispatch the ladder degrades onto.
    surv_a = [dv for dv in devs if dv.id != lose_a]
    surv_ab = [dv for dv in devs if dv.id not in (lose_a, lose_b)]
    surv_b = [dv for dv in devs if dv.id != lose_b]
    _warm_compile_cache(
        cfg, server.seg_len, max_batch, adaptive=True,
        meshes=(mesh,
                make_sort_mesh(7, devices=surv_a),
                make_sort_mesh(6, devices=surv_ab),
                make_sort_mesh(7, devices=surv_b)))

    rng = np.random.RandomState(seed)
    problems = _gen_problems(rng, requests)
    gaps = rng.exponential(1.0 / rate_hz, size=requests)

    futs, rejected = [], 0
    t_start = time.perf_counter()
    next_at = t_start
    for i, (hw, d, x) in enumerate(problems):
        next_at += gaps[i]
        pause = next_at - time.perf_counter()
        if pause > 0:
            time.sleep(pause)
        while sum(not f.done() for f in futs) >= window:
            time.sleep(0.005)
        try:
            futs.append(server.submit(x, hw=hw, priority=i % 3,
                                      deadline_s=deadline_s))
        except QueueFull:
            rejected += 1
    outcomes = {"completed": 0, "failed": 0, "deadline_missed": 0}
    for f in futs:
        try:
            f.result(timeout=600)
            outcomes["completed"] += 1
        except DeadlineExceeded:
            outcomes["deadline_missed"] += 1
        except (RequestFailed, ServerClosed):
            outcomes["failed"] += 1
    wall = time.perf_counter() - t_start
    server.close()

    st = server.stats
    lat = st["latencies_ms"]
    resolved = (outcomes["completed"] + outcomes["failed"]
                + outcomes["deadline_missed"] + rejected)
    cell = {
        "scenario": "capacity",
        "requests": requests,
        "arrival_rate_hz": rate_hz,
        "deadline_s": deadline_s,
        "shapes": [[list(hw), d] for hw, d in SHAPES],
        "rounds": cfg.rounds,
        "wall_clock": ("measured" if jax.default_backend() == "tpu"
                       else "emulated"),
        "wall_s": wall,
        "completed": outcomes["completed"],
        "failed": outcomes["failed"],
        "deadline_missed": outcomes["deadline_missed"],
        "queue_rejected": rejected,
        "goodput_rps": outcomes["completed"] / max(wall, 1e-9),
        "p50_ms": _percentile(lat, 50),
        "p99_ms": _percentile(lat, 99),
        "deadline_miss_rate": outcomes["deadline_missed"] / requests,
        "retries": st["retries"],
        "recoveries": st["recoveries"],
        "stragglers": st["stragglers"],
        "batches": st["batches"],
        "mean_batch": (float(np.mean(st["batch_sizes"]))
                       if st["batch_sizes"] else 0.0),
        "compile_programs": len(st["compile_keys"]),
        "injected_faults": engine.faults,
        "injected_delays": engine.delays,
        # elastic-capacity accounting (gated by tools/check_bench.py)
        "devices_start": len(devs),
        "device_faults": engine.device_faults,
        "evictions": st["evictions"],
        "reshards": st["reshards"],
        "device_returns": st["device_returns"],
        "degraded_requests": st["brownouts"],
        "degradations": {k: int(v)
                         for k, v in st["degradations"].items()},
        "lost_futures": requests - resolved,
    }
    assert cell["lost_futures"] == 0, cell
    assert cell["reshards"] == cell["evictions"] == 2, cell
    assert cell["device_returns"] == 1, cell
    assert st["completed"] == outcomes["completed"], (st, outcomes)
    return cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized load (fewer requests, short anneal)")
    ap.add_argument("--inject-faults", action="store_true",
                    help="add the fault-injection scenario")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    requests = 16 if args.smoke else 48
    rounds = 4 if args.smoke else 8
    cfg = ShuffleSoftSortConfig(rounds=rounds, inner_steps=2, chunk=64)

    cells = [run_scenario(
        "steady", cfg, requests=requests, rate_hz=40.0, window=16,
        queue_depth=64, max_batch=8, seed=args.seed)]
    if args.inject_faults:
        cells.append(run_scenario(
            "faults", cfg, requests=requests, rate_hz=40.0, window=16,
            queue_depth=64, max_batch=8, fail_every=7, delay_call=11,
            seed=args.seed))
    cells.append(run_scenario(
        "overload", cfg, requests=requests, rate_hz=500.0, window=requests,
        queue_depth=12, max_batch=4, deadline_s=0.5, seed=args.seed))
    cells.append(run_preempt_scenario(
        cfg, requests=requests, rate_hz=80.0, window=requests,
        queue_depth=64, max_batch=4, seed=args.seed))
    if len(jax.devices()) >= 8:
        cells.append(run_capacity_scenario(
            cfg, requests=requests, rate_hz=60.0, window=16,
            queue_depth=16, max_batch=8, deadline_s=5.0,
            seed=args.seed))
    else:
        print("capacity scenario skipped: needs >= 8 devices (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8)")

    record = {
        "bench": "serving_bench",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "note": ("closed-loop Poisson load over mixed shape buckets; "
                 "counters/accounting exact on any backend, wall-clock "
                 "labeled emulated off-TPU"),
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    for c in cells:
        line = (f"{c['scenario']:>9}: {c['completed']}/{c['requests']} ok, "
                f"p50 {c['p50_ms']:.0f}ms p99 {c['p99_ms']:.0f}ms, "
                f"goodput {c['goodput_rps']:.1f}/s, "
                f"missed {c['deadline_missed']}, shed {c['queue_rejected']}, "
                f"retries {c['retries']}, recoveries {c['recoveries']}")
        if c["scenario"] == "capacity":
            deg = c["degradations"]
            line += (f", evicted {c['evictions']} resharded "
                     f"{c['reshards']} returned {c['device_returns']}, "
                     f"degraded {c['degraded_requests']} "
                     f"(culled={deg['culled']} adaptive={deg['adaptive']} "
                     f"banded={deg['banded']} bf16={deg['bf16']})")
        print(line)
    print(f"wrote {args.out}")
    return record


if __name__ == "__main__":
    main()
