"""Before/after roofline comparison: baseline sweep vs optimized-defaults
sweep -> markdown table for EXPERIMENTS.md §Final.

    PYTHONPATH=src python -m benchmarks.compare_sweeps \
        --before dryrun_single.json --after dryrun_single_final.json
"""
from __future__ import annotations

import argparse
import json

from benchmarks.roofline import analyze


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--before", default="dryrun_single.json")
    ap.add_argument("--after", default="dryrun_single_final.json")
    args = ap.parse_args()

    def load(path):
        with open(path) as f:
            rows = analyze(json.load(f))
        return {(r["arch"], r["shape"]): r for r in rows}

    b, a = load(args.before), load(args.after)
    print("| arch | shape | bound before | bound after | Δ | dominant after |")
    print("|---|---|---:|---:|---:|---|")
    better = worse = same = 0
    for key in sorted(b):
        if key not in a:
            continue
        rb, ra = b[key], a[key]
        bb = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        ba = max(ra["compute_s"], ra["memory_s"], ra["collective_s"])
        delta = (ba - bb) / bb * 100 if bb else 0.0
        if delta < -2:
            better += 1
        elif delta > 2:
            worse += 1
        else:
            same += 1
        print(f"| {key[0]} | {key[1]} | {bb:.3f}s | {ba:.3f}s "
              f"| {delta:+.0f}% | {ra['dominant']} |")
    print(f"\nimproved: {better}, unchanged(±2%): {same}, regressed: {worse}")


if __name__ == "__main__":
    main()
