"""Paper Table III reproduction: Gumbel-Sinkhorn vs Kissing vs SoftSort vs
ShuffleSoftSort on random RGB colors.

Reports: learnable-parameter memory, wall-clock runtime, DPQ_16 quality,
mean neighbour distance, and permutation validity — the paper's exact
comparison axes (runtime is CPU-relative, as the paper's M1 numbers are).
"""
from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import ShuffleSoftSortConfig, shuffle_soft_sort, soft_sort_baseline
from repro.core.baselines.gumbel_sinkhorn import (
    GumbelSinkhornConfig,
    gumbel_sinkhorn_sort,
)
from repro.core.baselines.kissing import KissingConfig, kissing_sort
from repro.core.metrics import dpq, mean_neighbor_distance
from repro.core.softsort import is_valid_permutation


def run(n: int = 1024, budget: str = "full", seed: int = 42):
    hw = (int(np.sqrt(n)), int(np.sqrt(n)))
    assert hw[0] * hw[1] == n
    x = jax.random.uniform(jax.random.PRNGKey(seed), (n, 3))
    xs_np = np.asarray(x)

    fast = budget == "fast"
    rows = []

    def add(name, mem, t, order, xsorted, valid=None):
        rows.append({
            "method": name,
            "params": mem,
            "runtime_s": round(t, 1),
            "dpq16": round(dpq(xsorted, hw), 3) if valid in (None, True)
                     else float("nan"),
            "nbr_dist": round(mean_neighbor_distance(xsorted, hw), 3),
            "valid": bool(is_valid_permutation(order)
                          if valid is None else valid),
        })

    # Gumbel-Sinkhorn (N^2 params)
    t0 = time.time()
    gs_cfg = GumbelSinkhornConfig(steps=200 if fast else 1200)
    o, xsr, _ = gumbel_sinkhorn_sort(x, hw, gs_cfg)
    add("gumbel-sinkhorn", n * n, time.time() - t0, o, xsr)

    # Kissing (2NM params)
    t0 = time.time()
    m = max(int(np.ceil(np.sqrt(n) / 2.46)), 13 if n >= 1024 else 8)
    ki_cfg = KissingConfig(rank=m, steps=200 if fast else 1200)
    o, xsr, _, valid = kissing_sort(x, hw, ki_cfg)
    add("kissing", 2 * n * m, time.time() - t0, o, xsr, valid=valid)

    # SoftSort (N params)
    t0 = time.time()
    ss_cfg = ShuffleSoftSortConfig(rounds=250 if fast else 1000,
                                   inner_steps=8, chunk=min(256, n))
    o, xsr, _ = soft_sort_baseline(x, hw, ss_cfg)
    add("softsort", n, time.time() - t0, o, xsr)

    # ShuffleSoftSort (ours reproduced; N params)
    t0 = time.time()
    o, xsr, _ = shuffle_soft_sort(x, hw, ss_cfg, key=jax.random.PRNGKey(1))
    add("shufflesoftsort", n, time.time() - t0, o, xsr)

    return rows


def print_table(rows):
    hdr = f"{'method':18s} {'params':>9s} {'runtime[s]':>10s} " \
          f"{'DPQ16':>6s} {'nbr':>6s} {'valid':>5s}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['method']:18s} {r['params']:>9,d} "
              f"{r['runtime_s']:>10.1f} {r['dpq16']:>6.3f} "
              f"{r['nbr_dist']:>6.3f} {str(r['valid']):>5s}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--budget", choices=("fast", "full"), default="full")
    a = ap.parse_args()
    print_table(run(a.n, a.budget))
