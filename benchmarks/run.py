# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark entrypoint: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

--full uses the paper's N=1024 with full step budgets (slow on CPU);
the default fast mode (N=256) preserves the method ordering.
Roofline rows appear when a dry-run JSON is present (see
repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--records", default="dryrun_single.json")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    # ---- paper Table III: method comparison ---------------------------
    from benchmarks.paper_table import run as paper_run
    n = 1024 if args.full else 256
    budget = "full" if args.full else "fast"
    t0 = time.time()
    rows = paper_run(n=n, budget=budget)
    for r in rows:
        print(f"paper_table.{r['method']},{r['runtime_s'] * 1e6:.0f},"
              f"dpq16={r['dpq16']};params={r['params']};"
              f"valid={r['valid']}")
    sys.stderr.write(f"[paper_table n={n} done in {time.time()-t0:.0f}s]\n")

    # ---- kernel microbench (paper runtime column analogue) ------------
    from benchmarks.kernel_bench import bench, bench_outer_round
    for name, us, derived in bench(ns=(1024, 4096) if args.full
                                   else (1024,)) + bench_outer_round():
        print(f"kernel.{name},{us:.0f},{derived}")

    # ---- roofline terms from the dry-run (figure analogue) ------------
    if os.path.exists(args.records):
        from benchmarks.roofline import analyze
        with open(args.records) as f:
            recs = json.load(f)
        for r in analyze(recs):
            bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            print(f"roofline.{r['arch']}.{r['shape']},{bound_s * 1e6:.0f},"
                  f"dominant={r['dominant']};useful={r['useful_ratio']:.3f};"
                  f"roofline_frac={r['roofline_frac']:.3f}")
    else:
        sys.stderr.write(f"[no {args.records}; run repro.launch.dryrun "
                         "--all --out ... for roofline rows]\n")


if __name__ == "__main__":
    main()
