"""§Perf hillclimb driver: lower+compile one cell under named variants and
report the probe-corrected roofline deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell granite_train
    PYTHONPATH=src python -m benchmarks.hillclimb --cell llama4_prefill
    PYTHONPATH=src python -m benchmarks.hillclimb --cell 405b_decode

Each cell definition lists (variant-name, opts) pairs in hypothesis
order; results land in hillclimb_<cell>.json for EXPERIMENTS.md §Perf.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.models.config import cell_by_name

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

CELLS = {
    "granite_train": {
        "arch": "granite-moe-3b-a800m", "shape": "train_4k",
        "variants": [
            ("baseline", {}),
            ("moe_shard", {"moe_shard": True}),
            ("group256", {"moe_group_size": 256}),
            ("group256+moe_shard", {"moe_group_size": 256,
                                    "moe_shard": True}),
            ("group128+moe_shard", {"moe_group_size": 128,
                                    "moe_shard": True}),
            ("no_remat+group256+moe_shard", {"moe_group_size": 256,
                                             "moe_shard": True,
                                             "remat": False}),
            ("gather_moe+group256", {"moe_impl": "gather",
                                     "moe_group_size": 256,
                                     "moe_shard": True}),
            ("gather_moe+group256+no_remat", {"moe_impl": "gather",
                                              "moe_group_size": 256,
                                              "moe_shard": True,
                                              "remat": False}),
        ],
    },
    "llama4_prefill": {
        "arch": "llama4-scout-17b-a16e", "shape": "prefill_32k",
        "variants": [
            ("baseline", {}),
            ("moe_shard", {"moe_shard": True}),
            ("moe_shard+group256", {"moe_shard": True,
                                    "moe_group_size": 256}),
            ("moe_shard+group1024", {"moe_shard": True,
                                     "moe_group_size": 1024}),
            ("gather_moe", {"moe_impl": "gather", "moe_shard": True}),
            ("gather_moe+group1024", {"moe_impl": "gather",
                                      "moe_shard": True,
                                      "moe_group_size": 1024}),
            ("router_bf16", {}),     # code change: router matmul in bf16
            ("router_bf16+seq_parallel", {"force_sp": True}),
            ("router_bf16+gather_moe", {"moe_impl": "gather",
                                        "moe_shard": True}),
            ("router_bf16+sp+gather", {"force_sp": True,
                                       "moe_impl": "gather",
                                       "moe_shard": True}),
        ],
    },
    "405b_decode": {
        "arch": "llama3-405b", "shape": "decode_32k",
        "variants": [
            ("baseline", {}),
            ("weight_stationary_2dtp", {"decode_dshard": True}),
        ],
    },
}


def run_cell_variants(name, mesh):
    from repro.launch.dryrun import probe_corrected_costs
    spec = CELLS[name]
    cfg = get_config(spec["arch"])
    cell = cell_by_name(spec["shape"])
    out = []
    for vname, opts in spec["variants"]:
        t0 = time.time()
        try:
            costs = probe_corrected_costs(cfg, cell, mesh, opts)
            rec = {
                "variant": vname, "opts": opts,
                "flops": costs["flops"],
                "bytes": costs["bytes_accessed"],
                "coll": costs["collective_bytes"],
                "compute_s": costs["flops"] / PEAK_FLOPS,
                "memory_s": costs["bytes_accessed"] / HBM_BW,
                "collective_s": costs["collective_bytes"] / LINK_BW,
                "wall_s": round(time.time() - t0, 1),
            }
            rec["bound_s"] = max(rec["compute_s"], rec["memory_s"],
                                 rec["collective_s"])
        except Exception as e:                              # noqa: BLE001
            rec = {"variant": vname, "opts": opts, "error": repr(e)}
        out.append(rec)
        if "bound_s" in rec:
            print(f"  {vname:32s} compute={rec['compute_s']:8.3f}s "
                  f"memory={rec['memory_s']:8.3f}s "
                  f"coll={rec['collective_s']:8.3f}s "
                  f"bound={rec['bound_s']:8.3f}s", flush=True)
        else:
            print(f"  {vname:32s} ERROR {rec['error'][:80]}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS) + ["paper_sort"],
                    required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    print(f"hillclimbing {args.cell} on {args.mesh} mesh", flush=True)
    if args.cell == "paper_sort":
        out = run_paper_variants(mesh)
    else:
        out = run_cell_variants(args.cell, mesh)
    path = f"hillclimb_{args.cell}.json"
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")





# ---------------------------------------------------------------- paper cell

def run_paper_variants(mesh, n=1 << 20, d=59):
    """Hillclimb the paper's own workload: one SoftSort grad step over
    N=2^20 splat attributes.  Variants: row-shard topology, payload
    dtype, chunk size.  (The Pallas kernel's terms are analytic — it
    lowers only for TPU; see EXPERIMENTS.md §Perf.)"""
    import functools
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.losses import grid_sorting_loss
    from repro.core.softsort import softsort_apply_chunked

    hw = (1 << 10, 1 << 10)
    axis0 = mesh.axis_names[0]
    all_axes = tuple(mesh.axis_names)

    def make_step(chunk, bf16_payload):
        def loss(w, x, tau, norm):
            xx = x.astype(jnp.bfloat16) if bf16_payload else x
            y, cs = softsort_apply_chunked(w, xx, tau, chunk=chunk)
            return grid_sorting_loss(y.astype(jnp.float32), cs, x, hw, norm)

        def step(w, x, tau, norm):
            l, g = jax.value_and_grad(loss)(w, x, tau, norm)
            return l, g
        return step

    def measure(name, chunk, bf16_payload, shard_axes):
        w = jax.ShapeDtypeStruct((n,), jnp.float32)
        x = jax.ShapeDtypeStruct((n, d), jnp.float32)
        tau = jax.ShapeDtypeStruct((), jnp.float32)
        norm = jax.ShapeDtypeStruct((), jnp.float32)
        sh_x = NamedSharding(mesh, P(shard_axes, None))
        sh_w = NamedSharding(mesh, P())       # N params replicated
        jfn = jax.jit(make_step(chunk, bf16_payload),
                      in_shardings=(sh_w, sh_x, None, None),
                      out_shardings=(None, NamedSharding(mesh, P())))
        with jax.set_mesh(mesh):
            compiled = jfn.lower(w, x, tau, norm).compile()
        from repro.launch.dryrun import collective_stats
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
        rec = {
            "variant": name,
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["total_bytes"],
        }
        rec["compute_s"] = rec["flops"] / PEAK_FLOPS
        rec["memory_s"] = rec["bytes"] / HBM_BW
        rec["collective_s"] = rec["coll"] / LINK_BW
        rec["bound_s"] = max(rec["compute_s"], rec["memory_s"],
                             rec["collective_s"])
        print(f"  {name:32s} compute={rec['compute_s']:8.4f}s "
              f"memory={rec['memory_s']:8.4f}s "
              f"coll={rec['collective_s']:8.4f}s "
              f"bound={rec['bound_s']:8.4f}s", flush=True)
        return rec

    out = []
    out.append(measure("baseline_rows_axis0_c512", 512, False, axis0))
    out.append(measure("rows_all_axes_c512", 512, False, all_axes))
    out.append(measure("rows_all_axes_c2048", 2048, False, all_axes))
    out.append(measure("rows_all_axes_c512_bf16x", 512, True, all_axes))
    out.append(measure("rows_all_axes_c2048_bf16x", 2048, True, all_axes))
    return out


if __name__ == "__main__":
    main()
