"""Guardrail detection / probe-overhead benchmark (ISSUE 9 tentpole).

    PYTHONPATH=src python -m benchmarks.guardrail_bench [--smoke]
        [--out BENCH_guardrails.json]

Two cell families, both deterministic per seed:

* ``"kind": "detection"`` — the chaos grid from
  tests/test_guardrails.py rerun as a measured artifact: every
  ``FaultInjector`` value-corruption mode (bit-flip / sign-flip /
  stale-buffer / NaN-splat) injected at an exact dispatch index into
  every serving path (pure-jnp oracle / fused kernel / banded kernel /
  bf16 kernel) under a full-rate shadow guardrail.  Each cell records
  whether the corruption was *detected* (an ``IntegrityViolation``
  incident with the firing probe's name), *repaired* (the request
  still completed), and *bit_identical* (the repaired result equals an
  uninjected run of the same config and seed).  ``tools/check_bench.py``
  gates the committed file on all three being true in every cell —
  detection_rate must be exactly 1.0.

* ``"kind": "overhead"`` — the cost of the probes on a clean batched
  anneal, one cell per (mode, shadow_rate) point including the default
  serving rate (1/32).  Guarded and unguarded runs execute the SAME
  rung-segmented schedule (the unguarded baseline gets a no-op
  ``rung_hook`` so both pay identical host-sync seams) and the
  reported ``overhead_pct`` is min-of-reps over interleaved
  repetitions — min, not mean, because on a shared CPU box background
  load only ever inflates a wall-clock sample.  The committed file is
  gated on the default-rate cell staying <= 5%; smoke runs
  (``"smoke": true``) skip the timing gate (schema and detection are
  machine-independent, wall-clock thresholds are not) and CI re-checks
  the committed full-run artifact instead.

Off-TPU the ``wall_clock`` label is "emulated", same convention as
every other committed bench: detection booleans and probe bookkeeping
are exact anywhere, absolute times are not TPU numbers.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    run_round_segment,
    shuffle_soft_sort_batched,
)
from repro.launch.serve import SortServer
from repro.runtime.fault_tolerance import (
    CorruptionSpec,
    FaultInjector,
    RetryPolicy,
)
from repro.runtime.guardrails import GuardrailPolicy, shadow_sampled

# ----------------------------------------------------- detection grid
# Mirrors tests/test_guardrails.py: small problems, exact dispatch
# index 1 (the second rung), one corruption per run.

N_DET, HW_DET, D_DET = 16, (4, 4), 3
FULL_SHADOW = GuardrailPolicy(mode="shadow", shadow_rate=1.0)
FAST_RETRY = RetryPolicy(max_retries=4, backoff_base_s=0.0)

PATHS = {
    "oracle": {},
    "kernel": {"use_kernel": True},
    "banded": {"use_kernel": True, "band": 8},
    "bf16": {"use_kernel": True, "compute_dtype": "bfloat16"},
}
CORRUPTIONS = {
    "bitflip": ("orders", 5),
    "signflip": ("losses", 1),
    "stale": ("losses", 0),
    "nan": ("losses", 2),
}


def _det_cfg(path):
    return ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=N_DET,
                                 **PATHS[path])


def _serve_once(cfg, x, key, *, engine=None, guardrail=None):
    server = SortServer(HW_DET, d=D_DET, cfg=cfg, max_wait_ms=0.0,
                        sched_rungs=2, engine_fn=engine,
                        guardrail=guardrail, retry=FAST_RETRY)
    try:
        out = server.submit(x, key=key).result(timeout=300)
    finally:
        stats = server.stats
        server.close()
    return out, stats


def run_detection_grid(paths, corruptions):
    x = np.random.RandomState(0).rand(N_DET, D_DET).astype(np.float32)
    key = jax.random.PRNGKey(11)
    cells = []
    for path in paths:
        cfg = _det_cfg(path)
        clean, _ = _serve_once(cfg, x, key)
        for name in corruptions:
            target, index = CORRUPTIONS[name]
            inj = FaultInjector(
                run_round_segment,
                corrupt_calls={1: CorruptionSpec(name, target, index)})
            t0 = time.perf_counter()
            try:
                out, stats = _serve_once(cfg, x, key, engine=inj,
                                         guardrail=FULL_SHADOW)
                repaired = True
            except Exception:
                out, stats, repaired = None, {}, False
            wall = time.perf_counter() - t0
            detected = stats.get("integrity_violations", 0) >= 1
            incidents = stats.get("integrity_incidents", [])
            probe = incidents[0]["probe"] if incidents else None
            identical = repaired and all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(out, clean))
            cells.append({
                "kind": "detection",
                "path": path,
                "corruption": name,
                "target": target,
                "dispatch_index": 1,
                "injected": int(inj.corruptions),
                "detected": bool(detected),
                "probe": probe,
                "repaired": bool(repaired),
                "bit_identical": bool(identical),
                "violations": int(stats.get("integrity_violations", 0)),
                "self_heals": int(stats.get("self_heals", 0)),
                "wall_s": wall,
            })
            flag = "ok" if detected and repaired and identical else "FAIL"
            print(f"  detection {path:7s} x {name:9s} -> "
                  f"probe={probe!s:13s} {flag}")
    return cells


# ---------------------------------------------------- probe overhead
# One clean batched anneal per (mode, rate) point, all points running
# the identical rung-segmented schedule.  Sized so per-rung compute
# dominates the fixed per-rung probe cost — overhead on a toy problem
# measures host dispatch, not the probes' marginal price.

def overhead_points(default_rate):
    return [
        ("off", None, False),
        ("invariants", GuardrailPolicy(mode="invariants", seed=3), False),
        ("shadow", GuardrailPolicy(mode="shadow",
                                   shadow_rate=default_rate, seed=3), True),
        ("shadow", GuardrailPolicy(mode="shadow",
                                   shadow_rate=0.25, seed=3), False),
        ("shadow", GuardrailPolicy(mode="shadow",
                                   shadow_rate=1.0, seed=3), False),
    ]


def run_overhead(*, hw, b, rounds, inner_steps, every, reps,
                 default_rate):
    n = hw[0] * hw[1]
    cfg = ShuffleSoftSortConfig(rounds=rounds, inner_steps=inner_steps,
                                chunk=n)
    xs = np.random.RandomState(0).rand(b, n, D_DET).astype(np.float32)
    key = jax.random.PRNGKey(0)

    def once(guardrail):
        return shuffle_soft_sort_batched(
            xs, hw, cfg, key=key, rung_hook=lambda s: None,
            checkpoint_every=every, guardrail=guardrail)

    points = overhead_points(default_rate)
    once(None)                    # warm the segment programs
    once(points[-1][1])           # ...and the shadow/oracle program
    best = [float("inf")] * len(points)
    monitors = [None] * len(points)
    for _ in range(reps):         # interleaved: drift hits all points
        for i, (_, pol, _) in enumerate(points):
            t0 = time.perf_counter()
            once(pol)
            best[i] = min(best[i], time.perf_counter() - t0)
    base = best[0]
    rungs = len(range(0, rounds, every))
    cells = []
    for i, (mode, pol, is_default) in enumerate(points):
        rate = 0.0 if pol is None or pol.mode != "shadow" \
            else pol.shadow_rate
        sampled = sum(shadow_sampled(pol.seed, s, rate)
                      for s in range(0, rounds, every)) if pol else 0
        cell = {
            "kind": "overhead",
            "mode": mode,
            "shadow_rate": rate,
            "default": bool(is_default),
            "B": b, "N": hw[0] * hw[1], "rounds": rounds,
            "inner_steps": inner_steps, "rungs": rungs,
            "rungs_shadowed": int(sampled),
            "reps": reps,
            "unguarded_s": base,
            "guarded_s": best[i],
            "overhead_pct": 100.0 * (best[i] - base) / base,
        }
        cells.append(cell)
        print(f"  overhead {mode:10s} rate={rate:<7.5g} "
              f"{best[i]:.3f}s  {cell['overhead_pct']:+.1f}%"
              + ("  [default]" if is_default else ""))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid + small overhead problem; output "
                    "is schema-checked but exempt from the timing gate")
    ap.add_argument("--out", default="BENCH_guardrails.json")
    args = ap.parse_args()

    default_rate = GuardrailPolicy().shadow_rate
    print("detection grid:")
    if args.smoke:
        det = run_detection_grid(("oracle", "kernel"),
                                 ("signflip", "nan"))
    else:
        det = run_detection_grid(sorted(PATHS), sorted(CORRUPTIONS))
    print("probe overhead:")
    if args.smoke:
        over = run_overhead(hw=(8, 8), b=4, rounds=8, inner_steps=2,
                            every=1, reps=2, default_rate=default_rate)
    else:
        over = run_overhead(hw=(16, 16), b=16, rounds=96, inner_steps=4,
                            every=2, reps=4, default_rate=default_rate)

    ok = [c for c in det if c["detected"] and c["repaired"]
          and c["bit_identical"]]
    doc = {
        "bench": "guardrail_bench",
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "note": ("chaos detection grid + probe overhead; detection "
                 "booleans exact on any backend, wall-clock labeled "
                 "emulated off-TPU"),
        "wall_clock": ("measured" if jax.default_backend() == "tpu"
                       else "emulated"),
        "default_shadow_rate": default_rate,
        "detection_rate": len(ok) / max(1, len(det)),
        "cells": det + over,
    }
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}: {len(det)} detection cells "
          f"(rate {doc['detection_rate']:.2f}), {len(over)} overhead "
          "cells")


if __name__ == "__main__":
    main()
