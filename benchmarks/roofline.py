"""Roofline analysis from dry-run records (deliverable (g)).

Reads the JSON written by ``repro.launch.dryrun --all --out ...`` and
derives, per (arch x shape):

    compute_s    = per-device HLO FLOPs / 197e12        (v5e bf16 peak)
    memory_s     = per-device HLO bytes  / 819e9        (HBM bandwidth)
    collective_s = per-device wire bytes / 50e9         (per-link ICI)

plus MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill/decode)
and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which catches
remat/redundancy waste.  Dominant term = the bottleneck the §Perf loop
iterates on.
"""
from __future__ import annotations

import json

import jax

PEAK_FLOPS = 197e12          # TPU v5e bf16 per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link
CHIPS = {"single": 256, "multi": 512}


def active_param_counts(arch: str) -> tuple[int, int]:
    """(total_active_params, embed_params) via shape-only init; MoE expert
    leaves scale by k/E."""
    from repro.configs import get_config
    from repro.models import model as model_lib
    cfg = get_config(arch)

    shapes = jax.eval_shape(
        lambda k: model_lib.init_model(k, cfg)[0], jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    active = 0
    embed = 0
    moe_frac = (cfg.num_experts_per_tok / cfg.num_experts
                if cfg.num_experts else 1.0)
    for path, leaf in flat:
        keys = "/".join(str(p) for p in path)
        n = 1
        for s in leaf.shape:
            n *= s
        if "embed" in keys:
            embed += n
        elif "moe" in keys and "router" not in keys:
            active += int(n * moe_frac)
        else:
            active += n
    return active, embed


def model_flops(arch: str, shape: str, kind: str) -> float:
    from repro.models.config import cell_by_name
    cell = cell_by_name(shape)
    n_active, _ = active_param_counts(arch)
    if kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def analyze(records: list[dict]) -> list[dict]:
    out = []
    for rec in records:
        if rec.get("status") != "ok" or rec.get("kind") == "paper":
            continue
        ri = rec.get("roofline_inputs", {})
        if "flops" not in ri:
            continue
        chips = CHIPS[rec["mesh"]]
        compute_s = ri["flops"] / PEAK_FLOPS
        memory_s = ri["bytes_accessed"] / HBM_BW
        coll_s = ri["collective_bytes"] / LINK_BW
        mf = model_flops(rec["arch"], rec["shape"], rec["kind"])
        mf_dev = mf / chips
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        out.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dominant,
            "model_flops_dev": mf_dev,
            "useful_ratio": mf_dev / max(ri["flops"], 1.0),
            "roofline_frac": (mf_dev / PEAK_FLOPS) / max(bound, 1e-12),
        })
    return out


def print_table(rows):
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>9s} "
           f"{'coll_s':>9s} {'bound':>10s} {'useful':>7s} {'roofline':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:>10.4f} "
              f"{r['memory_s']:>9.4f} {r['collective_s']:>9.4f} "
              f"{r['dominant']:>10s} {r['useful_ratio']:>7.3f} "
              f"{r['roofline_frac']:>8.3f}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", default="dryrun_single.json")
    a = ap.parse_args()
    with open(a.records) as f:
        recs = json.load(f)
    print_table(analyze(recs))
