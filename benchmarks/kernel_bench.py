"""Kernel-tier microbenchmark: the SoftSort apply, fwd and fwd+grad,
one row per implementation layer, swept over an (N, d, B, K, dtype)
grid:

  * ``dense``     — O(N^2)-memory jnp oracle (``kernels/ref.py``)
  * ``chunked``   — streamed pure-jnp row blocks (``core/softsort.py``)
  * ``kernel_v1`` — v1 Pallas path: 3-pass forward + chunked jnp-scan
                    backward (``ops.softsort_apply_v1``, PR 1/2 design)
  * ``fused``     — fused online-softmax forward (2 passes) + 2-pass
                    Pallas backward with (perm, m, l, y) residuals
  * ``banded``    — O(N*K) band-grid Pallas path
                    (``ops.softsort_apply_banded``): both axes in
                    sorted-rank order, width-(2K+1) band scored,
                    payload carried d-on-sublanes; each cell's K is the
                    fourth sweep axis

The dtype axis (``float32`` / ``bfloat16``) exercises the kernels'
``compute_dtype`` tier: bf16 cells run ONLY the kernel impls (fused,
banded — the jnp tiers are the f32 reference and have no bf16 mode) and
their parity columns are measured against the same f32 oracles, gated
by the looser documented bf16 tolerance (``--tol-bf16``).  Block sizes
come from the committed autotune table exactly as production dispatch
does (``repro.kernels.autotune.lookup_blocks``, hardcoded-256 fallback).

Emits ``BENCH_kernels.json`` (committed at the repo root; validated by
``tools/check_bench.py``).  Three kinds of columns:

  * measured wall-clock (``fwd_s`` / ``fwdgrad_s``) — every cell also
    carries ``wall_clock``: "measured" on a real TPU, "emulated" on any
    other backend, where Pallas runs in INTERPRET mode and the numbers
    are shape/ordering signals only — emulation is known to INVERT real
    orderings (the jnp-scan baseline gets native XLA fusion while every
    Pallas grid step pays emulation overhead; EXPERIMENTS.md §Perf).
  * parity (``parity`` / ``band``) — max abs error against the dense
    oracle (and, for the banded kernel, against the windowed jnp oracle
    it must match).  Backend-independent; CI gates on these
    (``--check``): f32 columns against ``--tol``, bf16 columns against
    the documented ``--tol-bf16``.  Banded-vs-dense parity is
    additionally slacked by the recorded ``band.tail_bound``: the keys
    here are a shuffled arange — the trainer's per-round linear init —
    so the K-rank gap is K exactly and the bound is astronomically
    small.
  * modeled HBM traffic (``model_hbm_mb``) — per-pass bytes moved
    between HBM and VMEM for one fwd+grad step, counted mechanically
    from the block specs (block bytes x revisit count, at each
    operand's HBM dtype; see ``_model_hbm_bytes``) — EMITTED FOR EVERY
    DTYPE CELL so check_bench gates on it uniformly.  At the paper's
    d <= 50 the apply is memory-bound (EXPERIMENTS.md §Roofline), so
    TPU step time is proportional to these bytes;
    ``model_fused_over_v1`` / ``model_banded_over_fused`` are the
    expected on-TPU fwd+grad speedups of each transition, and bf16
    cells add ``model_f32_over_this`` — the bf16-vs-f32 traffic
    reduction of each kernel tier at that shape.

Usage:

    PYTHONPATH=src python -m benchmarks.kernel_bench            # full sweep
    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke --check
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.softsort import (
    band_tail_bound,
    softsort_apply_banded as banded_oracle,
    softsort_apply_chunked,
)
from repro.kernels.autotune import lookup_blocks
from repro.kernels.ops import (
    _band_geometry,
    _block_geometry,
    softsort_apply,
    softsort_apply_banded,
    softsort_apply_v1,
)
from repro.kernels.ref import softsort_apply_ref

FULL_CELLS = [  # (N, d, B, K)
    (1024, 8, 1, 128),
    (1024, 8, 8, 128),
    (1024, 50, 1, 128),
    (2048, 8, 1, 128),
    (4096, 8, 1, 256),
]
SMOKE_CELLS = [(384, 8, 2, 64)]    # multi-block grids, tiny runtime

DTYPES = ("float32", "bfloat16")
F32 = 4                        # bytes
DTYPE_BYTES = {"float32": 4, "bfloat16": 2}


def _time(fn, *args, reps: int = 3):
    """(mean seconds over reps, last output) — the output is returned so
    parity columns reuse it instead of re-running the (interpret-mode
    slow) computation a third time."""
    out = fn(*args)            # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def _batched_ref(w, x, tau):
    return jax.vmap(lambda wi, xi: softsort_apply_ref(wi, xi, tau))(w, x)


def _impls(tau, band, dtype):
    """name -> apply(w (B,N), x (B,N,d)) returning (y, c).  bf16 cells
    carry only the kernel impls — the jnp tiers are the f32 oracles."""
    kernel = {
        "fused": lambda w, x: softsort_apply(w, x, tau, compute_dtype=dtype),
        "banded": lambda w, x: softsort_apply_banded(w, x, tau, band,
                                                     compute_dtype=dtype),
    }
    if dtype != "float32":
        return kernel
    return {
        "dense": lambda w, x: _batched_ref(w, x, tau),
        "chunked": lambda w, x: softsort_apply_chunked(w, x, tau, 256),
        "kernel_v1": lambda w, x: softsort_apply_v1(w, x, tau),
        **kernel,
    }


def _model_hbm_bytes(n: int, d: int, bsz: int, band: int,
                     dtype: str = "float32") -> dict:
    """Per-step (fwd+grad) HBM<->VMEM bytes for the kernel paths,
    counted from the block specs: each pass moves ``block bytes x
    revisit count`` per operand (an operand whose index map ignores the
    innermost grid axis is fetched once per outer step and reused), at
    each operand's HBM dtype under the mixed-precision contract — keys,
    m/l/D, and the key/tau gradients are always f32; the payload, the
    dy/dc cotangents, the saved y residual, the y forward output and
    the dx gradient ride in the compute dtype (f32 scratch accumulators
    never touch HBM).  Block sizes resolve through the same autotune
    lookup production dispatch uses.

    N^2-scale terms exist ONLY in the v1 jnp-scan backward (f32-only):
    its einsum boundaries materialize p / dP / ds as (B, chunk, N) HBM
    arrays — one write + one read each, 6 x N^2 x 4 bytes per instance
    (delta, s, sgn fold into fused elementwise ops and are not counted
    — the model is conservative in v1's favor).  The fused backward
    consumes every score block inside its VMEM tile but still STREAMS
    the full (N/block)^2 tile space in TWO passes (the PR-5 merge of
    the delta pass into the dws sweep removed the third); the banded
    path visits only the (N/blk) * (2*ceil(K/blk)+1) band cells AND
    carries the payload d-on-sublanes (dsub = round_up(d, 8) instead of
    the 128-lane pad), which is where its order-of-magnitude byte
    reduction comes from at the paper's small d.
    """
    cdb = DTYPE_BYTES[dtype]
    brc, bcc = lookup_blocks("fused", n=n, d=d, dtype=dtype)
    br, bc, np_, dp = _block_geometry(n, d, brc, bcc)
    ni, nj = np_ // br, np_ // bc
    keys = np_ * F32                      # one (Np,)-sized f32 vector
    keys_c = np_ * cdb                    # one (Np,)-sized cd vector (dc)
    xmat = np_ * dp * cdb                 # one lane-padded (Np, dp) cd matrix
    # v1 is never autotuned: it always runs its hardcoded 256-square
    # blocks, so its model must use THAT geometry, not the fused
    # winner's.
    brv, bcv, npv, dpv = _block_geometry(n, d, 256, 256)
    niv, njv = npv // brv, npv // bcv
    keys_v = npv * F32
    xmat32 = npv * dpv * F32

    # Streamed passes (per instance).  "re-read k x" = the operand's
    # index map varies with the inner grid axis.
    fwd_fused = (
        # fused sweep: ws once, w/x re-read per row block, y (cd, via
        # the f32 scratch accumulator) / m / l written
        (keys + keys * ni + xmat * ni + xmat + 2 * keys)
        # colsum: w once, ws/m/l re-read per col block, c written
        + (keys + 3 * keys * nj + keys)
    )
    bwd_fused = (
        # merged delta+dws sweep: ws/m/l once, w/dc re-read per row
        # block, x re-read per row block, dy/y (cd) row-aligned once,
        # D/dws written (A/S partial sums live in VMEM scratch)
        (3 * keys + keys * ni + keys_c * ni + xmat * ni + 2 * xmat
         + 2 * keys)
        # dx pass: dy re-read per col block, x once, ws/m/l/D re-read,
        # w/dc once, dx (cd, via scratch) / dw_cols / dtau written
        + (xmat * nj + xmat + 4 * keys * nj + keys + keys_c + xmat
           + 2 * keys)
    )
    fwd_v1 = (
        (keys_v + keys_v * niv + 2 * keys_v)               # stats pass
        + (keys_v + keys_v * niv + xmat32 * niv + 2 * keys_v
           + xmat32)                                       # apply pass
        + (keys_v + 3 * keys_v * njv + keys_v)             # colsum pass
        # + m/l round-trip between stats and apply (written then re-read
        # per row block) — the mid-forward HBM traffic the fusion removes
        + 2 * keys_v * 2
    )
    n2 = 6 * n * n * F32                                   # p/dP/ds, w+r
    bwd_v1 = n2 + 2 * n * d * F32 * (n // min(256, n))     # + x/dy per chunk

    # Banded path: square blk-blocks, band cells only, transposed
    # payload (dsub sublanes x Np lanes).
    blkc, _ = lookup_blocks("banded", n=n, d=d, k=band, dtype=dtype)
    blk, npb, dsub = _band_geometry(n, d, blkc)
    nib = npb // blk
    off = -(-band // blk)
    cells = nib * (2 * off + 1)           # vs nib^2 dense grid cells
    bkeys = npb * F32
    bkeys_c = npb * cdb
    keyblk = blk * F32
    keyblk_c = blk * cdb
    xtb = blk * dsub * cdb                # one payload band block, cd
    xt = npb * dsub * cdb                 # whole transposed payload, cd
    fwd_banded = (
        # band sweep: wr once, wc/xt re-read per band cell, y (cd, via
        # scratch) / m / l written
        (bkeys + cells * keyblk + cells * xtb + xt + 2 * bkeys)
        # band colsum: wc once, wr/m/l re-read per band cell, c written
        + (bkeys + 3 * cells * keyblk + bkeys)
    )
    bwd_banded = (
        # merged delta+dws_row band sweep: wr/m/l once, wc per cell,
        # xs_t per cell, dy_t/y_t (cd) row-aligned once, dc (cd) per
        # cell, D/dws_row written (A/S in VMEM scratch)
        (3 * bkeys + cells * keyblk + cells * xtb + 2 * xt
         + cells * keyblk_c + 2 * bkeys)
        # dcol: dy_t per cell, xs_t once, wr/m/l/D per cell, wc once,
        # dc (cd) once, dxs_t (cd, via scratch) / dw_col / dtau written
        + (cells * xtb + xt + 4 * cells * keyblk + bkeys + bkeys_c
           + xt + 2 * bkeys)
    )

    model = {
        "fused": bsz * (fwd_fused + bwd_fused) / 1e6,
        "banded": bsz * (fwd_banded + bwd_banded) / 1e6,
    }
    if dtype == "float32":
        model["kernel_v1"] = bsz * (fwd_v1 + bwd_v1) / 1e6
    # Record the tilings the model was evaluated at — THIS backend's
    # dispatch resolution (autotuned winners where a table row matches,
    # the hardcoded fallback elsewhere).  A different backend may
    # dispatch different blocks (e.g. a TPU host misses every cpu-keyed
    # table row until re-tuned), so the committed model is explicitly a
    # projection at the recorded tiling, not at some other host's.
    blocks = {"fused": [br, bc], "banded": [blk], "kernel_v1": [brv, bcv]}
    return model, blocks


def _cell_operands(n: int, d: int, bsz: int):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n + d + bsz), 4)
    # Keys are a shuffled arange — exactly the per-round linear init the
    # trainer uses (w = arange(N) re-shuffled each round), so the bench
    # measures the operating regime: unit rank gaps, no bitwise ties (at
    # a bitwise-equal tie |.| has no derivative and blocked vs dense
    # autodiff legitimately pick different subgradients), and a K-rank
    # key spread of exactly K, which is what makes the banded tier's
    # tail bound (and hence its vs-dense parity gate) meaningful.  The
    # same keys ALSO make the bf16 score rounding exact here (scores
    # are small integer multiples of 1/tau), so bf16 cells isolate the
    # payload-side quantization.
    w = jax.vmap(lambda k: jax.random.permutation(
        k, jnp.arange(n, dtype=jnp.float32)))(jax.random.split(k1, bsz))
    x = jax.random.normal(k2, (bsz, n, d))
    a = jax.random.normal(k3, (bsz, n, d))
    b = jax.random.normal(k4, (bsz, n))
    return w, x, a, b


def _loss_fn(apply_fn, a, b):
    def f(w, x):
        y, c = apply_fn(w, x)
        return jnp.sum(y * a) + jnp.sum(c * b)
    return f


def _cell_refs(w, x, a, b, tau: float, band: int) -> dict:
    """The f32 oracle references (dense + windowed banded, fwd and dw),
    computed ONCE per (N, d, B, K) shape — every dtype cell of that
    shape shares the identical keys and payload, so recomputing the
    O(N^2) dense oracle per dtype would only burn bench time."""
    dense = jax.jit(lambda w, x: _batched_ref(w, x, tau))
    y_ref, c_ref = dense(w, x)
    dw_ref = jax.jit(jax.grad(_loss_fn(
        lambda w, x: _batched_ref(w, x, tau), a, b)))(w, x)
    ob = jax.jit(lambda w, x: banded_oracle(w, x, tau, band))
    y_ob, c_ob = ob(w, x)
    dw_ob = jax.jit(jax.grad(_loss_fn(
        lambda w, x: banded_oracle(w, x, tau, band), a, b)))(w, x)
    return {"y": y_ref, "c": c_ref, "dw": dw_ref,
            "y_band": y_ob, "c_band": c_ob, "dw_band": dw_ob}


def run_cell(n: int, d: int, bsz: int, band: int, dtype: str,
             tau: float = 0.5, reps: int = 3, operands=None,
             refs=None) -> dict:
    w, x, a, b = operands if operands is not None else _cell_operands(
        n, d, bsz)
    if refs is None:
        refs = _cell_refs(w, x, a, b, tau, band)

    impls = _impls(tau, band, dtype)

    fwd_s, fwdgrad_s, grads, outs = {}, {}, {}, {}
    for name, fn in impls.items():
        fwd_s[name], outs[name] = _time(jax.jit(fn), w, x, reps=reps)
        jg = jax.jit(jax.value_and_grad(_loss_fn(fn, a, b)))
        fwdgrad_s[name], (_, grads[name]) = _time(jg, w, x, reps=reps)

    y_ref, c_ref, dw_ref = refs["y"], refs["c"], refs["dw"]

    def relerr(got, want):
        # max abs error relative to the oracle's max magnitude — scale-
        # free, so one tolerance gates every N/d/B cell.
        scale = float(jnp.max(jnp.abs(want))) + 1e-9
        return float(jnp.max(jnp.abs(
            got.astype(jnp.float32) - want))) / scale

    parity = {}
    for name in impls:
        if name in ("dense", "banded"):
            continue
        parity[f"{name}_y_relerr"] = relerr(outs[name][0], y_ref)
        parity[f"{name}_c_relerr"] = relerr(outs[name][1], c_ref)
        parity[f"{name}_dw_relerr"] = relerr(grads[name], dw_ref)

    # Banded: against its windowed f32 jnp oracle (same truncation, so
    # this isolates the kernel/precision error), within the analytic
    # tail bound (plus tolerance) against the dense oracle.
    band_cols = {
        "K": band,
        "tail_bound": float(jnp.max(band_tail_bound(w, tau, band))),
        "vs_oracle_y_relerr": relerr(outs["banded"][0], refs["y_band"]),
        "vs_oracle_c_relerr": relerr(outs["banded"][1], refs["c_band"]),
        "vs_oracle_dw_relerr": relerr(grads["banded"], refs["dw_band"]),
        "vs_dense_y_relerr": relerr(outs["banded"][0], y_ref),
        "vs_dense_c_relerr": relerr(outs["banded"][1], c_ref),
        "vs_dense_dw_relerr": relerr(grads["banded"], dw_ref),
    }

    model, model_blocks = _model_hbm_bytes(n, d, bsz, band, dtype)
    cell = {
        "N": n, "d": d, "B": bsz, "K": band, "tau": tau,
        "dtype": dtype,
        "wall_clock": ("measured" if jax.default_backend() == "tpu"
                       else "emulated"),
        "fwd_s": fwd_s,
        "fwdgrad_s": fwdgrad_s,
        "parity": parity,
        "band": band_cols,
        "model_hbm_mb": model,
        "model_blocks": model_blocks,
        "model_banded_over_fused": model["fused"] / model["banded"],
        "passes": {"kernel_v1_fwd": 3, "fused_fwd": 2, "fused_bwd": 2,
                   "banded_fwd": 2, "banded_bwd": 2, "kernel_v1_bwd": 0},
    }
    if dtype == "float32":
        cell["model_fused_over_v1"] = model["kernel_v1"] / model["fused"]
    else:
        f32_model, _ = _model_hbm_bytes(n, d, bsz, band, "float32")
        cell["model_f32_over_this"] = {
            name: f32_model[name] / model[name] for name in model}
    return cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny multi-block cell (CI), both dtypes")
    ap.add_argument("--check", action="store_true",
                    help="assert every parity column <= its dtype's tol "
                         "(banded-vs-dense <= tol + tail bound) and exit "
                         "non-zero otherwise")
    ap.add_argument("--tol", type=float, default=2e-3,
                    help="f32 parity gate: max abs error vs the dense "
                         "oracle, scaled by the oracle magnitude")
    ap.add_argument("--tol-bf16", type=float, default=2e-2,
                    help="bf16 parity gate — the documented bf16 "
                         "envelope (EXPERIMENTS.md §Perf): payload "
                         "quantization is ~0.4%% relative and the "
                         "observed worst case across the sweep is "
                         "under 1%%")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_kernels.json "
                         "for the full sweep, stdout-only for --smoke)")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    shapes = SMOKE_CELLS if args.smoke else FULL_CELLS
    rows = []
    for n, d, bsz, band in shapes:
        operands = _cell_operands(n, d, bsz)
        refs = _cell_refs(*operands, 0.5, band)   # shared across dtypes
        for dtype in DTYPES:
            cell = run_cell(n, d, bsz, band, dtype, reps=args.reps,
                            operands=operands, refs=refs)
            rows.append(cell)
            extra = (f"fused/v1 HBM {cell['model_fused_over_v1']:.2f}x"
                     if dtype == "float32" else
                     f"f32/bf16 banded HBM "
                     f"{cell['model_f32_over_this']['banded']:.2f}x")
            print(f"N={n} d={d} B={bsz} K={band} {dtype}: "
                  f"fwd fused {cell['fwd_s']['fused']*1e3:.1f}ms "
                  f"banded {cell['fwd_s']['banded']*1e3:.1f}ms "
                  f"({cell['wall_clock']}), {extra}, "
                  f"banded/fused win "
                  f"{cell['model_banded_over_fused']:.2f}x, "
                  f"banded dw err vs oracle "
                  f"{cell['band']['vs_oracle_dw_relerr']:.2e} "
                  f"(vs dense {cell['band']['vs_dense_dw_relerr']:.2e}, "
                  f"bound {cell['band']['tail_bound']:.2e})")

    doc = {
        "bench": "kernel_bench",
        "backend": jax.default_backend(),
        "tol": args.tol,
        "tol_bf16": args.tol_bf16,
        "note": ("off-TPU the Pallas kernels run in interpret mode: "
                 "wall-clock columns are labeled 'emulated' and are "
                 "shape signals only (emulation overhead penalizes the "
                 "Pallas backward; the jnp-scan baseline gets native "
                 "XLA fusion — orderings INVERT vs real TPU, see "
                 "EXPERIMENTS.md §Perf); parity columns are exact "
                 "everywhere (f32 gated by tol, bf16 by tol_bf16); "
                 "model_hbm_mb counts per-step HBM<->VMEM bytes from "
                 "the block specs at each operand's HBM dtype and is "
                 "the memory-bound TPU projection (EXPERIMENTS.md "
                 "§Roofline) AT the tilings recorded in model_blocks — "
                 "this backend's dispatch resolution (autotuned winners "
                 "where present, 256 fallback elsewhere; v1 always its "
                 "hardcoded 256); another backend may dispatch "
                 "different blocks; banded vs-dense parity is gated "
                 "against band.tail_bound"),
        "cells": rows,
    }
    out = args.out or (None if args.smoke else "BENCH_kernels.json")
    if out:
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {out}")

    if args.check:
        bad = []
        for cell in rows:
            tol = args.tol if cell["dtype"] == "float32" else args.tol_bf16
            for key, val in cell["parity"].items():
                if not np.isfinite(val) or val > tol:
                    bad.append((cell["N"], cell["d"], cell["B"],
                                cell["dtype"], key, val))
            bound = cell["band"]["tail_bound"]
            for key, val in cell["band"].items():
                if key in ("K", "tail_bound"):
                    continue
                lim = tol + (bound if key.startswith("vs_dense") else 0)
                if not np.isfinite(val) or val > lim:
                    bad.append((cell["N"], cell["d"], cell["B"],
                                cell["dtype"], f"band.{key}", val))
        if bad:
            raise SystemExit(f"parity gate failed: {bad}")
        ncols = sum(len(c["parity"]) + len(c["band"]) - 2 for c in rows)
        print(f"parity gate OK (tol={args.tol}, tol_bf16={args.tol_bf16}, "
              f"{ncols} columns)")
    return doc


if __name__ == "__main__":
    main()
