"""Kernel-tier microbenchmark: the SoftSort apply, fwd and fwd+grad,
one row per implementation layer:

  * ``dense``     — O(N^2)-memory jnp oracle (``kernels/ref.py``)
  * ``chunked``   — streamed pure-jnp row blocks (``core/softsort.py``)
  * ``kernel_v1`` — v1 Pallas path: 3-pass forward + chunked jnp-scan
                    backward (``ops.softsort_apply_v1``, PR 1/2 design)
  * ``fused``     — fused online-softmax forward (2 passes) + full
                    Pallas backward with (perm, ws, m, l, y) residuals

Emits ``BENCH_kernels.json`` (committed at the repo root; validated by
``tools/check_bench.py``).  Two kinds of columns:

  * measured wall-clock (``fwd_s`` / ``fwdgrad_s``) — on a CPU CI
    backend the Pallas kernels run in INTERPRET mode, so these are
    shape/ordering signals only: interpretation emulates the grid
    block-by-block and cannot show an HBM-traffic win (the jnp scan
    backward gets native XLA fusion while the Pallas backward pays
    emulation overhead).  On a real TPU the same columns are the
    roofline numbers.
  * parity (``parity``) — max abs error of each implementation's
    forward and d(loss)/dw against the dense oracle.  EXACT everywhere,
    backend-independent; CI gates on these (``--check``).
  * modeled HBM traffic (``model_hbm_mb``) — per-pass bytes moved
    between HBM and VMEM for one fwd+grad step, counted mechanically
    from the block specs (block bytes x revisit count; see
    ``_model_hbm_bytes``).  At the paper's d <= 50 the apply is
    memory-bound (EXPERIMENTS.md §Roofline), so TPU step time is
    proportional to these bytes and ``model_fused_over_v1`` is the
    expected on-TPU fwd+grad speedup of the fused path.

Usage:

    PYTHONPATH=src python -m benchmarks.kernel_bench            # full sweep
    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke --check
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.softsort import softsort_apply_chunked
from repro.kernels.ops import (
    _block_geometry,
    softsort_apply,
    softsort_apply_v1,
)
from repro.kernels.ref import softsort_apply_ref

FULL_CELLS = [  # (N, d, B)
    (1024, 8, 1),
    (1024, 8, 8),
    (1024, 50, 1),
    (4096, 8, 1),
]
SMOKE_CELLS = [(384, 8, 2)]    # multi-block grid (2x2 tiles), tiny runtime

F32 = 4                        # bytes


def _time(fn, *args, reps: int = 3) -> float:
    out = fn(*args)            # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _batched_ref(w, x, tau):
    return jax.vmap(lambda wi, xi: softsort_apply_ref(wi, xi, tau))(w, x)


def _impls(tau):
    """name -> apply(w (B,N), x (B,N,d)) returning (y, c)."""
    return {
        "dense": lambda w, x: _batched_ref(w, x, tau),
        "chunked": lambda w, x: softsort_apply_chunked(w, x, tau, 256),
        "kernel_v1": lambda w, x: softsort_apply_v1(w, x, tau),
        "fused": lambda w, x: softsort_apply(w, x, tau),
    }


def _model_hbm_bytes(n: int, d: int, bsz: int) -> dict:
    """Per-step (fwd+grad) HBM<->VMEM bytes for the two kernel paths,
    counted from the block specs: each pass moves ``block bytes x
    revisit count`` per operand (an operand whose index map ignores the
    innermost grid axis is fetched once per outer step and reused).

    N^2-scale terms exist ONLY in the v1 jnp-scan backward: its einsum
    boundaries materialize p / dP / ds as (B, chunk, N) HBM arrays —
    one write + one read each, 6 x N^2 x 4 bytes per instance (delta,
    s, sgn fold into fused elementwise ops and are not counted — the
    model is conservative in v1's favor).  The fused backward consumes
    every score block inside its VMEM tile.
    """
    br, bc, np_, dp = _block_geometry(n, d, 256, 256)
    ni, nj = np_ // br, np_ // bc
    keys = np_ * F32                      # one (Np,)-sized vector
    xmat = np_ * dp * F32                 # one (Np, dp)-sized matrix

    # Streamed passes (per instance).  "re-read k x" = the operand's
    # index map varies with the inner grid axis.
    fwd_fused = (
        (keys + keys * ni + xmat * ni + 2 * keys + xmat)   # fused sweep:
        #  ws once, w re-read per row block, x re-read per row block,
        #  m/l/y written once
        + (2 * keys + 2 * keys * nj + keys + xmat * nj)    # colsum: ws/m/l
        #  re-read per col block, c written once, (x absent)
    )
    bwd_fused = (
        # delta: dy/y row-aligned (once), w/dc re-read per row block
        (2 * xmat + 2 * keys * ni + 4 * keys)
        # dx pass: dy re-read per col block, x once, dx/dwc/dtc written
        + (xmat * nj + xmat + 3 * keys + 4 * keys * nj + xmat)
        # dws pass: x re-read per row block, dy once, dws written
        + (xmat * ni + xmat + 4 * keys * ni + keys)
    )
    fwd_v1 = (
        (keys + keys * ni + 2 * keys)                      # stats pass
        + (keys + keys * ni + xmat * ni + 2 * keys + xmat)  # apply pass
        + (2 * keys + 2 * keys * nj + keys)                # colsum pass
        # + m/l round-trip between stats and apply (written then re-read
        # per row block) — the mid-forward HBM traffic the fusion removes
        + 2 * keys * 2
    )
    n2 = 6 * n * n * F32                                   # p/dP/ds, w+r
    bwd_v1 = n2 + 2 * n * d * F32 * (n // min(256, n))     # + x/dy per chunk

    return {
        "kernel_v1": bsz * (fwd_v1 + bwd_v1) / 1e6,
        "fused": bsz * (fwd_fused + bwd_fused) / 1e6,
    }


def run_cell(n: int, d: int, bsz: int, tau: float = 0.5,
             reps: int = 3) -> dict:
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n + d + bsz), 4)
    # Keys are unique by construction (shuffled linspace, the trainer's
    # arange-scale state): at a bitwise-equal tie |.| has no derivative
    # and blocked vs dense autodiff legitimately pick different
    # subgradients, which would poison the parity gate with a
    # measure-zero artifact (a normal draw at N=4096 f32 does collide).
    w = jax.vmap(lambda k: jax.random.permutation(
        k, jnp.linspace(-2.0, 2.0, n)))(jax.random.split(k1, bsz))
    x = jax.random.normal(k2, (bsz, n, d))
    a = jax.random.normal(k3, (bsz, n, d))
    b = jax.random.normal(k4, (bsz, n))

    impls = _impls(tau)

    def loss_fn(apply_fn):
        def f(w, x):
            y, c = apply_fn(w, x)
            return jnp.sum(y * a) + jnp.sum(c * b)
        return f

    fwd_s, fwdgrad_s, grads, outs = {}, {}, {}, {}
    for name, fn in impls.items():
        jfn = jax.jit(fn)
        fwd_s[name] = _time(jfn, w, x, reps=reps)
        jg = jax.jit(jax.value_and_grad(loss_fn(fn)))
        fwdgrad_s[name] = _time(jg, w, x, reps=reps)
        outs[name] = jfn(w, x)
        grads[name] = jg(w, x)[1]

    y_ref, c_ref = outs["dense"]
    dw_ref = grads["dense"]

    def relerr(got, want):
        # max abs error relative to the oracle's max magnitude — scale-
        # free, so one tolerance gates every N/d/B cell.
        scale = float(jnp.max(jnp.abs(want))) + 1e-9
        return float(jnp.max(jnp.abs(got - want))) / scale

    parity = {}
    for name in ("chunked", "kernel_v1", "fused"):
        parity[f"{name}_y_relerr"] = relerr(outs[name][0], y_ref)
        parity[f"{name}_c_relerr"] = relerr(outs[name][1], c_ref)
        parity[f"{name}_dw_relerr"] = relerr(grads[name], dw_ref)

    model = _model_hbm_bytes(n, d, bsz)
    return {
        "N": n, "d": d, "B": bsz, "tau": tau,
        "fwd_s": fwd_s,
        "fwdgrad_s": fwdgrad_s,
        "parity": parity,
        "model_hbm_mb": model,
        "model_fused_over_v1": model["kernel_v1"] / model["fused"],
        "passes": {"kernel_v1_fwd": 3, "fused_fwd": 2, "fused_bwd": 3,
                   "kernel_v1_bwd": 0},
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny multi-block cell (CI)")
    ap.add_argument("--check", action="store_true",
                    help="assert every parity column <= --tol and exit "
                         "non-zero otherwise")
    ap.add_argument("--tol", type=float, default=2e-3,
                    help="parity gate: max abs error vs the dense "
                         "oracle, scaled by the gradient magnitude")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_kernels.json "
                         "for the full sweep, stdout-only for --smoke)")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    rows = []
    for n, d, bsz in cells:
        cell = run_cell(n, d, bsz, reps=args.reps)
        rows.append(cell)
        print(f"N={n} d={d} B={bsz}: "
              f"fwd fused {cell['fwd_s']['fused']*1e3:.1f}ms "
              f"(v1 {cell['fwd_s']['kernel_v1']*1e3:.1f}ms), "
              f"fwd+grad fused {cell['fwdgrad_s']['fused']*1e3:.1f}ms "
              f"(v1 {cell['fwdgrad_s']['kernel_v1']*1e3:.1f}ms), "
              f"model fused/v1 HBM {cell['model_fused_over_v1']:.2f}x, "
              f"fused dw err {cell['parity']['fused_dw_relerr']:.2e}")

    doc = {
        "bench": "kernel_bench",
        "backend": jax.default_backend(),
        "note": ("off-TPU the Pallas kernels run in interpret mode: "
                 "wall-clock columns are shape signals only (emulation "
                 "overhead penalizes the Pallas backward; the jnp-scan "
                 "baseline gets native XLA fusion); parity columns are "
                 "exact; model_hbm_mb counts per-step HBM<->VMEM bytes "
                 "from the block specs and is the memory-bound TPU "
                 "projection (EXPERIMENTS.md §Roofline)"),
        "cells": rows,
    }
    out = args.out or (None if args.smoke else "BENCH_kernels.json")
    if out:
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {out}")

    if args.check:
        bad = []
        for cell in rows:
            for key, val in cell["parity"].items():
                if not np.isfinite(val) or val > args.tol:
                    bad.append((cell["N"], cell["d"], cell["B"], key, val))
        if bad:
            raise SystemExit(f"parity gate failed (tol={args.tol}): {bad}")
        print(f"parity gate OK (tol={args.tol}, "
              f"{sum(len(c['parity']) for c in rows)} columns)")
    return doc


if __name__ == "__main__":
    main()
