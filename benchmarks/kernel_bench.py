"""Kernel-tier microbenchmark: the SoftSort apply, fwd and fwd+grad,
one row per implementation layer:

  * ``dense``     — O(N^2)-memory jnp oracle (``kernels/ref.py``)
  * ``chunked``   — streamed pure-jnp row blocks (``core/softsort.py``)
  * ``kernel_v1`` — v1 Pallas path: 3-pass forward + chunked jnp-scan
                    backward (``ops.softsort_apply_v1``, PR 1/2 design)
  * ``fused``     — fused online-softmax forward (2 passes) + full
                    Pallas backward with (perm, m, l, y) residuals
  * ``banded``    — O(N*K) band-grid Pallas path
                    (``ops.softsort_apply_banded``): both axes in
                    sorted-rank order, width-(2K+1) band scored,
                    payload carried d-on-sublanes; each cell's K is the
                    fourth sweep axis

Emits ``BENCH_kernels.json`` (committed at the repo root; validated by
``tools/check_bench.py``).  Three kinds of columns:

  * measured wall-clock (``fwd_s`` / ``fwdgrad_s``) — on a CPU CI
    backend the Pallas kernels run in INTERPRET mode, so these are
    shape/ordering signals only: interpretation emulates the grid
    block-by-block and cannot show an HBM-traffic win (the jnp scan
    backward gets native XLA fusion while the Pallas backward pays
    emulation overhead).  On a real TPU the same columns are the
    roofline numbers.
  * parity (``parity`` / ``band``) — max abs error against the dense
    oracle (and, for the banded kernel, against the windowed jnp oracle
    it must match EXACTLY).  Backend-independent; CI gates on these
    (``--check``).  Banded-vs-dense parity is gated against the
    recorded ``band.tail_bound`` (plus float tolerance): the keys here
    are a shuffled arange — the trainer's per-round linear init — so
    the K-rank gap is K exactly and the bound is astronomically small.
  * modeled HBM traffic (``model_hbm_mb``) — per-pass bytes moved
    between HBM and VMEM for one fwd+grad step, counted mechanically
    from the block specs (block bytes x revisit count; see
    ``_model_hbm_bytes``).  At the paper's d <= 50 the apply is
    memory-bound (EXPERIMENTS.md §Roofline), so TPU step time is
    proportional to these bytes; ``model_fused_over_v1`` and
    ``model_banded_over_fused`` are the expected on-TPU fwd+grad
    speedups of each transition.

Usage:

    PYTHONPATH=src python -m benchmarks.kernel_bench            # full sweep
    PYTHONPATH=src python -m benchmarks.kernel_bench --smoke --check
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.softsort import (
    band_tail_bound,
    softsort_apply_banded as banded_oracle,
    softsort_apply_chunked,
)
from repro.kernels.ops import (
    _band_geometry,
    _block_geometry,
    softsort_apply,
    softsort_apply_banded,
    softsort_apply_v1,
)
from repro.kernels.ref import softsort_apply_ref

FULL_CELLS = [  # (N, d, B, K)
    (1024, 8, 1, 128),
    (1024, 8, 8, 128),
    (1024, 50, 1, 128),
    (4096, 8, 1, 256),
]
SMOKE_CELLS = [(384, 8, 2, 64)]    # multi-block grids, tiny runtime

F32 = 4                        # bytes


def _time(fn, *args, reps: int = 3):
    """(mean seconds over reps, last output) — the output is returned so
    parity columns reuse it instead of re-running the (interpret-mode
    slow) computation a third time."""
    out = fn(*args)            # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def _batched_ref(w, x, tau):
    return jax.vmap(lambda wi, xi: softsort_apply_ref(wi, xi, tau))(w, x)


def _impls(tau, band):
    """name -> apply(w (B,N), x (B,N,d)) returning (y, c)."""
    return {
        "dense": lambda w, x: _batched_ref(w, x, tau),
        "chunked": lambda w, x: softsort_apply_chunked(w, x, tau, 256),
        "kernel_v1": lambda w, x: softsort_apply_v1(w, x, tau),
        "fused": lambda w, x: softsort_apply(w, x, tau),
        "banded": lambda w, x: softsort_apply_banded(w, x, tau, band),
    }


def _model_hbm_bytes(n: int, d: int, bsz: int, band: int) -> dict:
    """Per-step (fwd+grad) HBM<->VMEM bytes for the kernel paths,
    counted from the block specs: each pass moves ``block bytes x
    revisit count`` per operand (an operand whose index map ignores the
    innermost grid axis is fetched once per outer step and reused).

    N^2-scale terms exist ONLY in the v1 jnp-scan backward: its einsum
    boundaries materialize p / dP / ds as (B, chunk, N) HBM arrays —
    one write + one read each, 6 x N^2 x 4 bytes per instance (delta,
    s, sgn fold into fused elementwise ops and are not counted — the
    model is conservative in v1's favor).  The fused backward consumes
    every score block inside its VMEM tile but still STREAMS the full
    (N/block)^2 tile space; the banded path visits only the
    (N/blk) * (2*ceil(K/blk)+1) band cells AND carries the payload
    d-on-sublanes (dsub = round_up(d, 8) instead of the 128-lane pad),
    which is where its order-of-magnitude byte reduction comes from at
    the paper's small d.
    """
    br, bc, np_, dp = _block_geometry(n, d, 256, 256)
    ni, nj = np_ // br, np_ // bc
    keys = np_ * F32                      # one (Np,)-sized vector
    xmat = np_ * dp * F32                 # one lane-padded (Np, dp) matrix

    # Streamed passes (per instance).  "re-read k x" = the operand's
    # index map varies with the inner grid axis.
    fwd_fused = (
        # fused sweep: ws once, w/x re-read per row block, y/m/l written
        (keys + keys * ni + xmat * ni + xmat + 2 * keys)
        # colsum: w once, ws/m/l re-read per col block, c written
        + (keys + 3 * keys * nj + keys)
    )
    bwd_fused = (
        # delta: dy/y row-aligned (once), ws/m/l once, w/dc re-read per
        # row block, D written
        (2 * xmat + 3 * keys + 2 * keys * ni + keys)
        # dx pass: dy re-read per col block, x once, ws/m/l/D re-read,
        # w/dc once, dx/dw_cols/dtau written
        + (xmat * nj + xmat + 4 * keys * nj + 2 * keys + xmat + 2 * keys)
        # dws pass: x re-read per row block, dy once, w/dc re-read,
        # ws/m/l/D once, dws written
        + (xmat * ni + xmat + 2 * keys * ni + 4 * keys + keys)
    )
    fwd_v1 = (
        (keys + keys * ni + 2 * keys)                      # stats pass
        + (keys + keys * ni + xmat * ni + 2 * keys + xmat)  # apply pass
        + (keys + 3 * keys * nj + keys)                    # colsum pass
        # + m/l round-trip between stats and apply (written then re-read
        # per row block) — the mid-forward HBM traffic the fusion removes
        + 2 * keys * 2
    )
    n2 = 6 * n * n * F32                                   # p/dP/ds, w+r
    bwd_v1 = n2 + 2 * n * d * F32 * (n // min(256, n))     # + x/dy per chunk

    # Banded path: square blk-blocks, band cells only, transposed
    # payload (dsub sublanes x Np lanes).
    blk, npb, dsub = _band_geometry(n, d, 256)
    nib = npb // blk
    off = -(-band // blk)
    cells = nib * (2 * off + 1)           # vs nib^2 dense grid cells
    bkeys = npb * F32
    keyblk = blk * F32
    xtb = blk * dsub * F32                # one payload band block
    xt = npb * dsub * F32                 # whole transposed payload
    fwd_banded = (
        # band sweep: wr once, wc/xt re-read per band cell, y/m/l written
        (bkeys + cells * keyblk + cells * xtb + xt + 2 * bkeys)
        # band colsum: wc once, wr/m/l re-read per band cell, c written
        + (bkeys + 3 * cells * keyblk + bkeys)
    )
    bwd_banded = (
        # delta: dy_t/y_t row-aligned once, wr/m/l once, wc/dc per cell
        (2 * xt + 3 * bkeys + 2 * cells * keyblk + bkeys)
        # dcol: dy_t per cell, xs_t once, wr/m/l/D per cell, wc/dc once,
        # dxs_t/dw_col/dtau written
        + (cells * xtb + xt + 4 * cells * keyblk + 2 * bkeys + xt
           + 2 * bkeys)
        # dws: xs_t per cell, dy_t once, wc/dc per cell, wr/m/l/D once,
        # dws written
        + (cells * xtb + xt + 2 * cells * keyblk + 4 * bkeys + bkeys)
    )

    return {
        "kernel_v1": bsz * (fwd_v1 + bwd_v1) / 1e6,
        "fused": bsz * (fwd_fused + bwd_fused) / 1e6,
        "banded": bsz * (fwd_banded + bwd_banded) / 1e6,
    }


def run_cell(n: int, d: int, bsz: int, band: int, tau: float = 0.5,
             reps: int = 3) -> dict:
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(n + d + bsz), 4)
    # Keys are a shuffled arange — exactly the per-round linear init the
    # trainer uses (w = arange(N) re-shuffled each round), so the bench
    # measures the operating regime: unit rank gaps, no bitwise ties (at
    # a bitwise-equal tie |.| has no derivative and blocked vs dense
    # autodiff legitimately pick different subgradients), and a K-rank
    # key spread of exactly K, which is what makes the banded tier's
    # tail bound (and hence its vs-dense parity gate) meaningful.
    w = jax.vmap(lambda k: jax.random.permutation(
        k, jnp.arange(n, dtype=jnp.float32)))(jax.random.split(k1, bsz))
    x = jax.random.normal(k2, (bsz, n, d))
    a = jax.random.normal(k3, (bsz, n, d))
    b = jax.random.normal(k4, (bsz, n))

    impls = _impls(tau, band)

    def loss_fn(apply_fn):
        def f(w, x):
            y, c = apply_fn(w, x)
            return jnp.sum(y * a) + jnp.sum(c * b)
        return f

    fwd_s, fwdgrad_s, grads, outs = {}, {}, {}, {}
    for name, fn in impls.items():
        fwd_s[name], outs[name] = _time(jax.jit(fn), w, x, reps=reps)
        jg = jax.jit(jax.value_and_grad(loss_fn(fn)))
        fwdgrad_s[name], (_, grads[name]) = _time(jg, w, x, reps=reps)

    y_ref, c_ref = outs["dense"]
    dw_ref = grads["dense"]

    def relerr(got, want):
        # max abs error relative to the oracle's max magnitude — scale-
        # free, so one tolerance gates every N/d/B cell.
        scale = float(jnp.max(jnp.abs(want))) + 1e-9
        return float(jnp.max(jnp.abs(got - want))) / scale

    parity = {}
    for name in ("chunked", "kernel_v1", "fused"):
        parity[f"{name}_y_relerr"] = relerr(outs[name][0], y_ref)
        parity[f"{name}_c_relerr"] = relerr(outs[name][1], c_ref)
        parity[f"{name}_dw_relerr"] = relerr(grads[name], dw_ref)

    # Banded: exact against its windowed jnp oracle, within the analytic
    # tail bound (plus float noise) against the dense oracle.
    ob = jax.jit(lambda w, x: banded_oracle(w, x, tau, band))
    y_ob, c_ob = ob(w, x)
    dw_ob = jax.jit(jax.grad(loss_fn(
        lambda w, x: banded_oracle(w, x, tau, band))))(w, x)
    band_cols = {
        "K": band,
        "tail_bound": float(jnp.max(band_tail_bound(w, tau, band))),
        "vs_oracle_y_relerr": relerr(outs["banded"][0], y_ob),
        "vs_oracle_c_relerr": relerr(outs["banded"][1], c_ob),
        "vs_oracle_dw_relerr": relerr(grads["banded"], dw_ob),
        "vs_dense_y_relerr": relerr(outs["banded"][0], y_ref),
        "vs_dense_c_relerr": relerr(outs["banded"][1], c_ref),
        "vs_dense_dw_relerr": relerr(grads["banded"], dw_ref),
    }

    model = _model_hbm_bytes(n, d, bsz, band)
    return {
        "N": n, "d": d, "B": bsz, "tau": tau,
        "fwd_s": fwd_s,
        "fwdgrad_s": fwdgrad_s,
        "parity": parity,
        "band": band_cols,
        "model_hbm_mb": model,
        "model_fused_over_v1": model["kernel_v1"] / model["fused"],
        "model_banded_over_fused": model["fused"] / model["banded"],
        "passes": {"kernel_v1_fwd": 3, "fused_fwd": 2, "fused_bwd": 3,
                   "banded_fwd": 2, "banded_bwd": 3, "kernel_v1_bwd": 0},
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny multi-block cell (CI)")
    ap.add_argument("--check", action="store_true",
                    help="assert every parity column <= --tol (banded-vs-"
                         "dense <= tol + tail bound) and exit non-zero "
                         "otherwise")
    ap.add_argument("--tol", type=float, default=2e-3,
                    help="parity gate: max abs error vs the dense "
                         "oracle, scaled by the gradient magnitude")
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: BENCH_kernels.json "
                         "for the full sweep, stdout-only for --smoke)")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    rows = []
    for n, d, bsz, band in cells:
        cell = run_cell(n, d, bsz, band, reps=args.reps)
        rows.append(cell)
        print(f"N={n} d={d} B={bsz} K={band}: "
              f"fwd fused {cell['fwd_s']['fused']*1e3:.1f}ms "
              f"banded {cell['fwd_s']['banded']*1e3:.1f}ms, "
              f"model fused/v1 HBM {cell['model_fused_over_v1']:.2f}x, "
              f"banded/fused win {cell['model_banded_over_fused']:.2f}x, "
              f"banded dw err vs oracle "
              f"{cell['band']['vs_oracle_dw_relerr']:.2e} "
              f"(vs dense {cell['band']['vs_dense_dw_relerr']:.2e}, "
              f"bound {cell['band']['tail_bound']:.2e})")

    doc = {
        "bench": "kernel_bench",
        "backend": jax.default_backend(),
        "note": ("off-TPU the Pallas kernels run in interpret mode: "
                 "wall-clock columns are shape signals only (emulation "
                 "overhead penalizes the Pallas backward; the jnp-scan "
                 "baseline gets native XLA fusion); parity columns are "
                 "exact; model_hbm_mb counts per-step HBM<->VMEM bytes "
                 "from the block specs and is the memory-bound TPU "
                 "projection (EXPERIMENTS.md §Roofline); banded "
                 "vs-dense parity is gated against band.tail_bound"),
        "cells": rows,
    }
    out = args.out or (None if args.smoke else "BENCH_kernels.json")
    if out:
        with open(out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {out}")

    if args.check:
        bad = []
        for cell in rows:
            for key, val in cell["parity"].items():
                if not np.isfinite(val) or val > args.tol:
                    bad.append((cell["N"], cell["d"], cell["B"], key, val))
            bound = cell["band"]["tail_bound"]
            for key, val in cell["band"].items():
                if key in ("K", "tail_bound"):
                    continue
                lim = args.tol + (bound if key.startswith("vs_dense") else 0)
                if not np.isfinite(val) or val > lim:
                    bad.append((cell["N"], cell["d"], cell["B"],
                                f"band.{key}", val))
        if bad:
            raise SystemExit(f"parity gate failed (tol={args.tol}): {bad}")
        ncols = sum(len(c["parity"]) + len(c["band"]) - 2 for c in rows)
        print(f"parity gate OK (tol={args.tol}, {ncols} columns)")
    return doc


if __name__ == "__main__":
    main()
