"""Microbenchmarks for the paper's compute hot spot (SoftSort apply) —
one per implementation layer:

  dense ref (O(N^2) memory)  vs  chunked-jnp stream  vs  Pallas kernel
  (interpret mode on CPU — numbers are *relative*, the kernel's real
  target is the TPU MXU; see EXPERIMENTS.md §Roofline for the model).

Also times one ShuffleSoftSort outer round (the trainer's unit of work).
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.softsort import softsort_apply_chunked
from repro.core.shufflesoftsort import ShuffleSoftSortConfig
from repro.kernels.ref import softsort_apply_ref


def _time(fn, *args, reps=3):
    fn(*args)                                   # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6   # us


def bench(ns=(1024, 4096), d=8, tau=0.5):
    rows = []
    for n in ns:
        w = jax.random.normal(jax.random.PRNGKey(0), (n,))
        x = jax.random.normal(jax.random.PRNGKey(1), (n, d))

        ref = jax.jit(lambda w, x: softsort_apply_ref(w, x, tau))
        chunked = jax.jit(
            lambda w, x: softsort_apply_chunked(w, x, tau, chunk=256))
        rows.append((f"softsort_ref_n{n}", _time(ref, w, x),
                     f"dense O(N^2) mem"))
        rows.append((f"softsort_chunked_n{n}", _time(chunked, w, x),
                     f"stream O(N*256) mem"))
    return rows


def bench_outer_round(n=1024, d=3):
    from repro.core.shufflesoftsort import _outer_round
    import functools
    from repro.core.softsort import softsort_apply_chunked as ch
    cfg = ShuffleSoftSortConfig(chunk=256)
    x = jax.random.uniform(jax.random.PRNGKey(0), (n, d))
    order = jnp.arange(n, dtype=jnp.int32)
    apply_fn = functools.partial(ch, chunk=cfg.chunk)

    def step(x, order):
        return _outer_round(x, order, jax.random.PRNGKey(1),
                            jnp.float32(0.5), jnp.float32(1.0),
                            hw=(32, 32), cfg=cfg, apply_fn=apply_fn)

    o, _ = step(x, order)                       # compile
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        o, l = step(x, o)
    jax.block_until_ready(o)
    us = (time.perf_counter() - t0) / reps * 1e6
    return [("shufflesort_round_n1024", us,
             "I=8 grad steps + commit")]


if __name__ == "__main__":
    for name, us, derived in bench() + bench_outer_round():
        print(f"{name},{us:.0f},{derived}")
