"""Throughput sweep: batched multi-problem engine vs the sequential loop.

    PYTHONPATH=src python -m benchmarks.batched_bench [--full]

For each (B, N) cell, times B independent grid-sorting problems solved

  * sequentially — B ``shuffle_soft_sort`` calls (the pre-batching API:
    one Python round-loop per problem, one host sync per round), and
  * batched      — ONE ``shuffle_soft_sort_batched`` call (one vmapped
    device program per round for all B problems).

and reports sorts/sec for both plus the speedup.  Default sweep is
B in {1, 8, 64} at N = 1024 with a short round budget so it finishes on
the CI CPU backend; ``--full`` extends to N = 4096 (the paper-scale
grid) and a longer budget.  Compile time is excluded (one warmup per
shape); per-seed results of the two paths are bit-identical, so this is
a pure scheduling/throughput comparison.  Results fill the table in
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)


def _square_hw(n: int) -> tuple[int, int]:
    h = int(np.sqrt(n))
    assert h * h == n, f"N={n} is not square"
    return (h, h)


def bench_cell(b: int, n: int, d: int, cfg: ShuffleSoftSortConfig,
               warm: bool = True):
    hw = _square_hw(n)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, d))
    keys = jax.random.split(jax.random.PRNGKey(1), b)

    def run_sequential():
        outs = []
        for i in range(b):
            outs.append(shuffle_soft_sort(xs[i], hw, cfg, key=keys[i]))
        return outs

    def run_batched():
        return shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=1,
                                         keys=keys)

    if warm:  # compile both programs outside the timed region
        shuffle_soft_sort(xs[0], hw, cfg, key=keys[0])
        run_batched()

    t0 = time.perf_counter()
    seq = run_sequential()
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    bat = run_batched()
    t_bat = time.perf_counter() - t0

    # Sanity: the two paths must agree per seed (bit-identical orders).
    for i in range(b):
        assert np.array_equal(seq[i][0], bat.all_orders[i, 0]), i

    return {
        "B": b, "N": n,
        "seq_s": t_seq, "bat_s": t_bat,
        "seq_sorts_per_s": b / t_seq,
        "bat_sorts_per_s": b / t_bat,
        "speedup": t_seq / t_bat,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="add N=4096 and a longer round budget")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--bs", type=int, nargs="+", default=None)
    args = ap.parse_args(argv)

    ns = (1024, 4096) if args.full else (1024,)
    bs = tuple(args.bs) if args.bs else (1, 8, 64)
    rounds = args.rounds or (50 if args.full else 10)
    cfg = ShuffleSoftSortConfig(rounds=rounds, inner_steps=4, chunk=256)

    print("name,us_per_call,derived")
    rows = []
    for n in ns:
        for b in bs:
            r = bench_cell(b, n, args.d, cfg)
            rows.append(r)
            print(f"batched_bench.B{b}_N{n},{r['bat_s'] * 1e6 / b:.0f},"
                  f"seq={r['seq_sorts_per_s']:.2f}sorts/s;"
                  f"bat={r['bat_sorts_per_s']:.2f}sorts/s;"
                  f"speedup={r['speedup']:.2f}x")
    return rows


if __name__ == "__main__":
    main()
