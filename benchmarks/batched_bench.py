"""Throughput sweep: batched multi-problem engine vs the sequential loop.

    PYTHONPATH=src python -m benchmarks.batched_bench [--full]

For each (B, N) cell, times B independent grid-sorting problems solved

  * sequentially — B ``shuffle_soft_sort`` calls (the pre-batching API:
    one Python round-loop per problem, one host sync per round), and
  * batched      — ONE ``shuffle_soft_sort_batched`` call (one vmapped
    device program per round for all B problems).

and reports sorts/sec for both plus the speedup.  Default sweep is
B in {1, 8, 64} at N = 1024 with a short round budget so it finishes on
the CI CPU backend; ``--full`` extends to N = 4096 (the paper-scale
grid) and a longer budget.  Compile time is excluded (one warmup per
shape); per-seed results of the two paths are bit-identical, so this is
a pure scheduling/throughput comparison.  Results fill the table in
EXPERIMENTS.md §Perf.

Scaling mode (EXPERIMENTS.md §Scaling):

    PYTHONPATH=src python -m benchmarks.batched_bench --devices 1 2 8

spawns one worker subprocess per requested device count (each with
``XLA_FLAGS=--xla_force_host_platform_device_count=<D>`` so the sweep
runs anywhere), and in each sweeps the devices x B x S grid over three
engines — vmap, mesh-sharded, and the successive-halving restart
tournament — recording wall time, best-restart loss, and the
tournament's executed-rounds fraction.  The aggregate is written to
``BENCH_scaling.json``.  On a forced-host CPU the "devices" are slices
of one physical socket, so treat the timings as shape/overhead signals;
the quality columns (tournament loss vs full loss) are exact.

Adaptive mode (EXPERIMENTS.md §Adaptive):

    PYTHONPATH=src python -m benchmarks.batched_bench --adaptive

compares the fixed schedule against ``schedule="adaptive"`` on a
deliberately over-provisioned anneal (cold ``tau_end``, long budget —
the serving norm) and merges ``"mode": "adaptive"`` rows into
``BENCH_scaling.json`` recording rounds-saved fraction vs final-loss
gap; ``tools/check_bench.py`` gates those rows (>= 20% saved at <= 1%
gap).  The loss columns are backend-exact, like the tournament's.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    restart_tournament,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)


def _square_hw(n: int) -> tuple[int, int]:
    h = int(np.sqrt(n))
    assert h * h == n, f"N={n} is not square"
    return (h, h)


def bench_cell(b: int, n: int, d: int, cfg: ShuffleSoftSortConfig,
               warm: bool = True):
    hw = _square_hw(n)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, d))
    keys = jax.random.split(jax.random.PRNGKey(1), b)

    def run_sequential():
        outs = []
        for i in range(b):
            outs.append(shuffle_soft_sort(xs[i], hw, cfg, key=keys[i]))
        return outs

    def run_batched():
        return shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=1,
                                         keys=keys)

    if warm:  # compile both programs outside the timed region
        shuffle_soft_sort(xs[0], hw, cfg, key=keys[0])
        run_batched()

    t0 = time.perf_counter()
    seq = run_sequential()
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    bat = run_batched()
    t_bat = time.perf_counter() - t0

    # Sanity: the two paths must agree per seed (bit-identical orders).
    for i in range(b):
        assert np.array_equal(seq[i][0], bat.all_orders[i, 0]), i

    return {
        "B": b, "N": n,
        "seq_s": t_seq, "bat_s": t_bat,
        "seq_sorts_per_s": b / t_seq,
        "bat_sorts_per_s": b / t_bat,
        "speedup": t_seq / t_bat,
    }


# --------------------------------------------------------------------------
# Scaling sweep: devices x B x S over vmap / sharded / tournament engines.
# --------------------------------------------------------------------------

def bench_scaling_cell(b: int, s: int, n: int, d: int,
                       cfg: ShuffleSoftSortConfig, n_devices: int,
                       rungs: int, cull: float) -> dict:
    """One devices x B x S cell: time the three engines on identical
    problems/keys and audit the sharded path's bit-identity."""
    from repro.launch.mesh import make_sort_mesh

    hw = _square_hw(n)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, d))
    keys = jax.random.split(jax.random.PRNGKey(1), b * s)
    mesh = make_sort_mesh(n_devices)

    def run_vmap():
        return shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s,
                                         keys=keys)

    def run_shard():
        return shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s,
                                         keys=keys, mesh=mesh)

    def run_tour():
        return restart_tournament(xs, hw, cfg, n_restarts=s, keys=keys,
                                  cull_fraction=cull, n_rungs=rungs,
                                  mesh=mesh)

    ref, shd, _ = run_vmap(), run_shard(), run_tour()    # compile warmup
    assert np.array_equal(ref.all_orders, shd.all_orders), (b, s, n_devices)

    t0 = time.perf_counter()
    ref = run_vmap()
    t_vmap = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_shard()
    t_shard = time.perf_counter() - t0
    t0 = time.perf_counter()
    tour = run_tour()
    t_tour = time.perf_counter() - t0

    full_loss = float(ref.losses[:, -1].mean())
    tour_loss = float(tour.final_loss.mean())
    return {
        "devices": n_devices, "B": b, "S": s, "N": n,
        "rounds": cfg.rounds, "rungs": rungs, "cull_fraction": cull,
        "vmap_s": t_vmap, "shard_s": t_shard, "tournament_s": t_tour,
        "shard_speedup": t_vmap / t_shard,
        "tournament_speedup": t_vmap / t_tour,
        "full_best_loss": full_loss,
        "tournament_best_loss": tour_loss,
        # > 0 when culling dropped the seed that would have won.
        "tournament_loss_gap": tour_loss - full_loss,
        "tournament_rounds_frac": tour.rounds_run / tour.rounds_full,
    }


def run_scaling_worker(args) -> list[dict]:
    """In-process sweep at THIS process's device count."""
    n_dev = len(jax.devices())
    cfg = ShuffleSoftSortConfig(rounds=args.rounds or 8, inner_steps=4,
                                chunk=256)
    rows = []
    for b in (args.bs or (4, 16)):
        for s in (args.restarts or (2, 8)):
            rows.append(bench_scaling_cell(
                b, s, args.n, args.d, cfg, n_dev,
                rungs=args.tournament_rungs, cull=args.cull_fraction))
    return rows


def run_scaling_sweep(args) -> dict:
    """Spawn one worker per device count (forced host devices must be
    set before jax initializes, hence subprocesses), aggregate, and
    write the BENCH_scaling.json artifact."""
    cells = []
    for n_dev in args.devices:
        env = dict(os.environ)
        flags = env.get("XLA_FLAGS", "")
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n_dev}".strip())
        cmd = [sys.executable, "-m", "benchmarks.batched_bench",
               "--scaling-worker", "--n", str(args.n), "--d", str(args.d),
               "--rounds", str(args.rounds or 8),
               "--tournament-rungs", str(args.tournament_rungs),
               "--cull-fraction", str(args.cull_fraction)]
        if args.bs:
            cmd += ["--bs"] + [str(x) for x in args.bs]
        if args.restarts:
            cmd += ["--restarts"] + [str(x) for x in args.restarts]
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             check=True)
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("SCALING_JSON ")][-1]
        rows = json.loads(line[len("SCALING_JSON "):])
        for r in rows:
            assert r["devices"] == n_dev, (r["devices"], n_dev)
        cells.extend(rows)
    record = {
        "bench": "batched_bench --devices",
        "backend": jax.default_backend(),
        "note": ("forced-host devices share one socket: timings are "
                 "overhead/shape signals, loss columns are exact"),
        "cells": cells,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {len(cells)} cells -> {args.out}")
    for r in cells:
        print(f"  dev={r['devices']} B={r['B']} S={r['S']}: "
              f"shard {r['shard_speedup']:.2f}x, tournament "
              f"{r['tournament_speedup']:.2f}x at "
              f"{r['tournament_rounds_frac']:.2f} of the rounds "
              f"(loss gap {r['tournament_loss_gap']:+.4f})")
    return record


def run_cull_sweep(args) -> list[dict]:
    """Tournament quality/compute tradeoff: sweep the cull fraction at
    fixed B x S and compare winner loss against the run-everything
    engine.  Fills the cull-fraction table in EXPERIMENTS.md §Scaling."""
    b = (args.bs or [4])[0]
    s = (args.restarts or [8])[0]
    n = args.n
    cfg = ShuffleSoftSortConfig(rounds=args.rounds or 12, inner_steps=4,
                                chunk=256)
    hw = _square_hw(n)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, args.d))
    keys = jax.random.split(jax.random.PRNGKey(1), b * s)
    full = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
    full_loss = float(full.losses[:, -1].mean())
    rows = []
    print(f"cull sweep: B={b} S={s} N={n} rounds={cfg.rounds} "
          f"rungs={args.tournament_rungs}; full-engine loss {full_loss:.4f}")
    for cull in (0.0, 0.25, 0.5, 0.75):
        res = restart_tournament(xs, hw, cfg, n_restarts=s, keys=keys,
                                 cull_fraction=cull,
                                 n_rungs=args.tournament_rungs)
        row = {
            "cull_fraction": cull,
            "rounds_frac": res.rounds_run / res.rounds_full,
            "final_loss": float(res.final_loss.mean()),
            "loss_gap_vs_full": float(res.final_loss.mean()) - full_loss,
        }
        rows.append(row)
        print(f"  cull={cull:.2f}: rounds_frac={row['rounds_frac']:.3f} "
              f"loss={row['final_loss']:.4f} "
              f"gap={row['loss_gap_vs_full']:+.4f}")
    return rows


def bench_adaptive_cell(b: int, n: int, d: int, rounds: int,
                        args) -> dict:
    """Fixed vs adaptive schedule on one over-provisioned cell.

    The schedule is deliberately conservative (cold ``tau_end``, long
    round budget — the serving norm, where one config covers many
    problem instances), so its tail is flat; the adaptive controller
    converts the measured plateau into skipped rounds.  The row records
    the two gated quantities: the fraction of schedule rounds the
    controller saved and the final-loss gap it cost (both vs the fixed
    engine on identical problems/keys — tools/check_bench.py enforces
    saved >= 20% at a gap <= 1%).
    """
    hw = _square_hw(n)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, d))
    keys = jax.random.split(jax.random.PRNGKey(1), b)
    base = dict(rounds=rounds, inner_steps=4, chunk=min(n, 256),
                tau_end=args.adaptive_tau_end)
    fixed = ShuffleSoftSortConfig(**base)
    adapt = ShuffleSoftSortConfig(
        **base, schedule="adaptive", adapt_every=args.adapt_every,
        patience=args.patience, plateau_rtol=args.plateau_rtol,
        decay_rungs=args.decay_rungs)

    rf = shuffle_soft_sort_batched(xs, hw, fixed, keys=keys)  # warmup
    ra = shuffle_soft_sort_batched(xs, hw, adapt, keys=keys)

    t0 = time.perf_counter()
    rf = shuffle_soft_sort_batched(xs, hw, fixed, keys=keys)
    t_fixed = time.perf_counter() - t0
    t0 = time.perf_counter()
    ra = shuffle_soft_sort_batched(xs, hw, adapt, keys=keys)
    t_adapt = time.perf_counter() - t0

    executed = ra.rounds_executed[:, 0]
    fixed_loss = float(rf.losses[:, -1].mean())
    adapt_loss = float(ra.losses[np.arange(b), executed - 1].mean())
    return {
        "mode": "adaptive",
        "devices": 1, "B": b, "N": n, "rounds": rounds,
        "tau_end": args.adaptive_tau_end,
        "adapt_every": args.adapt_every, "patience": args.patience,
        "plateau_rtol": args.plateau_rtol,
        "decay_rungs": args.decay_rungs,
        "fixed_s": t_fixed, "adaptive_s": t_adapt,
        "fixed_final_loss": fixed_loss,
        "adaptive_final_loss": adapt_loss,
        "mean_rounds_executed": float(executed.mean()),
        "rounds_saved_frac": float(1.0 - executed.sum() / (b * rounds)),
        "final_loss_gap_pct": (adapt_loss - fixed_loss) / fixed_loss * 100,
    }


def run_adaptive_sweep(args) -> dict:
    """Fixed-vs-adaptive rows, merged into the BENCH_scaling.json
    artifact alongside the devices x B x S cells (adaptive rows carry
    ``"mode": "adaptive"`` and replace any previous adaptive rows;
    EXPERIMENTS.md §Adaptive is built from exactly these columns)."""
    rounds = args.rounds or 80
    rows = [bench_adaptive_cell(b, n, args.d, rounds, args)
            for n in (args.adaptive_ns or (64, 256))
            for b in (args.bs or (4,))]

    cells, envelope = [], {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            prior = json.load(f)
        envelope = {k: v for k, v in prior.items() if k != "cells"}
        cells = [c for c in prior.get("cells", [])
                 if c.get("mode") != "adaptive"]
    envelope.setdefault("bench", "batched_bench --devices")
    envelope["backend"] = jax.default_backend()
    cells.extend(rows)
    record = dict(envelope, cells=cells)
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {len(rows)} adaptive cells -> {args.out} "
          f"({len(cells)} total)")
    for r in rows:
        print(f"  B={r['B']} N={r['N']} R={r['rounds']}: saved "
              f"{r['rounds_saved_frac']:.1%} of rounds at "
              f"{r['final_loss_gap_pct']:+.2f}% final-loss gap "
              f"({r['mean_rounds_executed']:.1f}/{r['rounds']} rounds, "
              f"{r['fixed_s']:.2f}s -> {r['adaptive_s']:.2f}s)")
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="add N=4096 and a longer round budget")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--bs", type=int, nargs="+", default=None)
    ap.add_argument("--devices", type=int, nargs="+", default=None,
                    help="run the scaling sweep at these device counts "
                         "(one forced-host-device subprocess each) and "
                         "write BENCH_scaling.json")
    ap.add_argument("--restarts", type=int, nargs="+", default=None,
                    help="S values for the scaling sweep")
    ap.add_argument("--n", type=int, default=256,
                    help="N for the scaling sweep")
    ap.add_argument("--tournament-rungs", type=int, default=3)
    ap.add_argument("--cull-fraction", type=float, default=0.5)
    ap.add_argument("--out", default="BENCH_scaling.json")
    ap.add_argument("--cull-sweep", action="store_true",
                    help="sweep tournament cull fractions at fixed B x S "
                         "and report the quality/compute tradeoff")
    ap.add_argument("--adaptive", action="store_true",
                    help="fixed-vs-adaptive schedule rows (rounds saved "
                         "vs final-loss gap), merged into --out")
    ap.add_argument("--adaptive-ns", type=int, nargs="+", default=None,
                    help="N values for the adaptive sweep")
    ap.add_argument("--adaptive-tau-end", type=float, default=0.02,
                    help="conservative (cold) schedule end for the "
                         "adaptive sweep — the over-provisioned regime")
    ap.add_argument("--adapt-every", type=int, default=5)
    ap.add_argument("--patience", type=int, default=1)
    ap.add_argument("--plateau-rtol", type=float, default=0.02)
    ap.add_argument("--decay-rungs", type=int, default=2)
    ap.add_argument("--scaling-worker", action="store_true",
                    help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.scaling_worker:
        rows = run_scaling_worker(args)
        print("SCALING_JSON " + json.dumps(rows))
        return rows
    if args.cull_sweep:
        return run_cull_sweep(args)
    if args.adaptive:
        return run_adaptive_sweep(args)
    if args.devices:
        return run_scaling_sweep(args)

    ns = (1024, 4096) if args.full else (1024,)
    bs = tuple(args.bs) if args.bs else (1, 8, 64)
    rounds = args.rounds or (50 if args.full else 10)
    cfg = ShuffleSoftSortConfig(rounds=rounds, inner_steps=4, chunk=256)

    print("name,us_per_call,derived")
    rows = []
    for n in ns:
        for b in bs:
            r = bench_cell(b, n, args.d, cfg)
            rows.append(r)
            print(f"batched_bench.B{b}_N{n},{r['bat_s'] * 1e6 / b:.0f},"
                  f"seq={r['seq_sorts_per_s']:.2f}sorts/s;"
                  f"bat={r['bat_sorts_per_s']:.2f}sorts/s;"
                  f"speedup={r['speedup']:.2f}x")
    return rows


if __name__ == "__main__":
    main()
