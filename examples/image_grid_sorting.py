"""Grid-based image sorting (paper §IV-A): arrange synthetic 'product
image' feature vectors (50-dim, clustered — the paper uses 50-dim
low-level visual features) on a grid so similar items are neighbours.

    PYTHONPATH=src python examples/image_grid_sorting.py
"""
import sys

import numpy as np

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import ShuffleSoftSortConfig, shuffle_soft_sort  # noqa: E402
from repro.core.metrics import dpq, mean_neighbor_distance  # noqa: E402


def synthetic_catalog(n=1024, d=50, clusters=24, seed=0):
    """Clustered features mimicking product categories."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(clusters, d) * 2.0
    labels = rng.randint(0, clusters, n)
    x = centers[labels] + 0.4 * rng.randn(n, d)
    return x.astype(np.float32), labels


def neighbor_label_agreement(labels, order, hw):
    """Fraction of horizontal/vertical neighbour pairs with equal category
    — a user-facing proxy for 'similar products are adjacent'."""
    h, w = hw
    g = labels[order].reshape(h, w)
    agree = (g[:, 1:] == g[:, :-1]).sum() + (g[1:, :] == g[:-1, :]).sum()
    total = h * (w - 1) + (h - 1) * w
    return agree / total


def main():
    n, hw = 1024, (32, 32)
    x, labels = synthetic_catalog(n)

    base_order = np.arange(n)
    print(f"random layout : dpq={dpq(x, hw):.3f} "
          f"nbr={mean_neighbor_distance(x, hw):.3f} "
          f"label-agree={neighbor_label_agreement(labels, base_order, hw):.3f}")

    cfg = ShuffleSoftSortConfig(rounds=500, inner_steps=8)
    order, xs, _ = shuffle_soft_sort(jnp.asarray(x), hw, cfg,
                                     key=jax.random.PRNGKey(3))
    print(f"sorted layout : dpq={dpq(xs, hw):.3f} "
          f"nbr={mean_neighbor_distance(xs, hw):.3f} "
          f"label-agree={neighbor_label_agreement(labels, order, hw):.3f}")


if __name__ == "__main__":
    main()
