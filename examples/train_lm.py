"""End-to-end LM training driver example (deliverable (b)): trains a
~10M-param decoder LM for a few hundred steps on CPU through the full
production stack — synthetic sharded data pipeline, Adam, checkpointing,
failure injection + recovery, straggler monitor.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 30
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    stats = main()
    assert stats["last_loss"] < stats["first_loss"], "loss must decrease"
    print("OK: loss decreased through failure-recovery training")
