"""Self-Organizing Gaussians (paper §IV-B): sort 3D-Gaussian-Splatting
attributes into 2-D grids to raise spatial correlation, then compress the
attribute planes with a standard codec (zlib as the stand-in).

The original SOG uses a heuristic non-differentiable sort because N is in
the millions; ShuffleSoftSort makes the sort *learnable* with only N
stored parameters (the permutation), which is the paper's headline
application.

    PYTHONPATH=src python examples/self_organizing_gaussians.py [--n 4096]
"""
import argparse
import sys
import zlib

import numpy as np

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import ShuffleSoftSortConfig, shuffle_soft_sort  # noqa: E402
from repro.core.metrics import mean_neighbor_distance  # noqa: E402


def synthetic_scene(n, seed=0, noise=0.01):
    """Synthetic splat set with realistic attribute structure: all
    attributes are smooth functions of the surface parameterization (real
    3DGS scenes are spatially coherent — nearby splats share scale,
    orientation and color), plus a small jitter."""
    rng = np.random.RandomState(seed)
    t = rng.rand(n, 2) * 2 * np.pi
    pos = np.stack([np.cos(t[:, 0]), np.sin(t[:, 0]) * np.cos(t[:, 1]),
                    np.sin(t[:, 1])], -1)
    scale = 0.2 + 0.1 * np.abs(np.sin(3 * t))                # (n, 2) -> 3
    scale = np.concatenate([scale, scale[:, :1]], -1)
    rot = np.stack([np.cos(t[:, 0] / 2), np.sin(t[:, 0] / 2),
                    np.cos(t[:, 1] / 2), np.sin(t[:, 1] / 2)], -1)
    opacity = (0.5 + 0.5 * np.cos(t[:, :1]))
    color = 0.5 + 0.5 * np.stack(
        [np.cos(t[:, 0]), np.sin(t[:, 1]), np.cos(t.sum(1))], -1)
    attrs = np.concatenate([pos, scale, rot, opacity, color], -1)
    attrs += noise * rng.randn(*attrs.shape)
    return attrs.astype(np.float32)                          # (n, 14)


def plane_bytes(attrs, order, hw):
    """Compress each attribute as an (h, w) int8 plane (per-plane scale),
    zlib-deflated — the codec proxy for the paper's image codecs."""
    h, w = hw
    total = 0
    for j in range(attrs.shape[1]):
        plane = attrs[order, j].reshape(h, w)
        scale = np.max(np.abs(plane)) / 127.0 + 1e-12
        q = np.clip(np.round(plane / scale), -127, 127).astype(np.int8)
        # 2-D delta (horizontal) mimics intra-frame prediction
        delta = np.diff(q.astype(np.int16), axis=1,
                        prepend=np.zeros((h, 1), np.int16)).astype(np.int8)
        total += len(zlib.compress(delta.tobytes(), 6))
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--rounds", type=int, default=800)
    args = ap.parse_args()
    n = args.n
    hw = (int(np.sqrt(n)), int(np.sqrt(n)))
    assert hw[0] * hw[1] == n

    attrs = synthetic_scene(n)
    raw = attrs.nbytes

    rng = np.random.RandomState(1)
    rand_order = rng.permutation(n)
    unsorted_bytes = plane_bytes(attrs, rand_order, hw)

    cfg = ShuffleSoftSortConfig(rounds=args.rounds, inner_steps=8,
                                chunk=min(512, n))
    order, xs, _ = shuffle_soft_sort(jnp.asarray(attrs), hw, cfg,
                                     key=jax.random.PRNGKey(5))
    # NOTE: splat order is ambiguous in 3DGS (the paper's key observation)
    # so the permutation is NOT stored — the sorted layout *is* the file.
    sorted_bytes = plane_bytes(attrs, order, hw)

    print(f"splats: {n}  attrs/splat: {attrs.shape[1]}  raw: {raw:,} B")
    print(f"codec (random order) : {unsorted_bytes:,} B "
          f"({raw / unsorted_bytes:.1f}x vs raw)")
    print(f"codec (SOG sorted)   : {sorted_bytes:,} B "
          f"({raw / sorted_bytes:.1f}x vs raw, "
          f"{unsorted_bytes / sorted_bytes:.2f}x vs unsorted)")
    print(f"neighbour distance   : "
          f"{mean_neighbor_distance(attrs[rand_order], hw):.3f} -> "
          f"{mean_neighbor_distance(attrs[order], hw):.3f}")
    print("(gains grow with N — the paper's regime is N~1e6 on 1024^2 "
          "grids with image codecs; this CPU demo uses zlib at N=1024)")
    assert sorted_bytes < unsorted_bytes, "sorting must help the codec"


if __name__ == "__main__":
    main()
