"""Quickstart: the paper's flagship demo — sort 1024 random RGB colors
onto a 32x32 grid with ShuffleSoftSort (N parameters only).

    PYTHONPATH=src python examples/quickstart.py [--rounds 600] [--n 1024]

Writes before/after PPM images and prints DPQ_16 + mean neighbour
distance (paper Fig. 1 / Table III).
"""
import argparse
import sys

import numpy as np

import jax

sys.path.insert(0, "src")

from repro.core import ShuffleSoftSortConfig, shuffle_soft_sort  # noqa: E402
from repro.core.metrics import dpq, mean_neighbor_distance  # noqa: E402


def save_ppm(path, grid_colors, hw, cell=8):
    h, w = hw
    img = (np.asarray(grid_colors).reshape(h, w, 3) * 255).astype(np.uint8)
    img = np.repeat(np.repeat(img, cell, 0), cell, 1)
    with open(path, "wb") as f:
        f.write(f"P6 {img.shape[1]} {img.shape[0]} 255\n".encode())
        f.write(img.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--rounds", type=int, default=600)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route through the Pallas kernel (interpret mode "
                         "on CPU: slow but bit-validated)")
    args = ap.parse_args()

    hw = (int(np.sqrt(args.n)), int(np.sqrt(args.n)))
    assert hw[0] * hw[1] == args.n, "n must be a perfect square"
    x = jax.random.uniform(jax.random.PRNGKey(42), (args.n, 3))

    print(f"random   : dpq={dpq(np.asarray(x), hw):.3f} "
          f"nbr={mean_neighbor_distance(np.asarray(x), hw):.3f}")
    save_ppm("colors_before.ppm", np.asarray(x), hw)

    cfg = ShuffleSoftSortConfig(rounds=args.rounds, inner_steps=8,
                                use_kernel=args.use_kernel)
    order, xs, losses = shuffle_soft_sort(x, hw, cfg,
                                          key=jax.random.PRNGKey(1))
    print(f"sorted   : dpq={dpq(xs, hw):.3f} "
          f"nbr={mean_neighbor_distance(xs, hw):.3f} "
          f"(loss {losses[0]:.3f} -> {losses[-1]:.3f})")
    save_ppm("colors_after.ppm", xs, hw)
    print("wrote colors_before.ppm / colors_after.ppm")
    assert sorted(order.tolist()) == list(range(args.n)), "invalid perm!"


if __name__ == "__main__":
    main()
