"""Multi-image grid sorting in one device call (batched engine demo).

A production gallery service rarely sorts ONE image set — it sorts many
concurrently (one per user upload).  Because ShuffleSoftSort needs only
N parameters per instance, the batched engine holds B catalogs x S
random restarts on-device simultaneously and trains them with one
vmapped program, then keeps each catalog's best-loss restart:

    PYTHONPATH=src python examples/batched_image_grids.py
"""
import sys
import time

import numpy as np

import jax

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    ShuffleSoftSortConfig,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.core.metrics import dpq  # noqa: E402


def synthetic_catalog(n, d=50, clusters=12, seed=0):
    """Clustered features mimicking one user's product-image catalog."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(clusters, d) * 2.0
    labels = rng.randint(0, clusters, n)
    x = centers[labels] + 0.4 * rng.randn(n, d)
    return x.astype(np.float32)


def main():
    n_images, n, hw = 6, 256, (16, 16)
    restarts = 2
    xs = np.stack([synthetic_catalog(n, seed=i) for i in range(n_images)])
    cfg = ShuffleSoftSortConfig(rounds=120, inner_steps=8, chunk=256)

    t0 = time.time()
    res = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=restarts,
                                    key=jax.random.PRNGKey(0))
    wall = time.time() - t0
    print(f"sorted {n_images} catalogs x {restarts} restarts "
          f"({n_images * restarts} instances of N={n}) in {wall:.1f}s "
          f"-> {n_images / wall:.2f} catalogs/s")
    for b in range(n_images):
        print(f"  catalog {b}: dpq {dpq(xs[b], hw):.3f} -> "
              f"{dpq(res.sorted[b], hw):.3f}  "
              f"(best restart {res.best_restart[b]}, "
              f"final losses {np.round(res.all_losses[b, :, -1], 4)})")

    # Reference point: one catalog through the sequential API.
    t0 = time.time()
    shuffle_soft_sort(xs[0], hw, cfg, key=jax.random.PRNGKey(0))
    print(f"(sequential API: {time.time() - t0:.1f}s per catalog-restart)")


if __name__ == "__main__":
    main()
