#!/usr/bin/env python
"""Docs link checker: internal markdown links/anchors must resolve.

    python tools/check_doc_links.py README.md EXPERIMENTS.md ...

Checks, for each given markdown file:
  * relative links `[..](path)` point at files/dirs that exist;
  * `[..](path#fragment)` / `[..](#fragment)` fragments name a real
    heading in the target markdown file (GitHub slugification);

and, repo-wide (every .py file under the project trees):
  * every section-sign token — the convention code docstrings use to
    cite the experiments log, with or without an explicit
    `EXPERIMENTS.md` prefix — names a real section heading in
    EXPERIMENTS.md, so a heading rename or deletion fails CI instead of
    silently stranding the docstrings that cite it.  Roman-numeral
    tokens (paper sections like `paper §IV-A`) are exempt.

External (http/https/mailto) links are not fetched.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]*)(#[^)\s]*)?\)")
SECTION_RE = re.compile(r"§[\w-]+")
# §IV-A / §II etc. cite the source paper, not EXPERIMENTS.md.
PAPER_SECTION_RE = re.compile(r"§[IVXLC]+(?:-[A-Z\d]+)?$")
# Markdown files only flag explicitly prefixed citations — prose there
# legitimately mentions other documents' section signs.
MD_SECTION_RE = re.compile(r"EXPERIMENTS(?:\.md)?\s+(§[\w-]+)")


def github_slug(heading: str) -> str:
    """GitHub's markdown heading -> anchor id (ASCII approximation)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def md_anchors(path: pathlib.Path) -> set[str]:
    anchors = set()
    for line in path.read_text().splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            anchors.add(github_slug(m.group(1)))
    return anchors


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text()
    for m in LINK_RE.finditer(text):
        target, frag = m.group(1), m.group(2)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve() if target else path
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
            continue
        if frag and resolved.suffix == ".md":
            anchor = frag.lstrip("#")
            if anchor not in md_anchors(resolved):
                errors.append(
                    f"{path}: dangling anchor -> {target or path.name}{frag}")
    return errors


def check_section_refs(repo: pathlib.Path) -> list[str]:
    """Every section citation in project Python files must have a
    heading in EXPERIMENTS.md."""
    exp = repo / "EXPERIMENTS.md"
    if not exp.exists():
        return [f"{exp} is missing but referenced by docstrings"]
    headings = set(re.findall(r"^##\s+(§[\w-]+)", exp.read_text(), re.M))
    this_file = pathlib.Path(__file__).resolve()
    errors = []
    for tree in ("src", "benchmarks", "examples", "tests", "tools"):
        for src in sorted((repo / tree).rglob("*.py")):
            if src.resolve() == this_file:
                continue          # the checker's own docstring
            for ref in sorted(set(SECTION_RE.findall(src.read_text()))):
                if ref in headings or PAPER_SECTION_RE.match(ref):
                    continue
                errors.append(f"{src}: dangling section reference {ref} "
                              "(no such EXPERIMENTS.md heading)")
    for src in sorted(repo.glob("*.md")):
        for ref in sorted(set(MD_SECTION_RE.findall(src.read_text()))):
            if ref not in headings:
                errors.append(f"{src}: dangling section reference {ref} "
                              "(no such EXPERIMENTS.md heading)")
    return errors


def main() -> int:
    repo = pathlib.Path(__file__).resolve().parent.parent
    errors = []
    for name in sys.argv[1:]:
        p = pathlib.Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p))
    errors.extend(check_section_refs(repo))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(sys.argv) - 1} files; {len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
