#!/usr/bin/env python
"""Docs link checker: internal markdown links/anchors must resolve.

    python tools/check_doc_links.py README.md EXPERIMENTS.md ...

Checks, for each given markdown file:
  * relative links `[..](path)` point at files/dirs that exist;
  * `§Section` references into EXPERIMENTS.md (the convention used by
    code docstrings) name a real `## §Section` heading.

External (http/https/mailto) links are not fetched.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{path}: broken link -> {target}")
    return errors


def check_section_refs(repo: pathlib.Path) -> list[str]:
    """Every section mention of the experiments log must have a heading."""
    exp = repo / "EXPERIMENTS.md"
    if not exp.exists():
        return [f"{exp} is missing but referenced by docstrings"]
    headings = set(re.findall(r"^##\s+(§\S+)", exp.read_text(), re.M))
    errors = []
    for src in list(repo.rglob("*.py")) + list(repo.glob("*.md")):
        if ".git" in src.parts:
            continue
        for ref in re.findall(r"EXPERIMENTS\.md\s+(§[\w-]+)", src.read_text()):
            if ref not in headings:
                errors.append(f"{src}: dangling reference EXPERIMENTS.md {ref}")
    return errors


def main() -> int:
    repo = pathlib.Path(__file__).resolve().parent.parent
    errors = []
    for name in sys.argv[1:]:
        p = pathlib.Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p))
    errors.extend(check_section_refs(repo))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"checked {len(sys.argv) - 1} files; {len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
