#!/usr/bin/env python3
"""Schema validator for the committed ``BENCH_*.json`` benchmark records.

Benchmarks are committed artifacts that docs tables are built from, so
CI gates their shape: every record must carry the common envelope
(``bench`` name, ``backend``, a non-empty ``cells`` list of objects)
and every numeric leaf anywhere in the document must be finite — a NaN
or Infinity in a committed benchmark means a sweep silently diverged.

Bench-specific checks:

  * ``kernel_bench``  — every cell needs the measured/parity/model
    columns, and every ``parity`` entry must be within ``--tol`` of the
    dense oracle (relative error; the columns are backend-independent,
    so a committed file that fails this was generated from broken
    kernels, whatever machine produced it).  Banded cells additionally
    carry a ``band`` record (width K, analytic ``tail_bound``, parity
    vs the windowed jnp oracle and vs the dense oracle); the
    vs-oracle columns must be exact to ``--tol`` and the vs-dense
    columns within ``tail_bound + --tol`` — the bound is precisely the
    error the truncation is licensed to introduce.
  * ``batched_bench --devices`` (BENCH_scaling.json) — cells need the
    sweep axes and timing columns.

Usage (CI runs exactly this, see .github/workflows/ci.yml):

    python tools/check_bench.py                 # validates all BENCH_*.json
    python tools/check_bench.py BENCH_kernels.json --tol 2e-3

Exit code 0 = every file valid.  No third-party deps — runs anywhere.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import sys

ENVELOPE_KEYS = ("bench", "backend", "cells")

KERNEL_CELL_KEYS = ("N", "d", "B", "fwd_s", "fwdgrad_s", "parity", "band",
                    "model_hbm_mb", "model_fused_over_v1",
                    "model_banded_over_fused", "passes")
KERNEL_IMPLS = ("dense", "chunked", "kernel_v1", "fused", "banded")
# Banded records: band width + its analytic dropped-mass bound + parity
# against both the windowed jnp oracle (must be exact to --tol) and the
# dense oracle (must be within tail_bound + --tol — the bound is what
# licenses the truncation).
BAND_KEYS = ("K", "tail_bound", "vs_oracle_y_relerr", "vs_oracle_c_relerr",
             "vs_oracle_dw_relerr", "vs_dense_y_relerr",
             "vs_dense_c_relerr", "vs_dense_dw_relerr")

SCALING_CELL_KEYS = ("devices", "B", "S", "N", "vmap_s", "shard_s",
                     "tournament_s", "tournament_loss_gap")


def _walk_numbers(obj, path=""):
    """Yield (path, value) for every numeric leaf."""
    if isinstance(obj, bool):
        return
    if isinstance(obj, (int, float)):
        yield path, float(obj)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk_numbers(v, f"{path}.{k}" if path else str(k))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _walk_numbers(v, f"{path}[{i}]")


def check_file(path: str, tol: float) -> list[str]:
    errors: list[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object"]
    for key in ENVELOPE_KEYS:
        if key not in doc:
            errors.append(f"{path}: missing required key '{key}'")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errors.append(f"{path}: 'cells' must be a non-empty list")
        cells = []
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            errors.append(f"{path}: cells[{i}] is not an object")

    for p, v in _walk_numbers(doc):
        if not math.isfinite(v):
            errors.append(f"{path}: non-finite number at {p}: {v}")

    bench = doc.get("bench", "")
    if bench == "kernel_bench":
        for i, cell in enumerate(cells):
            if not isinstance(cell, dict):
                continue
            for key in KERNEL_CELL_KEYS:
                if key not in cell:
                    errors.append(
                        f"{path}: cells[{i}] missing '{key}'")
            for col in ("fwd_s", "fwdgrad_s"):
                for impl in KERNEL_IMPLS:
                    if impl not in cell.get(col, {}):
                        errors.append(
                            f"{path}: cells[{i}].{col} missing '{impl}'")
            for name, val in cell.get("parity", {}).items():
                if not isinstance(val, (int, float)) or val > tol:
                    errors.append(
                        f"{path}: cells[{i}].parity.{name} = {val} "
                        f"exceeds tol {tol}")
            band = cell.get("band", {})
            if not isinstance(band, dict):
                errors.append(f"{path}: cells[{i}].band is not an object")
                band = {}
            for key in BAND_KEYS:
                if key not in band:
                    errors.append(f"{path}: cells[{i}].band missing '{key}'")
            k_val = band.get("K")
            if not isinstance(k_val, int) or k_val < 1:
                errors.append(
                    f"{path}: cells[{i}].band.K = {k_val!r} must be a "
                    "positive int")
            bound = band.get("tail_bound")
            if not isinstance(bound, (int, float)) or bound < 0:
                errors.append(
                    f"{path}: cells[{i}].band.tail_bound = {bound!r} "
                    "must be a non-negative number")
                bound = 0.0
            for name, val in band.items():
                if name in ("K", "tail_bound"):
                    continue
                lim = tol + (bound if name.startswith("vs_dense") else 0.0)
                if not isinstance(val, (int, float)) or val > lim:
                    errors.append(
                        f"{path}: cells[{i}].band.{name} = {val} exceeds "
                        f"{'tail bound + ' if name.startswith('vs_dense') else ''}"
                        f"tol {lim}")
    elif bench.startswith("batched_bench"):
        for i, cell in enumerate(cells):
            if not isinstance(cell, dict):
                continue
            for key in SCALING_CELL_KEYS:
                if key not in cell:
                    errors.append(f"{path}: cells[{i}] missing '{key}'")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json files (default: glob the cwd)")
    ap.add_argument("--tol", type=float, default=2e-3,
                    help="max allowed parity error for kernel_bench")
    args = ap.parse_args(argv)

    files = args.files or sorted(glob.glob("BENCH_*.json"))
    if not files:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1

    all_errors: list[str] = []
    for path in files:
        errs = check_file(path, args.tol)
        status = "FAIL" if errs else "ok"
        print(f"check_bench: {path}: {status}")
        all_errors.extend(errs)
    for e in all_errors:
        print(f"  {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
