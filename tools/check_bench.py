#!/usr/bin/env python3
"""Schema validator for the committed benchmark / autotune JSON records.

Benchmarks are committed artifacts that docs tables are built from, so
CI gates their shape: every record must carry the common envelope
(``bench`` name, ``backend``, a non-empty ``cells`` list of objects)
and every numeric leaf anywhere in the document must be finite — a NaN
or Infinity in a committed benchmark means a sweep silently diverged.

Bench-specific checks:

  * ``kernel_bench``  — every cell needs the measured/parity/model
    columns, a ``dtype`` axis value, and a ``wall_clock`` label
    ("measured" only on a TPU backend, "emulated" anywhere else — the
    off-TPU interpret-mode numbers invert real orderings, see
    EXPERIMENTS.md §Perf, so a committed file may never pass them off
    as measured).  Parity entries must be within the dtype's tolerance
    of the dense f32 oracle — ``--tol`` for float32 cells, the looser
    DOCUMENTED ``--tol-bf16`` for bfloat16 cells (the columns are
    backend-independent, so a committed file that fails this was
    generated from broken kernels, whatever machine produced it).
    float32 cells must carry all five impls; bfloat16 cells carry the
    kernel impls only (the jnp tiers are the f32 reference).  Banded
    cells additionally carry a ``band`` record (width K, analytic
    ``tail_bound``, parity vs the windowed jnp oracle and vs the dense
    oracle); the vs-oracle columns must be within the dtype tolerance
    and the vs-dense columns within ``tail_bound + tol`` — the bound
    is precisely the error the truncation is licensed to introduce.
    Every dtype cell must emit the modeled-HBM column (uniform gate),
    and the recorded backward pass counts must say 2 (the PR-5 merged
    backward).
  * ``autotune``      — the committed block-size table
    (``src/repro/kernels/autotune_table.json``): every cell needs the
    (tier, N, d, K, dtype, backend) key fields plus ``winner`` and the
    per-candidate timings, the winner must be IN the recorded candidate
    grid for its tier, and the winner's own timing must be present.
  * ``batched_bench --devices`` (BENCH_scaling.json) — cells need the
    sweep axes and timing columns.  Adaptive-annealing rows
    (``"mode": "adaptive"``, from ``batched_bench --adaptive``) are
    gated on the paper-claims acceptance bar instead: the controller
    must save >= 20% of schedule rounds at a final-loss gap <= 1% vs
    the fixed engine on identical problems/keys (loss columns are
    backend-exact, so the bar holds on any machine).
  * ``serving_bench`` (BENCH_serving.json) — cells need the per-scenario
    load axes and the tail-latency/robustness columns, the same
    ``wall_clock`` measured-only-on-TPU labeling rule as kernel cells,
    rates in [0, 1] with p50 <= p99, and the exactly-once accounting
    identity ``completed + failed + deadline_missed + queue_rejected ==
    requests`` — a committed serving row that leaks or double-counts a
    request is a scheduler bug, not a measurement.  Fault-scenario rows
    (``injected_faults > 0``) must additionally show the recovery
    machinery engaging: ``retries + failed >= 1``.  Warm-restart rows
    (``"scenario": "preempt"``) are gated on the cross-generation
    ledger: at least one request was actually in flight at the kill
    (``preempted_inflight >= 1``), every one of them was adopted by the
    successor (``resumed_requests == preempted_inflight``), and the two
    generations' completions partition the total
    (``completed_gen1 + completed_gen2 == completed``).

  * ``guardrail_bench`` (BENCH_guardrails.json) — the chaos grid must
    show every injected value corruption detected (with a named firing
    probe), repaired, and bit-identical to a clean run
    (``detection_rate == 1.0`` — the booleans are backend-exact, so a
    committed cell that fails was a real guardrail escape), and the
    overhead cells must include exactly one default-rate cell, gated
    at <= 5% probe overhead on full runs (smoke runs are schema-checked
    only: wall-clock thresholds are machine-dependent, the committed
    full-run artifact carries the gate).

Usage (CI runs exactly this, see .github/workflows/ci.yml):

    python tools/check_bench.py                 # BENCH_*.json + autotune
    python tools/check_bench.py BENCH_kernels.json --tol 2e-3

Exit code 0 = every file valid.  No third-party deps — runs anywhere.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

ENVELOPE_KEYS = ("bench", "backend", "cells")

KERNEL_CELL_KEYS = ("N", "d", "B", "K", "dtype", "wall_clock", "fwd_s",
                    "fwdgrad_s", "parity", "band", "model_hbm_mb",
                    "model_blocks", "model_banded_over_fused", "passes")
KERNEL_IMPLS_F32 = ("dense", "chunked", "kernel_v1", "fused", "banded")
KERNEL_IMPLS_BF16 = ("fused", "banded")
# Banded records: band width + its analytic dropped-mass bound + parity
# against both the windowed jnp oracle (within the dtype tolerance) and
# the dense oracle (within tail_bound + tolerance — the bound is what
# licenses the truncation).
BAND_KEYS = ("K", "tail_bound", "vs_oracle_y_relerr", "vs_oracle_c_relerr",
             "vs_oracle_dw_relerr", "vs_dense_y_relerr",
             "vs_dense_c_relerr", "vs_dense_dw_relerr")
# The PR-5 merged backward: any committed record claiming more passes
# was generated from stale kernels.
EXPECTED_PASSES = {"fused_fwd": 2, "fused_bwd": 2,
                   "banded_fwd": 2, "banded_bwd": 2}

SCALING_CELL_KEYS = ("devices", "B", "S", "N", "vmap_s", "shard_s",
                     "tournament_s", "tournament_loss_gap")

# Adaptive-annealing rows in BENCH_scaling.json (``"mode": "adaptive"``,
# written by ``batched_bench --adaptive``): the fixed-vs-adaptive
# comparison columns plus the two gated quantities — the controller
# must save at least 20% of the schedule rounds at a final-loss gap of
# at most 1% (the paper-claims acceptance bar; EXPERIMENTS.md
# §Adaptive).  A committed row below the bar means the controller
# regressed, not that the sweep was unlucky: the cells run on fixed
# problems and keys.
ADAPTIVE_CELL_KEYS = ("mode", "B", "N", "rounds", "adapt_every",
                      "patience", "plateau_rtol", "decay_rungs",
                      "fixed_s", "adaptive_s", "fixed_final_loss",
                      "adaptive_final_loss", "mean_rounds_executed",
                      "rounds_saved_frac", "final_loss_gap_pct")
ADAPTIVE_MIN_SAVED_FRAC = 0.2
ADAPTIVE_MAX_LOSS_GAP_PCT = 1.0

SERVING_CELL_KEYS = ("scenario", "requests", "arrival_rate_hz",
                     "wall_clock", "wall_s", "completed", "failed",
                     "deadline_missed", "queue_rejected", "goodput_rps",
                     "p50_ms", "p99_ms", "deadline_miss_rate", "retries",
                     "recoveries", "stragglers", "batches", "mean_batch",
                     "injected_faults", "injected_delays")

# Warm-restart ("preempt") serving rows: the kill-and-resume ledger a
# committed row must balance across the two server generations.
SERVING_PREEMPT_KEYS = ("preempted_inflight", "resumed_requests",
                        "completed_gen1", "completed_gen2")

# Elastic-capacity ("capacity") serving rows: the device-loss chaos
# ledger.  A committed row must show the full loop — devices evicted,
# exactly one re-shard per eviction, zero lost futures — and, on full
# runs, that the brownout ladder kept the in-budget p50 within 2x the
# steady row's p50 (wall-clock gates are skipped on smoke runs, same
# policy as the guardrail overhead gate).  The row is REQUIRED in
# non-smoke serving records: regenerating BENCH_serving.json on a
# host with < 8 devices silently drops the scenario, and this gate
# turns that silence into a CI failure naming the XLA_FLAGS fix.
SERVING_CAPACITY_KEYS = ("devices_start", "device_faults", "evictions",
                         "reshards", "device_returns",
                         "degraded_requests", "degradations",
                         "lost_futures")
SERVING_DEGRADATION_RUNGS = ("culled", "adaptive", "banded", "bf16")
SERVING_CAPACITY_MAX_P50_RATIO = 2.0

AUTOTUNE_CELL_KEYS = ("tier", "N", "d", "K", "dtype", "backend", "winner",
                      "winner_s", "candidate_s")

# Guardrail chaos/overhead records (BENCH_guardrails.json, from
# benchmarks/guardrail_bench.py).  Detection cells are the committed
# proof that every injected value-corruption mode is caught, repaired,
# and leaves the repaired run bit-identical to a clean one — booleans,
# exact on any backend, so the gate is unconditional.  Overhead cells
# carry the guarded-vs-unguarded timing axis; the DEFAULT-rate cell is
# gated at <= 5% probe overhead, but only on full runs ("smoke": false)
# — wall-clock thresholds on a shared CI box are noise, so CI checks
# the committed full-run artifact and only schema-checks its own smoke
# output.
GUARDRAIL_DETECTION_KEYS = ("kind", "path", "corruption", "target",
                            "dispatch_index", "injected", "detected",
                            "probe", "repaired", "bit_identical",
                            "violations", "self_heals", "wall_s")
GUARDRAIL_OVERHEAD_KEYS = ("kind", "mode", "shadow_rate", "default", "B",
                           "N", "rounds", "inner_steps", "rungs",
                           "rungs_shadowed", "reps", "unguarded_s",
                           "guarded_s", "overhead_pct")
GUARDRAIL_MAX_DEFAULT_OVERHEAD_PCT = 5.0

# The committed autotune table lives with the package so dispatch can
# find it from any cwd; validate it alongside the BENCH_*.json glob.
# Anchored to this script's location so running check_bench from any
# cwd still validates it (the BENCH_*.json glob stays cwd-based — those
# are cwd artifacts by convention).
AUTOTUNE_TABLE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro", "kernels", "autotune_table.json")


def _walk_numbers(obj, path=""):
    """Yield (path, value) for every numeric leaf."""
    if isinstance(obj, bool):
        return
    if isinstance(obj, (int, float)):
        yield path, float(obj)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            yield from _walk_numbers(v, f"{path}.{k}" if path else str(k))
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            yield from _walk_numbers(v, f"{path}[{i}]")


def _check_kernel_cells(path, cells, tol, tol_bf16, errors):
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            continue
        for key in KERNEL_CELL_KEYS:
            if key not in cell:
                errors.append(f"{path}: cells[{i}] missing '{key}'")
        dtype = cell.get("dtype", "float32")
        cell_tol = tol_bf16 if dtype == "bfloat16" else tol
        impls = (KERNEL_IMPLS_BF16 if dtype == "bfloat16"
                 else KERNEL_IMPLS_F32)
        if cell.get("wall_clock") not in ("measured", "emulated"):
            errors.append(
                f"{path}: cells[{i}].wall_clock = "
                f"{cell.get('wall_clock')!r} must be measured|emulated")
        for col in ("fwd_s", "fwdgrad_s"):
            for impl in impls:
                if impl not in cell.get(col, {}):
                    errors.append(
                        f"{path}: cells[{i}].{col} missing '{impl}'")
        model = cell.get("model_hbm_mb", {})
        # f32 cells must also model the v1 baseline (the docs' fused-
        # over-v1 tables are built from exactly these columns).
        model_impls = (("fused", "banded", "kernel_v1")
                       if dtype == "float32" else ("fused", "banded"))
        for impl in model_impls:
            if impl not in model:
                errors.append(
                    f"{path}: cells[{i}].model_hbm_mb missing '{impl}' "
                    f"(the modeled-HBM column must exist for every "
                    f"dtype cell)")
        ratio_key = ("model_fused_over_v1" if dtype == "float32"
                     else "model_f32_over_this")
        if ratio_key not in cell:
            errors.append(f"{path}: cells[{i}] missing '{ratio_key}'")
        for name, val in cell.get("parity", {}).items():
            if not isinstance(val, (int, float)) or val > cell_tol:
                errors.append(
                    f"{path}: cells[{i}].parity.{name} = {val} "
                    f"exceeds {dtype} tol {cell_tol}")
        band = cell.get("band", {})
        if not isinstance(band, dict):
            errors.append(f"{path}: cells[{i}].band is not an object")
            band = {}
        for key in BAND_KEYS:
            if key not in band:
                errors.append(f"{path}: cells[{i}].band missing '{key}'")
        k_val = band.get("K")
        if not isinstance(k_val, int) or k_val < 1:
            errors.append(
                f"{path}: cells[{i}].band.K = {k_val!r} must be a "
                "positive int")
        bound = band.get("tail_bound")
        if not isinstance(bound, (int, float)) or bound < 0:
            errors.append(
                f"{path}: cells[{i}].band.tail_bound = {bound!r} "
                "must be a non-negative number")
            bound = 0.0
        for name, val in band.items():
            if name in ("K", "tail_bound"):
                continue
            lim = cell_tol + (bound if name.startswith("vs_dense") else 0.0)
            if not isinstance(val, (int, float)) or val > lim:
                errors.append(
                    f"{path}: cells[{i}].band.{name} = {val} exceeds "
                    f"{'tail bound + ' if name.startswith('vs_dense') else ''}"
                    f"{dtype} tol {lim}")
        passes = cell.get("passes", {})
        for name, want in EXPECTED_PASSES.items():
            got = passes.get(name)
            if got != want:
                errors.append(
                    f"{path}: cells[{i}].passes.{name} = {got!r}, "
                    f"expected {want} (3->2 merged backward)")


def _check_serving_cells(path, doc, cells, errors):
    backend = doc.get("backend")
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            continue
        for key in SERVING_CELL_KEYS:
            if key not in cell:
                errors.append(f"{path}: cells[{i}] missing '{key}'")
        wc = cell.get("wall_clock")
        if wc not in ("measured", "emulated"):
            errors.append(
                f"{path}: cells[{i}].wall_clock = {wc!r} must be "
                "measured|emulated")
        elif wc == "measured" and backend != "tpu":
            errors.append(
                f"{path}: cells[{i}].wall_clock = 'measured' on a "
                f"{backend!r} backend — off-TPU serving latencies must "
                "be labeled 'emulated'")
        counts = {k: cell.get(k) for k in
                  ("requests", "completed", "failed", "deadline_missed",
                   "queue_rejected")}
        if all(isinstance(v, int) and v >= 0 for v in counts.values()):
            total = sum(v for k, v in counts.items() if k != "requests")
            if total != counts["requests"]:
                errors.append(
                    f"{path}: cells[{i}] breaks exactly-once accounting: "
                    f"completed+failed+deadline_missed+queue_rejected = "
                    f"{total} != requests = {counts['requests']}")
        else:
            errors.append(
                f"{path}: cells[{i}] outcome counters must be "
                f"non-negative ints, got {counts}")
        rate = cell.get("deadline_miss_rate")
        if not isinstance(rate, (int, float)) or not 0.0 <= rate <= 1.0:
            errors.append(
                f"{path}: cells[{i}].deadline_miss_rate = {rate!r} "
                "must be in [0, 1]")
        p50, p99 = cell.get("p50_ms"), cell.get("p99_ms")
        if (isinstance(p50, (int, float)) and isinstance(p99, (int, float))
                and (p50 < 0 or p99 < p50)):
            errors.append(
                f"{path}: cells[{i}] latency order violated: "
                f"0 <= p50 ({p50}) <= p99 ({p99})")
        if (cell.get("injected_faults", 0) > 0
                and cell.get("retries", 0) + cell.get("failed", 0) < 1):
            errors.append(
                f"{path}: cells[{i}] injected faults but neither retried "
                "nor failed — the recovery path never engaged")
        if cell.get("scenario") == "preempt":
            pre = {k: cell.get(k) for k in SERVING_PREEMPT_KEYS}
            if not all(isinstance(v, int) and v >= 0
                       for v in pre.values()):
                errors.append(
                    f"{path}: cells[{i}] preempt columns must be "
                    f"non-negative ints, got {pre}")
                continue
            if pre["preempted_inflight"] < 1:
                errors.append(
                    f"{path}: cells[{i}] preempt row with no in-flight "
                    "requests at the kill — the scenario never "
                    "exercised the warm restart")
            if pre["resumed_requests"] != pre["preempted_inflight"]:
                errors.append(
                    f"{path}: cells[{i}] leaked preempted requests: "
                    f"resumed_requests = {pre['resumed_requests']} != "
                    f"preempted_inflight = {pre['preempted_inflight']}")
            if (isinstance(cell.get("completed"), int)
                    and pre["completed_gen1"] + pre["completed_gen2"]
                    != cell["completed"]):
                errors.append(
                    f"{path}: cells[{i}] generation completions do not "
                    f"partition the total: {pre['completed_gen1']} + "
                    f"{pre['completed_gen2']} != {cell['completed']}")
        if cell.get("scenario") == "capacity":
            _check_capacity_cell(path, doc, cells, i, cell, errors)
    smoke = bool(doc.get("smoke", False))
    if not smoke and not any(
            isinstance(c, dict) and c.get("scenario") == "capacity"
            for c in cells):
        errors.append(
            f"{path}: full serving record has no 'capacity' cell — "
            "regenerate with XLA_FLAGS="
            "--xla_force_host_platform_device_count=8 so the elastic "
            "capacity-loss scenario runs")


def _check_capacity_cell(path, doc, cells, i, cell, errors):
    """Elastic-capacity chaos ledger for one 'capacity' serving row."""
    cap = {k: cell.get(k) for k in SERVING_CAPACITY_KEYS}
    deg = cap.pop("degradations")
    if not all(isinstance(v, int) and v >= 0 for v in cap.values()):
        errors.append(
            f"{path}: cells[{i}] capacity columns must be non-negative "
            f"ints, got {cap}")
        return
    if not (isinstance(deg, dict)
            and sorted(deg) == sorted(SERVING_DEGRADATION_RUNGS)
            and all(isinstance(v, int) and v >= 0
                    for v in deg.values())):
        errors.append(
            f"{path}: cells[{i}].degradations must map exactly "
            f"{SERVING_DEGRADATION_RUNGS} to non-negative ints, "
            f"got {deg!r}")
    if cap["lost_futures"] != 0:
        errors.append(
            f"{path}: cells[{i}] lost {cap['lost_futures']} futures — "
            "every offered request must resolve exactly once")
    if cap["reshards"] != cap["evictions"]:
        errors.append(
            f"{path}: cells[{i}] re-shard ledger broken: reshards = "
            f"{cap['reshards']} != evictions = {cap['evictions']} "
            "(every eviction re-shards exactly once)")
    if cap["evictions"] < 1:
        errors.append(
            f"{path}: cells[{i}] capacity row with no evictions — the "
            "device-loss chaos never engaged")
    if cap["device_returns"] > cap["evictions"]:
        errors.append(
            f"{path}: cells[{i}] more device returns "
            f"({cap['device_returns']}) than evictions "
            f"({cap['evictions']})")
    if not bool(doc.get("smoke", False)):
        steady = next(
            (c for c in cells if isinstance(c, dict)
             and c.get("scenario") == "steady"), None)
        p50, s50 = cell.get("p50_ms"), (steady or {}).get("p50_ms")
        if (isinstance(p50, (int, float)) and isinstance(s50, (int, float))
                and s50 > 0
                and p50 > SERVING_CAPACITY_MAX_P50_RATIO * s50):
            errors.append(
                f"{path}: cells[{i}] brownout failed its budget: "
                f"capacity p50 {p50:.1f}ms > "
                f"{SERVING_CAPACITY_MAX_P50_RATIO}x steady p50 "
                f"{s50:.1f}ms")
        if cap["degraded_requests"] < 1:
            errors.append(
                f"{path}: cells[{i}] full capacity row degraded no "
                "requests — the brownout ladder never engaged")


def _check_autotune_cells(path, doc, cells, errors):
    candidates = doc.get("candidates")
    if not isinstance(candidates, dict):
        errors.append(f"{path}: autotune table missing 'candidates'")
        candidates = {}
    # Normalize candidate grids to tuples for membership checks.
    grids = {tier: [tuple(c) if isinstance(c, list) else (c,)
                    for c in cands]
             for tier, cands in candidates.items()
             if isinstance(cands, list)}
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            continue
        for key in AUTOTUNE_CELL_KEYS:
            if key not in cell:
                errors.append(f"{path}: cells[{i}] missing '{key}'")
        tier = cell.get("tier")
        winner = cell.get("winner")
        if not isinstance(winner, list) or not winner:
            errors.append(
                f"{path}: cells[{i}].winner = {winner!r} must be a "
                "non-empty list")
            continue
        win = tuple(winner)
        grid = grids.get(tier)
        if grid is None:
            errors.append(
                f"{path}: cells[{i}].tier = {tier!r} has no candidate "
                "grid")
        elif win not in grid:
            errors.append(
                f"{path}: cells[{i}] winner {winner} absent from the "
                f"'{tier}' candidate grid {sorted(grid)}")
        cand_s = cell.get("candidate_s", {})
        label = "x".join(str(v) for v in winner)
        if label not in cand_s:
            errors.append(
                f"{path}: cells[{i}].candidate_s missing the winner's "
                f"own timing '{label}'")


def _check_guardrail_cells(path, doc, cells, errors):
    backend = doc.get("backend")
    if doc.get("wall_clock") == "measured" and backend != "tpu":
        errors.append(
            f"{path}: wall_clock = 'measured' on a {backend!r} backend "
            "— off-TPU guardrail timings must be labeled 'emulated'")
    smoke = bool(doc.get("smoke", False))
    det = [c for c in cells if isinstance(c, dict)
           and c.get("kind") == "detection"]
    over = [c for c in cells if isinstance(c, dict)
            and c.get("kind") == "overhead"]
    if not det:
        errors.append(f"{path}: no detection cells")
    if not over:
        errors.append(f"{path}: no overhead cells")
    caught = 0
    for i, cell in enumerate(det):
        for key in GUARDRAIL_DETECTION_KEYS:
            if key not in cell:
                errors.append(f"{path}: detection cells[{i}] missing "
                              f"'{key}'")
        if cell.get("injected", 0) < 1:
            errors.append(
                f"{path}: detection cells[{i}] "
                f"({cell.get('path')}/{cell.get('corruption')}) never "
                "injected its corruption — the grid cell measured "
                "nothing")
        good = (cell.get("detected") is True
                and cell.get("repaired") is True
                and cell.get("bit_identical") is True)
        caught += good
        if not good:
            errors.append(
                f"{path}: detection cells[{i}] "
                f"({cell.get('path')}/{cell.get('corruption')}) failed "
                f"the chaos gate: detected={cell.get('detected')} "
                f"repaired={cell.get('repaired')} "
                f"bit_identical={cell.get('bit_identical')} — an "
                "injected corruption slipped a committed guardrail")
        if cell.get("detected") and not cell.get("probe"):
            errors.append(
                f"{path}: detection cells[{i}] detected a corruption "
                "but recorded no firing probe")
    rate = doc.get("detection_rate")
    if det and rate != 1.0:
        errors.append(
            f"{path}: detection_rate = {rate!r} must be exactly 1.0")
    elif det and caught != len(det):
        errors.append(
            f"{path}: detection_rate says 1.0 but only {caught}/"
            f"{len(det)} cells pass the chaos gate")
    defaults = []
    for i, cell in enumerate(over):
        for key in GUARDRAIL_OVERHEAD_KEYS:
            if key not in cell:
                errors.append(f"{path}: overhead cells[{i}] missing "
                              f"'{key}'")
        r = cell.get("shadow_rate")
        if not isinstance(r, (int, float)) or not 0.0 <= r <= 1.0:
            errors.append(
                f"{path}: overhead cells[{i}].shadow_rate = {r!r} must "
                "be in [0, 1]")
        if cell.get("default") is True:
            defaults.append(cell)
    if over and len(defaults) != 1:
        errors.append(
            f"{path}: exactly one overhead cell must be flagged "
            f"'default': true, found {len(defaults)}")
    for cell in defaults:
        pct = cell.get("overhead_pct")
        if not isinstance(pct, (int, float)):
            errors.append(
                f"{path}: default overhead cell has non-numeric "
                f"overhead_pct = {pct!r}")
        elif not smoke and pct > GUARDRAIL_MAX_DEFAULT_OVERHEAD_PCT:
            errors.append(
                f"{path}: default-rate probe overhead {pct:.2f}% "
                f"exceeds the {GUARDRAIL_MAX_DEFAULT_OVERHEAD_PCT}% "
                "budget (EXPERIMENTS.md §Robustness) — the always-on "
                "guardrail rate must stay in the noise")


def check_file(path: str, tol: float, tol_bf16: float) -> list[str]:
    errors: list[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]

    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object"]
    for key in ENVELOPE_KEYS:
        if key not in doc:
            errors.append(f"{path}: missing required key '{key}'")
    cells = doc.get("cells")
    if not isinstance(cells, list) or not cells:
        errors.append(f"{path}: 'cells' must be a non-empty list")
        cells = []
    for i, cell in enumerate(cells):
        if not isinstance(cell, dict):
            errors.append(f"{path}: cells[{i}] is not an object")

    for p, v in _walk_numbers(doc):
        if not math.isfinite(v):
            errors.append(f"{path}: non-finite number at {p}: {v}")

    bench = doc.get("bench", "")
    if bench == "kernel_bench":
        _check_kernel_cells(path, cells, tol, tol_bf16, errors)
    elif bench == "autotune":
        _check_autotune_cells(path, doc, cells, errors)
    elif bench == "serving_bench":
        _check_serving_cells(path, doc, cells, errors)
    elif bench == "guardrail_bench":
        _check_guardrail_cells(path, doc, cells, errors)
    elif bench.startswith("batched_bench"):
        for i, cell in enumerate(cells):
            if not isinstance(cell, dict):
                continue
            if cell.get("mode") == "adaptive":
                _check_adaptive_cell(path, i, cell, errors)
                continue
            for key in SCALING_CELL_KEYS:
                if key not in cell:
                    errors.append(f"{path}: cells[{i}] missing '{key}'")
    return errors


def _check_adaptive_cell(path, i, cell, errors):
    for key in ADAPTIVE_CELL_KEYS:
        if key not in cell:
            errors.append(f"{path}: cells[{i}] missing '{key}'")
    saved = cell.get("rounds_saved_frac")
    if not isinstance(saved, (int, float)) or not 0.0 <= saved < 1.0:
        errors.append(
            f"{path}: cells[{i}].rounds_saved_frac = {saved!r} must be "
            "in [0, 1)")
    elif saved < ADAPTIVE_MIN_SAVED_FRAC:
        errors.append(
            f"{path}: cells[{i}].rounds_saved_frac = {saved:.3f} below "
            f"the {ADAPTIVE_MIN_SAVED_FRAC:.0%} adaptive acceptance bar")
    gap = cell.get("final_loss_gap_pct")
    if not isinstance(gap, (int, float)):
        errors.append(
            f"{path}: cells[{i}].final_loss_gap_pct = {gap!r} must be "
            "a number")
    elif gap > ADAPTIVE_MAX_LOSS_GAP_PCT:
        errors.append(
            f"{path}: cells[{i}].final_loss_gap_pct = {gap:+.3f} exceeds "
            f"the {ADAPTIVE_MAX_LOSS_GAP_PCT}% adaptive acceptance bar")
    executed = cell.get("mean_rounds_executed")
    rounds = cell.get("rounds")
    if (isinstance(executed, (int, float)) and isinstance(rounds, int)
            and isinstance(saved, (int, float))
            and not 0 < executed <= rounds):
        errors.append(
            f"{path}: cells[{i}].mean_rounds_executed = {executed} "
            f"outside (0, rounds={rounds}]")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*",
                    help="BENCH_*.json / autotune table files (default: "
                         "glob the cwd + the committed autotune table)")
    ap.add_argument("--tol", type=float, default=2e-3,
                    help="max allowed f32 parity error for kernel_bench")
    ap.add_argument("--tol-bf16", type=float, default=2e-2,
                    help="max allowed bfloat16 parity error — the "
                         "documented bf16 envelope (EXPERIMENTS.md "
                         "§Perf)")
    args = ap.parse_args(argv)

    # The committed autotune table is ALWAYS in the default list — if it
    # has gone missing, check_file reports it unreadable and CI fails,
    # rather than the gate silently self-disabling.
    files = args.files or (sorted(glob.glob("BENCH_*.json"))
                           + [AUTOTUNE_TABLE])
    if not files:
        print("check_bench: no BENCH_*.json files found", file=sys.stderr)
        return 1

    all_errors: list[str] = []
    for path in files:
        errs = check_file(path, args.tol, args.tol_bf16)
        status = "FAIL" if errs else "ok"
        print(f"check_bench: {path}: {status}")
        all_errors.extend(errs)
    for e in all_errors:
        print(f"  {e}", file=sys.stderr)
    return 1 if all_errors else 0


if __name__ == "__main__":
    sys.exit(main())
