"""Flash attention (GQA-aware) Pallas TPU kernel.

The §Roofline tables show every *_train / prefill cell memory-bound on
the (B, H, S, S) score tensors the jnp attention materializes; on TPU
the fix is exactly this kernel: stream (Br, Bc) score tiles through VMEM
with running max/denominator statistics so HBM traffic is O(S·Dh)
instead of O(S²).

Same two-pass structure as ``softsort_apply`` (it *is* the same
algorithm with a dot-product score instead of an L1 distance):

  pass 1  _stats_kernel : running row-max m and denominator l
  pass 2  _apply_kernel : exact P tile = exp(s−m)/l, fused (Br,Bc)@(Bc,Dh)

Grid planes iterate (batch*q_heads); GQA maps q-head -> kv-head by
integer division inside the index maps, so repeated K/V are never
materialized (matches the jnp path after the §Perf GQA-einsum fix).

Block shapes are (8k, 128m)-aligned for the MXU; VMEM working set
~ Br*Bc + 2*Bc*Dh + Br*Dh floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _mask(i, j, br, bc, q_len, kv_len, causal, q_offset):
    rows = i * br + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    cols = j * bc + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    ok = (rows < q_len) & (cols < kv_len)
    if causal:
        ok &= cols <= (rows + q_offset)
    return ok


def _stats_kernel(q_ref, k_ref, m_ref, l_ref, *, scale, br, bc,
                  q_len, kv_len, causal, q_offset):
    i, j = pl.program_id(1), pl.program_id(2)
    s = jnp.dot(q_ref[0], k_ref[0].T,
                preferred_element_type=jnp.float32) * scale   # (Br, Bc)
    s = jnp.where(_mask(i, j, br, bc, q_len, kv_len, causal, q_offset),
                  s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True)[None])
    l_ref[...] = (l_ref[...] * jnp.exp(m_prev - m_new)
                  + jnp.sum(jnp.exp(s[None] - m_new), -1, keepdims=True))
    m_ref[...] = m_new


def _apply_kernel(q_ref, k_ref, v_ref, m_ref, l_ref, o_ref, *, scale, br,
                  bc, q_len, kv_len, causal, q_offset):
    i, j = pl.program_id(1), pl.program_id(2)
    s = jnp.dot(q_ref[0], k_ref[0].T,
                preferred_element_type=jnp.float32) * scale
    s = jnp.where(_mask(i, j, br, bc, q_len, kv_len, causal, q_offset),
                  s, NEG_INF)
    p = jnp.exp(s - m_ref[0]) / jnp.maximum(l_ref[0], 1e-30)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(p, v_ref[0].astype(jnp.float32),
                          preferred_element_type=jnp.float32
                          )[None].astype(o_ref.dtype)


def flash_attention_fwd_pallas(
    q: jnp.ndarray,    # (BH, Tq_pad, Dh)  — batch*q_heads planes
    k: jnp.ndarray,    # (BHkv, Tk_pad, Dh)
    v: jnp.ndarray,    # (BHkv, Tk_pad, Dh)
    *,
    rep: int,          # q heads per kv head
    scale: float,
    q_len: int,
    kv_len: int,
    causal: bool,
    q_offset: int,     # absolute position of q row 0 (decode: pos)
    br: int,
    bc: int,
    interpret: bool,
):
    bh, tq, dh = q.shape
    tk = k.shape[1]
    ni, nj = tq // br, tk // bc
    f32 = jnp.float32
    kw = dict(scale=scale, br=br, bc=bc, q_len=q_len, kv_len=kv_len,
              causal=causal, q_offset=q_offset)

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, **kw),
        grid=(bh, ni, nj),
        in_specs=[
            pl.BlockSpec((1, br, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bc, dh), lambda h, i, j, rep=rep:
                         (h // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br, 1), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, br, 1), lambda h, i, j: (h, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bh, tq, 1), f32),
                   jax.ShapeDtypeStruct((bh, tq, 1), f32)],
        interpret=interpret,
    )(q, k)

    out = pl.pallas_call(
        functools.partial(_apply_kernel, **kw),
        grid=(bh, ni, nj),
        in_specs=[
            pl.BlockSpec((1, br, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bc, dh), lambda h, i, j, rep=rep:
                         (h // rep, j, 0)),
            pl.BlockSpec((1, bc, dh), lambda h, i, j, rep=rep:
                         (h // rep, j, 0)),
            pl.BlockSpec((1, br, 1), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, br, 1), lambda h, i, j: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, tq, dh), f32),
        interpret=interpret,
    )(q, k, v, m, l)
    return out
