"""Per-shape block-size autotuning for the SoftSort-apply kernel tiers.

The fused dense kernels are tiled by (Br, Bc) and the banded kernels by
one square block edge; the right tiling depends on (N, d, K, dtype,
backend) — lane-padded payload blocks want narrow Bc at large d, band
grids want blk commensurate with K, and bf16 halves every block's bytes
which moves the VMEM sweet spot.  Rather than freeze 256 everywhere,
this module:

  * ``search`` / ``search_cells`` — times every candidate tiling on the
    kernel-bench harness (fwd+grad of the real custom_vjp path, shuffled
    -arange keys — the trainer's operating regime) and records the
    winner per (tier, N, d, K, dtype, backend);
  * persists winners to a committed JSON table
    (``src/repro/kernels/autotune_table.json``, envelope ``bench:
    "autotune"`` — schema-checked by ``tools/check_bench.py``, which
    also rejects winners that are not in the recorded candidate grid);
  * ``lookup_blocks`` — consulted by ``repro.kernels.ops`` at dispatch
    time whenever the caller leaves the block sizes unset.  A lookup
    miss (unknown shape, un-tuned backend, missing/corrupt table) falls
    back to the safe hardcoded 256-square tiling — the pre-autotune
    default, valid for every shape — so dispatch NEVER searches and
    NEVER fails; the table only ever upgrades it.

Block choice is pure performance: every candidate computes the identical
math (asserted by the parity suites for the 256 default and by
``--check`` here for each searched winner), so consulting the table
cannot perturb the engines' bit-identity contracts — within one fixed
(dtype, block) choice results are bitwise reproducible, and the table
pins exactly that choice per shape.

Wall-clock caveat: on a CPU CI backend the kernels run in interpret
mode, so the committed winners for ``backend: "cpu"`` rank *emulation*
cost, not MXU cost (EXPERIMENTS.md §Autotune).  The table keys include
the backend precisely so a TPU run re-tunes into its own rows:

    PYTHONPATH=src python -m repro.kernels.autotune            # full
    PYTHONPATH=src python -m repro.kernels.autotune --smoke --check
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp

TABLE_PATH = os.path.join(os.path.dirname(__file__), "autotune_table.json")

# The pre-autotune defaults: valid for every shape (the geometry helpers
# clamp oversized blocks), so they are the safe lookup-miss fallback.
FALLBACK = {"fused": (256, 256), "banded": (256, 256)}

# Candidate tilings.  Dense: (Br, Bc) pairs — Br is sublane-quantized,
# Bc lane-quantized.  Banded: square block edges (the band offset
# arithmetic wants one edge length).  Kept deliberately small: each
# candidate is a full recompile of fwd+grad.
CANDIDATES = {
    "fused": [(128, 128), (128, 256), (256, 128), (256, 256), (512, 256)],
    "banded": [128, 256, 512],
}
SMOKE_CANDIDATES = {
    "fused": [(128, 128), (256, 256)],
    "banded": [128, 256],
}

# (tier, N, d, K) cells of the full search — the bench sweep's shapes.
# K = 0 means the dense tier (no band).
FULL_CELLS = [
    ("fused", 1024, 8, 0),
    ("fused", 1024, 50, 0),
    ("banded", 1024, 8, 128),
    ("banded", 1024, 50, 128),
    ("banded", 2048, 8, 128),
    ("banded", 4096, 8, 256),
]
SMOKE_CELLS = [
    ("fused", 256, 8, 0),
    ("banded", 384, 8, 64),
]

DTYPES = ("float32", "bfloat16")


def _cell_key(tier: str, n: int, d: int, k: int, dtype: str,
              backend: str) -> tuple:
    return (tier, int(n), int(d), int(k or 0), str(dtype), str(backend))


def _cand_label(cand) -> str:
    return "x".join(str(v) for v in cand) if isinstance(cand, (list, tuple)) \
        else str(cand)


def _effective(tier: str, cand, n: int):
    """Collapse candidates that the geometry helpers would clamp to the
    same tiling at this N, so the search never times duplicates."""
    from repro.kernels.ops import _band_geometry, _block_geometry
    if tier == "fused":
        br, bc, _, _ = _block_geometry(n, 1, cand[0], cand[1])
        return (br, bc)
    blk, _, _ = _band_geometry(n, 1, cand)
    return (blk,)


@functools.lru_cache(maxsize=8)
def _load_table(path: str):
    """Parse the table once per path; None when absent or unreadable
    (the fallback then applies — dispatch must never fail)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if not isinstance(doc, dict) or doc.get("bench") != "autotune":
        return None
    rows = {}
    for cell in doc.get("cells", ()):
        try:
            key = _cell_key(cell["tier"], cell["N"], cell["d"], cell["K"],
                            cell["dtype"], cell["backend"])
            rows[key] = tuple(int(v) for v in (
                cell["winner"] if isinstance(cell["winner"], list)
                else [cell["winner"]]))
        except (KeyError, TypeError, ValueError):
            continue
    return rows


def lookup_blocks(tier: str, n: int, d: int, k: int | None = None,
                  dtype: str = "float32",
                  path: str = TABLE_PATH) -> tuple[int, int]:
    """Autotuned (block_rows, block_cols) for the fused tier or
    (blk, blk) for the banded tier; hardcoded fallback on any miss.

    Pure host-side reading of a static table — called at trace time on
    static shapes, never searches, never raises.
    """
    assert tier in FALLBACK, tier
    rows = _load_table(path)
    if rows:
        key = _cell_key(tier, n, d, k or 0, dtype, jax.default_backend())
        win = rows.get(key)
        if win:
            return (win[0], win[1]) if len(win) > 1 else (win[0], win[0])
    return FALLBACK[tier]


# --------------------------------------------------------------------------
# Search: the kernel-bench timing harness over the candidate grid.
# --------------------------------------------------------------------------

def _make_operands(n: int, d: int, bsz: int = 1):
    """Shuffled-arange keys + normal payload — the trainer's per-round
    linear-init regime, same as benchmarks/kernel_bench.py."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d))
    w = jax.vmap(lambda k: jax.random.permutation(
        k, jnp.arange(n, dtype=jnp.float32)))(jax.random.split(k1, bsz))
    x = jax.random.normal(k2, (bsz, n, d))
    return w, x


def _time_apply(apply_fn, w, x, reps: int) -> float:
    """Mean fwd+grad seconds — the step the trainer actually pays."""
    def loss(w, x):
        y, c = apply_fn(w, x)
        return jnp.sum(y) + jnp.sum(c)

    f = jax.jit(jax.grad(loss, argnums=(0, 1)))
    jax.block_until_ready(f(w, x))                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(w, x)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / max(reps, 1)


def search_cell(tier: str, n: int, d: int, k: int, dtype: str,
                candidates, reps: int = 2, tau: float = 0.5) -> dict:
    """Time every (deduplicated) candidate tiling for one cell; returns
    the table row with the winner and the full per-candidate timings."""
    from repro.kernels.ops import softsort_apply, softsort_apply_banded
    w, x = _make_operands(n, d)
    timings: dict[str, float] = {}
    seen_geom: dict[tuple, str] = {}
    best, best_s = None, float("inf")
    for cand in candidates:
        geom = _effective(tier, cand, n)
        if geom in seen_geom:       # clamps to an already-timed tiling
            timings[_cand_label(cand)] = timings[seen_geom[geom]]
            continue
        if tier == "fused":
            def apply_fn(w, x, cand=cand):
                return softsort_apply(w, x, tau, block_rows=cand[0],
                                      block_cols=cand[1],
                                      compute_dtype=dtype)
        else:
            def apply_fn(w, x, cand=cand):
                return softsort_apply_banded(w, x, tau, band=k,
                                             block=cand,
                                             compute_dtype=dtype)
        label = _cand_label(cand)
        seen_geom[geom] = label
        secs = _time_apply(apply_fn, w, x, reps)
        timings[label] = secs
        if secs < best_s:
            best, best_s = cand, secs
    winner = list(best) if isinstance(best, (list, tuple)) else [best]
    return {
        "tier": tier, "N": n, "d": d, "K": int(k or 0), "dtype": dtype,
        "backend": jax.default_backend(),
        "winner": winner,
        "winner_s": best_s,
        "candidate_s": timings,
    }


def search_cells(cells, candidates_by_tier, reps: int = 2,
                 verbose: bool = True) -> list[dict]:
    rows = []
    for tier, n, d, k in cells:
        for dtype in DTYPES:
            row = search_cell(tier, n, d, k, dtype,
                              candidates_by_tier[tier], reps=reps)
            rows.append(row)
            if verbose:
                print(f"autotune {tier} N={n} d={d} K={k} {dtype}: "
                      f"winner {_cand_label(row['winner'])} "
                      f"({row['winner_s'] * 1e3:.1f} ms)")
    return rows


def write_table(rows, candidates_by_tier, path: str) -> dict:
    """Merge ``rows`` into the table at ``path`` and rewrite it.

    MERGE, not replace: rows keep their (tier, N, d, K, dtype, backend)
    identity, so re-tuning on one backend updates that backend's rows
    and leaves every other backend's committed rows intact (the whole
    point of keying rows by backend — a TPU re-tune must not delete the
    cpu CI rows, nor vice versa).  A cell searched again simply
    replaces its previous row.  Candidate grids merge per tier the same
    way (new grid wins for its tier)."""
    existing = _load_table(path)
    if existing:
        with open(path) as f:
            old_doc = json.load(f)
        merged = {  # key -> row, old rows first so new ones replace them
            _cell_key(c["tier"], c["N"], c["d"], c["K"], c["dtype"],
                      c["backend"]): c
            for c in old_doc.get("cells", ()) if isinstance(c, dict)}
        for row in rows:
            merged[_cell_key(row["tier"], row["N"], row["d"], row["K"],
                             row["dtype"], row["backend"])] = row
        rows = [merged[k] for k in sorted(merged)]
        # Candidate grids UNION per tier: a narrow (e.g. smoke) re-tune
        # must not shrink the grid out from under previously committed
        # winners (check_bench requires every winner to be in the grid).
        union: dict[str, list] = {}
        old_cands = old_doc.get("candidates", {})
        for source in (old_cands, candidates_by_tier):
            for t, cands in source.items():
                if not isinstance(cands, (list, tuple)):
                    continue
                seen = union.setdefault(t, [])
                for c in cands:
                    tup = tuple(c) if isinstance(c, (list, tuple)) else (c,)
                    if tup not in [tuple(v) if isinstance(v, (list, tuple))
                                   else (v,) for v in seen]:
                        seen.append(c)
        candidates_by_tier = union
    doc = {
        "bench": "autotune",
        "version": 1,
        "backend": jax.default_backend(),
        "note": ("block-size winners per (tier, N, d, K, dtype, backend) "
                 "from the fwd+grad timing harness; consulted by "
                 "repro.kernels.ops when block sizes are unset, with a "
                 "hardcoded 256 fallback on any miss.  CPU rows rank "
                 "interpret-mode emulation cost, not MXU cost — re-run "
                 "on a TPU backend to add real rows (EXPERIMENTS.md "
                 "§Autotune)."),
        "candidates": {t: [list(c) if isinstance(c, (list, tuple)) else [c]
                           for c in cands]
                       for t, cands in candidates_by_tier.items()},
        "cells": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    _load_table.cache_clear()
    return doc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny cells + candidate grid (CI; interpret "
                         "mode off-TPU as always)")
    ap.add_argument("--out", default=None,
                    help="output table path (default: the committed "
                         "table for the full search, a throwaway "
                         "/tmp file for --smoke)")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--check", action="store_true",
                    help="after writing, re-read the table via "
                         "lookup_blocks and assert every searched cell "
                         "round-trips to its winner")
    args = ap.parse_args(argv)

    cells = SMOKE_CELLS if args.smoke else FULL_CELLS
    cands = SMOKE_CANDIDATES if args.smoke else CANDIDATES
    out = args.out or (os.path.join("/tmp", "autotune_smoke.json")
                       if args.smoke else TABLE_PATH)
    rows = search_cells(cells, cands, reps=args.reps)
    write_table(rows, cands, out)
    print(f"wrote {out} ({len(rows)} cells)")

    if args.check:
        bad = []
        for row in rows:
            got = lookup_blocks(row["tier"], row["N"], row["d"], row["K"],
                                row["dtype"], path=out)
            want = tuple(row["winner"])
            want = want if len(want) > 1 else (want[0], want[0])
            if got != want:
                bad.append((row, got))
        if bad:
            raise SystemExit(f"autotune round-trip failed: {bad}")
        print(f"round-trip OK ({len(rows)} cells, cold write -> warm "
              "lookup, no re-search)")
    return rows


if __name__ == "__main__":
    main()
