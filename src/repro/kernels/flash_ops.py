"""jit'd wrapper + ref oracle for the flash-attention kernel.

``flash_attention(q, k, v, causal=...)`` takes model-layout tensors
(B, T, H, Dh) / (B, S, Hkv, Dh), pads to block multiples, folds
batch×head planes, and dispatches to the Pallas kernels (interpret mode
off-TPU).  Custom VJP: backward re-computes attention per q-chunk via
``jax.vjp`` of the reference on the chunk — O(chunk·S) memory, exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_fwd_pallas

_LANE = 128
_SUBLANE = 8


def _round_up(v, m):
    return (v + m - 1) // m * m


def _on_tpu():
    return jax.default_backend() == "tpu"


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0):
    """Pure-jnp GQA oracle. q: (B,T,H,Dh), k/v: (B,S,Hkv,Dh)."""
    b, tq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    qg = q.reshape(b, tq, hkv, rep, dh)
    s = jnp.einsum("bqhrd,bkhd->bhrqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    if causal:
        rows = jnp.arange(tq)[:, None] + q_offset
        cols = jnp.arange(k.shape[1])[None, :]
        s = jnp.where((cols <= rows)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", p, v.astype(jnp.float32))
    return out.reshape(b, tq, h, dh)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    bwd_chunk: int = 128):
    return _fwd_impl(q, k, v, causal, q_offset, block_q, block_k)


def _fwd_impl(q, k, v, causal, q_offset, block_q, block_k):
    b, tq, h, dh = q.shape
    s_len, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    br = min(block_q, _round_up(tq, _SUBLANE))
    bc = min(block_k, _round_up(s_len, _LANE))
    tqp = _round_up(tq, br)
    tkp = _round_up(s_len, bc)
    dhp = _round_up(dh, _LANE)

    def fold(x, heads, t_pad):
        x = jnp.pad(x.astype(jnp.float32),
                    ((0, 0), (0, t_pad - x.shape[1]), (0, 0),
                     (0, dhp - dh)))
        return x.transpose(0, 2, 1, 3).reshape(b * heads, -1, dhp)

    qf, kf, vf = fold(q, h, tqp), fold(k, hkv, tkp), fold(v, hkv, tkp)
    out = flash_attention_fwd_pallas(
        qf, kf, vf, rep=rep, scale=dh ** -0.5, q_len=tq, kv_len=s_len,
        causal=causal, q_offset=q_offset, br=br, bc=bc,
        interpret=not _on_tpu())
    out = out.reshape(b, h, tqp, dhp).transpose(0, 2, 1, 3)
    return out[:, :tq, :, :dh].astype(q.dtype)


def _fwd_rule(q, k, v, causal, q_offset, block_q, block_k, bwd_chunk):
    out = _fwd_impl(q, k, v, causal, q_offset, block_q, block_k)
    return out, (q, k, v)


def _bwd_rule(causal, q_offset, block_q, block_k, bwd_chunk, res, dout):
    q, k, v = res
    b, tq, h, dh = q.shape
    chunk = min(bwd_chunk, tq)
    tqp = _round_up(tq, chunk)
    qp = jnp.pad(q, ((0, 0), (0, tqp - tq), (0, 0), (0, 0)))
    dop = jnp.pad(dout, ((0, 0), (0, tqp - tq), (0, 0), (0, 0)))
    nblk = tqp // chunk

    def body(carry, blk_idx):
        dk_acc, dv_acc = carry
        start = blk_idx * chunk
        qb = jax.lax.dynamic_slice_in_dim(qp, start, chunk, axis=1)
        dob = jax.lax.dynamic_slice_in_dim(dop, start, chunk, axis=1)
        valid = (start + jnp.arange(chunk)) < tq

        def f(qb_, k_, v_):
            o = flash_attention_ref(qb_, k_, v_, causal=causal,
                                    q_offset=q_offset + start)
            return o * valid[None, :, None, None]

        _, vjp = jax.vjp(f, qb, k, v)
        dq_b, dk_b, dv_b = vjp(dob * valid[None, :, None, None])
        return (dk_acc + dk_b, dv_acc + dv_b), dq_b

    (dk, dv), dq_blocks = jax.lax.scan(
        body,
        (jnp.zeros_like(k, jnp.float32), jnp.zeros_like(v, jnp.float32)),
        jnp.arange(nblk))
    # scan ys: (nblk, B, chunk, H, Dh) -> (B, Tq_pad, H, Dh)
    dq = dq_blocks.transpose(1, 0, 2, 3, 4).reshape(b, tqp, h, dh)
    return (dq[:, :tq].astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


flash_attention.defvjp(_fwd_rule, _bwd_rule)
