"""Pure-jnp oracle for the fused SoftSort-apply kernel.

Materializes the full (N, N) soft permutation matrix — O(N^2) memory,
reference semantics only.  Every kernel test sweeps shapes/dtypes and
asserts allclose against this.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softsort_apply_ref(
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: jnp.ndarray | float,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(P_soft @ x, column_sums(P_soft)) with P = softmax(-|sort(w)_i - w_j|/tau).

    Args:
      w: (N,) sort keys.
      x: (N, d) payload.
      tau: temperature (scalar).

    Returns:
      y: (N, d), colsum: (N,).
    """
    ws = w[jnp.argsort(jax.lax.stop_gradient(w))]
    s = -jnp.abs(ws[:, None] - w[None, :]) / tau
    p = jax.nn.softmax(s, axis=-1)
    return p @ x, p.sum(axis=0)
