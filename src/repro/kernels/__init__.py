# Pallas TPU kernel layer for the paper's compute hot spot: the fused
# SoftSort apply (P_soft @ x, colsum(P_soft)) streamed flash-attention
# style, plus the flash attention used by the LM serving workloads.
#
#   ops.py              — public custom-VJP wrapper ``softsort_apply``;
#                         accepts (N,)/(N, d) or batched (B, N)/(B, N, d)
#   softsort_apply.py   — the forward kernels (batch = outermost grid dim)
#   ref.py              — O(N^2) pure-jnp oracle the tests assert against
#
# Kernels self-select ``interpret=True`` off-TPU, so this package works
# (slowly) on CPU — CI exercises exactly that path.
from repro.kernels.ops import softsort_apply  # noqa: F401
from repro.kernels.ref import softsort_apply_ref  # noqa: F401
