# Pallas TPU kernel layer for the paper's compute hot spot: the fused
# SoftSort apply (P_soft @ x, colsum(P_soft)) streamed flash-attention
# style — forward AND backward — plus the flash attention used by the
# LM serving workloads.
#
#   ops.py              — public custom-VJP wrapper ``softsort_apply``;
#                         accepts (N,)/(N, d) or batched (B, N)/(B, N, d);
#                         saves (perm, ws, m, l, y) residuals so the
#                         backward never re-sorts or re-normalizes.
#                         ``softsort_apply_v1`` keeps the previous
#                         3-pass-fwd / jnp-scan-bwd design as the
#                         benchmark baseline (benchmarks/kernel_bench.py)
#   softsort_apply.py   — the kernels: fused online-softmax forward
#                         (2 pallas_calls) + 3-pass backward (batch =
#                         outermost grid dim everywhere)
#   ref.py              — O(N^2) pure-jnp oracle the tests assert against
#
# Kernels self-select ``interpret=True`` off-TPU, so this package works
# (slowly) on CPU — CI exercises exactly that path.
from repro.kernels.ops import softsort_apply, softsort_apply_v1  # noqa: F401
from repro.kernels.ref import softsort_apply_ref  # noqa: F401
