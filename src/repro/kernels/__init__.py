# Pallas TPU kernel layer for the paper's compute hot spot: the fused
# SoftSort apply (P_soft @ x, colsum(P_soft)) streamed flash-attention
# style — forward AND backward — plus the flash attention used by the
# LM serving workloads.
#
#   ops.py              — public custom-VJP wrappers ``softsort_apply``
#                         (exact, O(N^2) compute streamed in O(N*block)
#                         memory) and ``softsort_apply_banded`` (O(N*K)
#                         compute AND traffic: both axes gathered into
#                         sorted-rank order, only a width-(2K+1) band
#                         scored, tail mass bounded by
#                         ``core.softsort.band_tail_bound``); both accept
#                         (N,)/(N, d) or batched (B, N)/(B, N, d) and
#                         save (perm, m, l, y) residuals so the backward
#                         never re-sorts or re-normalizes.
#                         ``softsort_apply_v1`` keeps the previous
#                         3-pass-fwd / jnp-scan-bwd design as the
#                         benchmark baseline (benchmarks/kernel_bench.py)
#   softsort_apply.py   — the kernels: fused online-softmax forward
#                         (2 pallas_calls) + 3-pass backward (batch =
#                         outermost grid dim everywhere), plus the banded
#                         variants whose grids visit only the band's
#                         2*ceil(K/blk)+1 column blocks per row block
#   ref.py              — O(N^2) pure-jnp oracle the tests assert against
#
# Kernels self-select ``interpret=True`` off-TPU, so this package works
# (slowly) on CPU — CI exercises exactly that path.
from repro.kernels.ops import (  # noqa: F401
    softsort_apply,
    softsort_apply_banded,
    softsort_apply_v1,
)
from repro.kernels.ref import softsort_apply_ref  # noqa: F401
