# Pallas TPU kernel layer for the paper's compute hot spot: the fused
# SoftSort apply (P_soft @ x, colsum(P_soft)) streamed flash-attention
# style — forward AND backward — plus the flash attention used by the
# LM serving workloads.
#
#   ops.py              — public custom-VJP wrappers ``softsort_apply``
#                         (exact, O(N^2) compute streamed in O(N*block)
#                         memory) and ``softsort_apply_banded`` (O(N*K)
#                         compute AND traffic: both axes gathered into
#                         sorted-rank order, only a width-(2K+1) band
#                         scored, tail mass bounded by
#                         ``core.softsort.band_tail_bound``); both accept
#                         (N,)/(N, d) or batched (B, N)/(B, N, d), a
#                         ``compute_dtype`` ("float32"/"bfloat16" —
#                         bf16 scores/payload, f32 keys/stats/
#                         accumulators), and save (perm, m, l, y)
#                         residuals so the backward never re-sorts or
#                         re-normalizes.  Block sizes default to the
#                         committed autotune table.
#                         ``softsort_apply_v1`` keeps the previous
#                         3-pass-fwd / jnp-scan-bwd design as the
#                         benchmark baseline (benchmarks/kernel_bench.py)
#   softsort_apply.py   — the kernels: fused online-softmax forward
#                         (2 pallas_calls) + 2-pass backward (the delta
#                         pass is merged into the dws sweep; batch =
#                         outermost grid dim everywhere), plus the banded
#                         variants whose grids visit only the
#                         2*ceil(K/blk)+1 band blocks per row block
#   autotune.py         — per-(N, d, K, dtype, backend) block-size
#                         search + the committed ``autotune_table.json``
#                         consulted at dispatch (hardcoded fallback)
#   ref.py              — O(N^2) pure-jnp oracle the tests assert against
#
# Kernels self-select ``interpret=True`` off-TPU, so this package works
# (slowly) on CPU — CI exercises exactly that path.
from repro.kernels.autotune import lookup_blocks  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    softsort_apply,
    softsort_apply_banded,
    softsort_apply_v1,
)
from repro.kernels.ref import softsort_apply_ref  # noqa: F401
