"""Fused flash-style SoftSort-apply Pallas TPU kernels (batched).

Computes, without ever materializing the (N, N) soft permutation matrix,
for every instance b of a leading batch axis:

    P[b]_ij   = softmax_j( -|sort(w[b])_i - w[b]_j| / tau )
    y[b]      = P[b] @ x[b]          (B, N, d)
    colsum[b] = sum_i P[b]_ij        (B, N)

Structure is exactly flash attention with an L1-distance score and the
sorted keys playing the role of queries:

  * ``_stats_kernel``  — pass 1: streaming row max ``m`` and denominator
    ``l`` over column blocks (grid = (B, Ni, Nj), j innermost; m/l output
    blocks are revisited consecutively so they live in VMEM as
    accumulators — the TPU sequential-grid idiom).
  * ``_apply_kernel``  — pass 2: exact P block = exp(s - m)/l, fused
    (Br, Bc) @ (Bc, d) MXU matmul accumulated into the y block.
  * ``_colsum_kernel`` — pass 2': same P block math with the i/j grid
    axes transposed (j outer, i inner) so the colsum block accumulates
    over row blocks.

The batch axis is the OUTERMOST grid dimension: each instance is an
independent sweep over its own (Ni, Nj) tile space, so the accumulator
idiom above is untouched — b changes only after an instance's tiles are
exhausted.  Instances share one scalar ``tau`` (the trainer anneals a
single schedule across the whole batch).  The batch block size is
``None`` (squeezed), so the kernels themselves see the same 2-D blocks
as the single-problem version — this file's kernels serve both; the
unbatched wrapper in ``repro.kernels.ops`` simply runs B = 1.

VMEM working set per step ~ Br*Bc (scores) + Bc*d (x block) + Br*d
(y accumulator) floats; with the default Br = Bc = 256, d <= 512 this is
well under the ~16 MB/core budget and independent of B.  Block shapes
are (8k, 128m)-aligned so the MXU sees aligned contractions.

All kernels mask columns/rows >= n (true length) with -inf / zero, so
the wrapper may pad N up to block multiples with arbitrary finite
values.  ``tau`` arrives as a (1, 1) array so it can be a traced value
inside jit without retriggering compilation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _score(ws_blk, w_blk, inv_tau):
    # (Br, 1) x (1, Bc) -> (Br, Bc) L1 scores, scaled.
    return -jnp.abs(ws_blk - w_blk) * inv_tau


def _col_mask(j, bc, n):
    col_ids = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    return col_ids < n


def _row_mask(i, br, n):
    row_ids = i * br + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
    return row_ids < n


def _stats_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)               # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        jnp.exp(s - m_new), axis=-1, keepdims=True)
    m_ref[...] = m_new


def _apply_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, y_ref,
                  *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(p, x_ref[...], preferred_element_type=jnp.float32)


def _colsum_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, c_ref,
                   *, n: int, br: int, bc: int):
    # Grid is (B, Nj, Ni): i innermost so the c block accumulates in VMEM.
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)                 # mask pad rows

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.sum(p, axis=0, keepdims=True)


def softsort_apply_fwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded
    x: jnp.ndarray,       # (B, Np, dp) payload, padded
    tau: jnp.ndarray,     # (1, 1) — shared across the batch
    *,
    n: int,               # true length
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched fused forward: returns (y (B, Np, dp), colsum (B, 1, Np))."""
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(ws, w, tau)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, np_, dp), f32),
        interpret=interpret,
    )(ws, w, x, tau, m, l)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum
