"""Fused flash-style SoftSort-apply Pallas TPU kernels (batched, fwd + bwd).

Computes, without ever materializing the (N, N) soft permutation matrix,
for every instance b of a leading batch axis:

    P[b]_ij   = softmax_j( -|sort(w[b])_i - w[b]_j| / tau )
    y[b]      = P[b] @ x[b]          (B, N, d)
    colsum[b] = sum_i P[b]_ij        (B, N)

Structure is exactly flash attention with an L1-distance score and the
sorted keys playing the role of queries.

Forward — ONE online-softmax sweep (FlashAttention-2 style) plus the
colsum reduction, two ``pallas_call``s total, so the score block is
computed exactly twice and the softmax stats never round-trip to HBM
mid-forward:

  * ``_fwd_fused_kernel`` — streaming row max ``m``, denominator ``l``
    AND the un-normalized y accumulator in one pass (grid = (B, Ni, Nj),
    j innermost; the m/l/y output blocks are revisited consecutively so
    they live in VMEM as accumulators — the TPU sequential-grid idiom).
    Each column block rescales the running y by ``exp(m_prev - m_new)``;
    the final ``1/l`` is applied once at the last column block.  ``m``
    and ``l`` are kernel *outputs*: the backward reuses them as
    residuals instead of re-deriving the softmax.
  * ``_colsum_kernel``    — exact P block = exp(s - m)/l with the i/j
    grid axes transposed (j outer, i inner) so the colsum block
    accumulates over row blocks.

Backward — three Pallas passes driven by the ``custom_vjp`` in
``repro.kernels.ops``, which saves ``(perm, ws, m, l, y)`` from the
forward so no pass re-sorts or re-normalizes.  With
``dP_ij = dy_i . x_j + dc_j`` and ``ds = P * (dP - D)`` where
``D_i = sum_j P_ij dP_ij``:

  * ``_bwd_delta_kernel`` — row grid: ``D_i = dy_i . y_i + (P @ dc)_i``
    (the first term is flash attention's delta trick — ``sum_j P_ij
    (dy_i . x_j) = dy_i . y_i`` because y was saved; only the colsum
    cotangent needs a streamed ``P @ dc``).
  * ``_bwd_dx_kernel``    — transposed grid (j outer, i inner):
    ``dx_j = sum_i P_ij dy_i`` (a (Bc, Br) x (Br, d) MXU contraction),
    plus the column-indexed reductions ``dw_cols_j = sum_i ds_ij
    sgn_ij / tau`` and a per-column ``dtau`` partial.
  * ``_bwd_dws_kernel``   — row grid: ``dws_i = -sum_j ds_ij sgn_ij
    / tau`` (scattered back through ``perm`` by the wrapper).

No (B, chunk, N) ``delta``/``p``/``dp``/``ds`` temporaries ever touch
HBM — every score/probability block is consumed inside its VMEM tile.

The batch axis is the OUTERMOST grid dimension: each instance is an
independent sweep over its own (Ni, Nj) tile space, so the accumulator
idiom above is untouched — b changes only after an instance's tiles are
exhausted.  Instances share one scalar ``tau`` (the trainer anneals a
single schedule across the whole batch).  The batch block size is
``None`` (squeezed), so the kernels themselves see 2-D blocks.

VMEM working set per step ~ Br*Bc (scores) + Bc*d (x block) + Br*d
(y/dy blocks) floats; with the default Br = Bc = 256, d <= 512 this is
well under the ~16 MB/core budget and independent of B.  Block shapes
are (8k, 128m)-aligned so the MXU sees aligned contractions.

All kernels mask columns >= n (true length) with -inf scores and rows
>= n out of every column-indexed reduction, so the wrapper may pad N up
to block multiples with arbitrary finite values.  ``tau`` arrives as a
(1, 1) array so it can be a traced value inside jit without
retriggering compilation.

The v1 split forward (separate stats + apply passes, three
``pallas_call``s) is kept at the bottom as the benchmark baseline for
``benchmarks/kernel_bench.py`` — it is what PR 1/2 shipped, and the
fused-vs-v1 rows in BENCH_kernels.json quantify the win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _score(ws_blk, w_blk, inv_tau):
    # (Br, 1) x (1, Bc) -> (Br, Bc) L1 scores, scaled.
    return -jnp.abs(ws_blk - w_blk) * inv_tau


def _col_mask(j, bc, n):
    col_ids = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    return col_ids < n


def _row_mask(i, br, n):
    row_ids = i * br + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
    return row_ids < n


# --------------------------------------------------------------------------
# Forward: fused online-softmax sweep + colsum.
# --------------------------------------------------------------------------

def _fwd_fused_kernel(ws_ref, w_ref, x_ref, tau_ref, y_ref, m_ref, l_ref,
                      *, n: int, bc: int, nj: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)               # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        y_ref[...] = jnp.zeros_like(y_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    p_un = jnp.exp(s - m_new)                                  # un-normalized
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        p_un, axis=-1, keepdims=True)
    m_ref[...] = m_new
    y_ref[...] = y_ref[...] * correction + jnp.dot(
        p_un, x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _normalize():
        y_ref[...] = y_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _colsum_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, c_ref,
                   *, n: int, br: int, bc: int):
    # Grid is (B, Nj, Ni): i innermost so the c block accumulates in VMEM.
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)                 # mask pad rows

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.sum(p, axis=0, keepdims=True)


def softsort_apply_fwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded
    x: jnp.ndarray,       # (B, Np, dp) payload, padded
    tau: jnp.ndarray,     # (1, 1) — shared across the batch
    *,
    n: int,               # true length
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused forward: (y (B, Np, dp), colsum (B, 1, Np), m, l (B, Np, 1)).

    Two ``pallas_call``s: the fused online-softmax sweep and the
    transposed-grid colsum reduction.  ``m``/``l`` are returned so the
    backward can reuse them as residuals.
    """
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    y, m, l = pl.pallas_call(
        functools.partial(_fwd_fused_kernel, n=n, bc=bc, nj=nj),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # y
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, dp), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(ws, w, x, tau)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum, m, l


# --------------------------------------------------------------------------
# Backward: three Pallas passes over the saved residuals.
# --------------------------------------------------------------------------

def _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n):
    """Exact normalized P block from the saved softmax stats (no re-max,
    no re-sum) — the residual-reuse core of the backward."""
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    return s, p


def _bwd_delta_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, dy_ref, y_ref,
                      dc_ref, d_ref, *, n: int, bc: int):
    """D_i = dy_i . y_i + sum_j P_ij dc_j, streamed over column blocks."""
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    _, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.sum(dy_ref[...] * y_ref[...], axis=-1,
                             keepdims=True)

    d_ref[...] += jax.lax.dot_general(
        p, dc_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _bwd_dx_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, dy_ref,
                   dc_ref, d_ref, dx_ref, dwc_ref, dtc_ref,
                   *, n: int, br: int, bc: int):
    """Transposed grid (B, Nj, Ni): per column block accumulate
    dx_j = P^T @ dy, dw_cols_j = sum_i ds * sgn / tau, and the
    per-column dtau partial sum_i ds * (-s) / tau."""
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)      # pad rows are not rows of P
    # dP_ij = dy_i . x_j + dc_j
    dp = jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])                                  # (Br, Bc)
    sgn = jnp.sign(ws_ref[...] - w_ref[...])

    @pl.when(i == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)
        dwc_ref[...] = jnp.zeros_like(dwc_ref)
        dtc_ref[...] = jnp.zeros_like(dtc_ref)

    dx_ref[...] += jax.lax.dot_general(
        p, dy_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (Bc, dp)
    dwc_ref[...] += jnp.sum(ds * sgn, axis=0, keepdims=True) * inv_tau
    # s = -|delta|/tau  =>  d s / d tau = -s / tau; masked cols have
    # ds == 0 exactly, and NEG_INF is finite, so 0 * (-NEG_INF) == 0.
    dtc_ref[...] += jnp.sum(ds * (-s), axis=0, keepdims=True) * inv_tau


def _bwd_dws_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, dy_ref,
                    dc_ref, d_ref, dws_ref, *, n: int, bc: int):
    """Row grid (B, Ni, Nj): dws_i = -sum_j ds_ij * sgn_ij / tau."""
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    _, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n)
    dp = jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])
    sgn = jnp.sign(ws_ref[...] - w_ref[...])

    @pl.when(j == 0)
    def _init():
        dws_ref[...] = jnp.zeros_like(dws_ref)

    dws_ref[...] += jnp.sum(ds * (-sgn), axis=-1, keepdims=True) * inv_tau


def softsort_apply_bwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded
    x: jnp.ndarray,       # (B, Np, dp) payload, padded
    tau: jnp.ndarray,     # (1, 1)
    m: jnp.ndarray,       # (B, Np, 1) saved row maxes
    l: jnp.ndarray,       # (B, Np, 1) saved row denominators
    y: jnp.ndarray,       # (B, Np, dp) saved forward output
    dy: jnp.ndarray,      # (B, Np, dp) cotangent of y (pad rows zero)
    dc: jnp.ndarray,      # (B, 1, Np) cotangent of colsum (pad cols zero)
    *,
    n: int,
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused backward from saved residuals.

    Returns (dws (B, Np, 1) — gradient w.r.t. the SORTED keys, to be
    scattered through ``perm`` by the caller; dw_cols (B, 1, Np);
    dx (B, Np, dp); dtau_cols (B, 1, Np) — per-column dtau partials,
    summed to a scalar by the caller).
    """
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    row_spec = pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0))
    col_spec = pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j))
    tau_spec = pl.BlockSpec((1, 1), lambda b, i, j: (0, 0))

    delta = pl.pallas_call(
        functools.partial(_bwd_delta_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            row_spec,                                                 # ws
            col_spec,                                                 # w
            tau_spec,                                                 # tau
            row_spec,                                                 # m
            row_spec,                                                 # l
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # dy
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # y
            col_spec,                                                 # dc
        ],
        out_specs=row_spec,                                           # D
        out_shape=jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        interpret=interpret,
    )(ws, w, tau, m, l, dy, y, dc)

    # Transposed grid: j outer, i inner, so the column-indexed outputs
    # (dx, dw_cols, dtau_cols) accumulate in VMEM.
    dx, dw_cols, dtau_cols = pl.pallas_call(
        functools.partial(_bwd_dx_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, j, i: (b, j, 0)),  # x
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
            pl.BlockSpec((None, br, dp), lambda b, j, i: (b, i, 0)),  # dy
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dc
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # D
        ],
        out_specs=[
            pl.BlockSpec((None, bc, dp), lambda b, j, i: (b, j, 0)),  # dx
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dw_cols
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dtau
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, dp), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        ],
        interpret=interpret,
    )(ws, w, x, tau, m, l, dy, dc, delta)

    dws = pl.pallas_call(
        functools.partial(_bwd_dws_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            row_spec,                                                 # ws
            col_spec,                                                 # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x
            tau_spec,                                                 # tau
            row_spec,                                                 # m
            row_spec,                                                 # l
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # dy
            col_spec,                                                 # dc
            row_spec,                                                 # D
        ],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        interpret=interpret,
    )(ws, w, x, tau, m, l, dy, dc, delta)

    return dws, dw_cols, dx, dtau_cols


# --------------------------------------------------------------------------
# v1 split forward (stats + apply + colsum, three pallas_calls) — kept as
# the measured baseline for benchmarks/kernel_bench.py.  Not used by the
# production path.
# --------------------------------------------------------------------------

def _stats_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)               # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        jnp.exp(s - m_new), axis=-1, keepdims=True)
    m_ref[...] = m_new


def _apply_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, y_ref,
                  *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(p, x_ref[...], preferred_element_type=jnp.float32)


def softsort_apply_fwd_pallas_v1(
    ws: jnp.ndarray,
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    n: int,
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """v1 baseline forward: three passes (stats, apply, colsum), scores
    computed three times, m/l round-tripping through HBM between passes.
    Returns (y (B, Np, dp), colsum (B, 1, Np))."""
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(ws, w, tau)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, np_, dp), f32),
        interpret=interpret,
    )(ws, w, x, tau, m, l)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum
