"""Fused flash-style SoftSort-apply Pallas TPU kernels (batched, fwd + bwd).

Computes, without ever materializing the (N, N) soft permutation matrix,
for every instance b of a leading batch axis:

    P[b]_ij   = softmax_j( -|sort(w[b])_i - w[b]_j| / tau )
    y[b]      = P[b] @ x[b]          (B, N, d)
    colsum[b] = sum_i P[b]_ij        (B, N)

Structure is exactly flash attention with an L1-distance score and the
sorted keys playing the role of queries.

Forward — ONE online-softmax sweep (FlashAttention-2 style) plus the
colsum reduction, two ``pallas_call``s total, so the score block is
computed exactly twice and the softmax stats never round-trip to HBM
mid-forward:

  * ``_fwd_fused_kernel`` — streaming row max ``m``, denominator ``l``
    AND the un-normalized y accumulator in one pass (grid = (B, Ni, Nj),
    j innermost; m/l are revisited output blocks and y accumulates in a
    float32 VMEM *scratch* buffer — both live on-chip across the column
    sweep, the TPU sequential-grid idiom).  Each column block rescales
    the running y by ``exp(m_prev - m_new)``; the final ``1/l`` is
    applied once at the last column block, where y is written out ONCE
    in the compute dtype.  ``m`` and ``l`` are kernel *outputs*: the
    backward reuses them as residuals instead of re-deriving the
    softmax.
  * ``_colsum_kernel``    — exact P block = exp(s - m)/l with the i/j
    grid axes transposed (j outer, i inner) so the colsum block
    accumulates over row blocks.

Backward — TWO Pallas passes driven by the ``custom_vjp`` in
``repro.kernels.ops``, which saves ``(perm, m, l, y)`` from the
forward so no pass re-sorts or re-normalizes.  With
``dP_ij = dy_i . x_j + dc_j`` and ``ds = P * (dP - D)`` where
``D_i = sum_j P_ij dP_ij``:

  * ``_bwd_dws_delta_kernel`` — row grid: ONE sweep fuses the old
    delta pass into the dws pass.  It accumulates three row vectors —
    ``D_i = dy_i . y_i + (P @ dc)_i`` (the first term is flash
    attention's delta trick: ``sum_j P_ij (dy_i . x_j) = dy_i . y_i``
    because y was saved; only the colsum cotangent needs a streamed
    ``P @ dc``), ``A_i = sum_j P_ij dP_ij sgn_ij`` and ``S_i = sum_j
    P_ij sgn_ij`` (A and S in VMEM scratch) — and combines them at the
    last column block:
    ``dws_i = -sum_j ds_ij sgn_ij / tau = -(A_i - D_i S_i) / tau``
    (the D-dependent part of ds factors out of the row reduction, so
    dws never needs a completed D mid-sweep).  One fewer full re-score
    of the tile space than the previous 3-pass design, and D is still
    emitted for the pass below.
  * ``_bwd_dx_kernel``    — transposed grid (j outer, i inner):
    ``dx_j = sum_i P_ij dy_i`` (a (Bc, Br) x (Br, d) MXU contraction,
    accumulated in f32 scratch, written once in the compute dtype),
    plus the column-indexed reductions ``dw_cols_j = sum_i ds_ij
    sgn_ij / tau`` and a per-column ``dtau`` partial (here ds needs
    D_i per summand, so this pass genuinely consumes the finished D).

No (B, chunk, N) ``delta``/``p``/``dp``/``ds`` temporaries ever touch
HBM — every score/probability block is consumed inside its VMEM tile.

Mixed precision (``cd``, the compute dtype — f32 or bf16, threaded from
``ops``' ``compute_dtype``):

  * KEYS STAY FLOAT32.  The keys are the paper's N learnable
    parameters; quantizing them to bf16 collapses unit rank gaps into
    ties above N = 256 (bf16 integers are exact only to 256) and was
    measured to blow the key-gradient parity up to ~0.5 relative.  Key
    vectors are O(N) bytes — negligible against the payload — so f32
    keys cost nothing and keep rank resolution exact.
  * SCORES are computed from the f32 keys and then ROUNDED to ``cd``
    (``.astype(cd)``), so the bf16 tier sees genuinely bf16 scores —
    but with error proportional to the score's own magnitude, not the
    key magnitude.  In the trainer's shuffled-arange regime the scores
    are small integer multiples of 1/tau and round exactly.
  * PAYLOAD-SIDED ARRAYS (x, dy, dc, the saved y residual, and the dx
    gradient output) live in ``cd`` in HBM — at bf16 every payload
    block moved is half the bytes, which is where the measured traffic
    reduction comes from — and every matmul takes cd inputs with
    ``preferred_element_type=jnp.float32``: f32 MXU accumulation.
  * EVERYTHING LOAD-BEARING STAYS F32: the online-softmax max/exp/sum,
    the m/l stats and residuals, D, every VMEM accumulator (the y and
    dx accumulators are explicit f32 scratch when their HBM form is
    cd), and the key/tau gradients (dws, dw_cols, dtau).

``cd == float32`` reproduces the previous all-f32 kernels bit-for-bit.
The bf16 parity envelope is measured in EXPERIMENTS.md §Perf and gated
by ``tests/test_precision.py`` / ``tools/check_bench.py``.

The batch axis is the OUTERMOST grid dimension: each instance is an
independent sweep over its own (Ni, Nj) tile space, so the accumulator
idiom above is untouched — b changes only after an instance's tiles are
exhausted.  Instances share one scalar ``tau`` (the trainer anneals a
single schedule across the whole batch).  The batch block size is
``None`` (squeezed), so the kernels themselves see 2-D blocks.

VMEM working set per step ~ Br*Bc (scores) + Bc*d (x block) + Br*d
(y/dy blocks + the f32 y scratch) floats; with the default Br = Bc =
256, d <= 512 this is well under the ~16 MB/core budget and independent
of B.  Block shapes are (8k, 128m)-aligned so the MXU sees aligned
contractions; the autotune table (``repro.kernels.autotune``) picks
per-shape block sizes within that constraint.

All kernels mask columns >= n (true length) with -inf scores and rows
>= n out of every column-indexed reduction, so the wrapper may pad N up
to block multiples with arbitrary finite values.  ``tau`` arrives as a
(1, 1) array so it can be a traced value inside jit without
retriggering compilation.

The v1 split forward (separate stats + apply passes, three
``pallas_call``s) is kept at the bottom as the benchmark baseline for
``benchmarks/kernel_bench.py`` — it is what PR 1/2 shipped (f32 only),
and the fused-vs-v1 rows in BENCH_kernels.json quantify the win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _score(ws_blk, w_blk, inv_tau, cd):
    # (Br, 1) x (1, Bc) -> (Br, Bc) L1 scores, scaled.  Keys are always
    # f32 (see module docstring); the finished score is rounded to the
    # compute dtype and upcast, so the bf16 tier's scores carry bf16
    # precision relative to the SCORE scale while the softmax math
    # downstream stays f32.  cd == f32 is the exact identity.
    s = -jnp.abs(ws_blk - w_blk) * inv_tau
    return s.astype(cd).astype(jnp.float32)


def _col_mask(j, bc, n):
    col_ids = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    return col_ids < n


def _row_mask(i, br, n):
    row_ids = i * br + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
    return row_ids < n


# --------------------------------------------------------------------------
# Forward: fused online-softmax sweep + colsum.
# --------------------------------------------------------------------------

def _fwd_fused_kernel(ws_ref, w_ref, x_ref, tau_ref, y_ref, m_ref, l_ref,
                      acc_ref, *, n: int, bc: int, nj: int, cd):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau, cd)           # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    p_un = jnp.exp(s - m_new)                                  # un-normalized
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        p_un, axis=-1, keepdims=True)
    m_ref[...] = m_new
    # Payload matmul inputs in the compute dtype, accumulation pinned to
    # the f32 VMEM scratch by preferred_element_type — the MXU contract.
    acc_ref[...] = acc_ref[...] * correction + jnp.dot(
        p_un.astype(cd), x_ref[...],
        preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _normalize():
        y_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(y_ref.dtype)


def _colsum_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, c_ref,
                   *, n: int, br: int, bc: int, cd):
    # Grid is (B, Nj, Ni): i innermost so the c block accumulates in VMEM.
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau, cd)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)                 # mask pad rows

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.sum(p, axis=0, keepdims=True)


def softsort_apply_fwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded, f32
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded, f32
    x: jnp.ndarray,       # (B, Np, dp) payload, padded, compute dtype
    tau: jnp.ndarray,     # (1, 1) — shared across the batch
    *,
    n: int,               # true length
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused forward: (y (B, Np, dp) in the compute dtype, colsum
    (B, 1, Np), m, l (B, Np, 1) f32).

    Two ``pallas_call``s: the fused online-softmax sweep and the
    transposed-grid colsum reduction.  ``m``/``l`` are returned so the
    backward can reuse them as residuals; the compute dtype is inferred
    from ``x.dtype`` (the wrapper casts operands once).
    """
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32
    cd = x.dtype

    y, m, l = pl.pallas_call(
        functools.partial(_fwd_fused_kernel, n=n, bc=bc, nj=nj, cd=cd),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # y
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, dp), cd),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        scratch_shapes=[pltpu.VMEM((br, dp), f32)],       # y accumulator
        interpret=interpret,
    )(ws, w, x, tau)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc, cd=cd),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum, m, l


# --------------------------------------------------------------------------
# Backward: two Pallas passes over the saved residuals.
# --------------------------------------------------------------------------

def _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n, cd):
    """Exact normalized P block from the saved softmax stats (no re-max,
    no re-sum) — the residual-reuse core of the backward.  Scores are
    quantized exactly as the forward quantized them, so exp(s - m)/l
    reconstructs the forward's P bit-for-bit per compute dtype."""
    s = _score(ws_ref[...], w_ref[...], inv_tau, cd)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    return s, p


def _bwd_dws_delta_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref,
                          dy_ref, y_ref, dc_ref, d_ref, dws_ref,
                          a_ref, srow_ref, *, n: int, bc: int, nj: int, cd):
    """Fused delta + dws row-grid sweep (the 3->2 backward-pass merge).

    Accumulates, per row block over the column blocks:
      D_i = dy_i . y_i + sum_j P_ij dc_j       (delta, emitted for the
                                                transposed pass)
      A_i = sum_j P_ij dP_ij sgn_ij            (f32 scratch)
      S_i = sum_j P_ij sgn_ij                  (f32 scratch)
    and combines at the last column block:
      dws_i = -sum_j ds_ij sgn_ij / tau = -(A_i - D_i * S_i) / tau
    — the D-dependent half of ds = P (dP - D) factors out of the row
    reduction, so dws never needs a finished D mid-sweep."""
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    _, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n, cd)
    dp = jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    sgn = jnp.sign(ws_ref[...] - w_ref[...])

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.sum(dy_ref[...].astype(jnp.float32)
                             * y_ref[...].astype(jnp.float32),
                             axis=-1, keepdims=True)
        a_ref[...] = jnp.zeros_like(a_ref)
        srow_ref[...] = jnp.zeros_like(srow_ref)

    d_ref[...] += jax.lax.dot_general(
        p.astype(cd), dc_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    a_ref[...] += jnp.sum(p * dp * sgn, axis=-1, keepdims=True)
    srow_ref[...] += jnp.sum(p * sgn, axis=-1, keepdims=True)

    @pl.when(j == nj - 1)
    def _combine():
        dws_ref[...] = -(a_ref[...] - d_ref[...] * srow_ref[...]) \
            * inv_tau


def _bwd_dx_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, dy_ref,
                   dc_ref, d_ref, dx_ref, dwc_ref, dtc_ref, acc_ref,
                   *, n: int, br: int, bc: int, ni: int, cd):
    """Transposed grid (B, Nj, Ni): per column block accumulate
    dx_j = P^T @ dy (f32 scratch, written once in the compute dtype),
    dw_cols_j = sum_i ds * sgn / tau, and the per-column dtau partial
    sum_i ds * (-s) / tau."""
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n, cd)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)      # pad rows are not rows of P
    # dP_ij = dy_i . x_j + dc_j
    dp = jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])                                  # (Br, Bc)
    sgn = jnp.sign(ws_ref[...] - w_ref[...])

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        dwc_ref[...] = jnp.zeros_like(dwc_ref)
        dtc_ref[...] = jnp.zeros_like(dtc_ref)

    acc_ref[...] += jax.lax.dot_general(
        p.astype(cd), dy_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (Bc, dp)
    dwc_ref[...] += jnp.sum(ds * sgn, axis=0, keepdims=True) * inv_tau
    # s = -|delta|/tau  =>  d s / d tau = -s / tau; masked cols have
    # ds == 0 exactly, and NEG_INF is finite, so 0 * (-NEG_INF) == 0.
    dtc_ref[...] += jnp.sum(ds * (-s), axis=0, keepdims=True) * inv_tau

    @pl.when(i == ni - 1)
    def _flush():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


def softsort_apply_bwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded, f32
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded, f32
    x: jnp.ndarray,       # (B, Np, dp) payload, padded, compute dtype
    tau: jnp.ndarray,     # (1, 1)
    m: jnp.ndarray,       # (B, Np, 1) saved row maxes, f32
    l: jnp.ndarray,       # (B, Np, 1) saved row denominators, f32
    y: jnp.ndarray,       # (B, Np, dp) saved forward output, compute dtype
    dy: jnp.ndarray,      # (B, Np, dp) cotangent of y, compute dtype
    dc: jnp.ndarray,      # (B, 1, Np) cotangent of colsum, compute dtype
    *,
    n: int,
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused backward from saved residuals — TWO Pallas passes.

    Pass 1 (row grid) fuses the old delta pass into the dws pass: one
    sweep emits D (consumed by pass 2) AND dws.  Pass 2 (transposed
    grid) produces the column-indexed dx / dw_cols / dtau_cols.

    Returns (dws (B, Np, 1) f32 — gradient w.r.t. the SORTED keys, to
    be scattered through ``perm`` by the caller; dw_cols (B, 1, Np)
    f32; dx (B, Np, dp) in the compute dtype; dtau_cols (B, 1, Np) f32
    — per-column dtau partials, summed to a scalar by the caller).
    """
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32
    cd = x.dtype

    row_spec = pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0))
    col_spec = pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j))
    tau_spec = pl.BlockSpec((1, 1), lambda b, i, j: (0, 0))

    # Fused delta+dws row-grid sweep; the A/S partial sums live in f32
    # VMEM scratch and never touch HBM.
    delta, dws = pl.pallas_call(
        functools.partial(_bwd_dws_delta_kernel, n=n, bc=bc, nj=nj, cd=cd),
        grid=(bsz, ni, nj),
        in_specs=[
            row_spec,                                                 # ws
            col_spec,                                                 # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x
            tau_spec,                                                 # tau
            row_spec,                                                 # m
            row_spec,                                                 # l
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # dy
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # y
            col_spec,                                                 # dc
        ],
        out_specs=[row_spec, row_spec],                    # D, dws
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        scratch_shapes=[pltpu.VMEM((br, 1), f32),          # A
                        pltpu.VMEM((br, 1), f32)],         # S
        interpret=interpret,
    )(ws, w, x, tau, m, l, dy, y, dc)

    # Transposed grid: j outer, i inner, so the column-indexed outputs
    # (dx via scratch, dw_cols, dtau_cols) accumulate in VMEM.
    dx, dw_cols, dtau_cols = pl.pallas_call(
        functools.partial(_bwd_dx_kernel, n=n, br=br, bc=bc, ni=ni, cd=cd),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, j, i: (b, j, 0)),  # x
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
            pl.BlockSpec((None, br, dp), lambda b, j, i: (b, i, 0)),  # dy
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dc
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # D
        ],
        out_specs=[
            pl.BlockSpec((None, bc, dp), lambda b, j, i: (b, j, 0)),  # dx
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dw_cols
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dtau
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, dp), cd),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        ],
        scratch_shapes=[pltpu.VMEM((bc, dp), f32)],        # dx accumulator
        interpret=interpret,
    )(ws, w, x, tau, m, l, dy, dc, delta)

    return dws, dw_cols, dx, dtau_cols


# --------------------------------------------------------------------------
# Banded tier: O(N * K) windowed kernels in sorted-rank coordinates.
#
# The wrapper (ops.softsort_apply_banded) gathers BOTH matrix axes into
# sorted-key order, so the soft permutation matrix P~ is diagonally
# dominant in rank space and only the width-(2K+1) band around the
# diagonal is scored — out-of-band entries are treated as exactly zero
# (neglected mass bounded by core.softsort.band_tail_bound).  Each row
# block i therefore touches only the nbj = 2*ceil(K/blk) + 1 column
# blocks u = i - off .. i + off, shrinking the grid from (N/blk)^2 to
# (N/blk) * nbj cells per pass; edge blocks clip their index maps into
# range and mask themselves out entirely.
#
# Two layout changes vs the dense kernels above, both HBM-traffic wins
# at the paper's small payload widths (d = 3..50):
#
#   * scores live TRANSPOSED, (bc, br) with matrix columns on sublanes
#     and rows on lanes, so the running softmax stats m/l are (1, br)
#     lane vectors and every reduction stays a lane-wise op;
#   * the payload is carried transposed, (dsub, Np) with dsub =
#     round_up(d, 8) on SUBLANES — padding d to the 8-sublane quantum
#     instead of the 128-lane quantum cuts payload blocks 16x at d = 8
#     (the (bc, d) @ -> y contraction becomes x_t @ p_un on the MXU).
#
# Same online-softmax + residual-saving custom_vjp structure as the
# fused dense tier: one forward sweep emitting (y_t, m, l), a
# transposed-grid colsum, and TWO backward passes (fused delta+dws row
# sweep, then the column-indexed dx/dw/dtau pass — the same 3->2 merge
# as the dense tier, one fewer full re-score of the band).  Because
# both axes are sorted, the key gradient has a row AND a column
# component here — the wrapper sums them before scattering through the
# saved perm.  Mixed precision follows the dense tier's contract: keys
# f32, scores rounded to the compute dtype, payload-sided arrays
# (xt, dyt, dc, the yt residual, the dxt output) in the compute dtype,
# stats/accumulators f32.
# --------------------------------------------------------------------------


def _band_mask(i, u, blk: int, k: int, n: int):
    """(bc, br) validity of a banded score block: |rank_col - rank_row|
    <= K, both ranks real (not padding), both block ids in range (a
    clipped edge block computes its UNCLIPPED ids here, so it masks
    itself out entirely instead of double-counting the block it was
    clamped onto)."""
    rows = i * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    cols = u * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0)
    return ((jnp.abs(cols - rows) <= k)
            & (cols >= 0) & (cols < n) & (rows >= 0) & (rows < n))


def _score_t(wc_blk, wr_blk, inv_tau, cd):
    # (Bc, 1) x (1, Br) -> (Bc, Br) transposed L1 scores, scaled.
    # Same precision contract as ``_score``: f32 keys, score rounded to
    # the compute dtype, f32 out for the softmax stats.
    s = -jnp.abs(wc_blk - wr_blk) * inv_tau
    return s.astype(cd).astype(jnp.float32)


def _fwd_band_kernel(wr_ref, wc_ref, xt_ref, tau_ref, y_ref, m_ref, l_ref,
                     acc_ref, *, n: int, k: int, blk: int, off: int,
                     nbj: int, cd):
    i = pl.program_id(1)
    jj = pl.program_id(2)
    u = i - off + jj                              # unclipped column block
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(i, u, blk, k, n)
    s = jnp.where(mask, _score_t(wc_ref[...], wr_ref[...], inv_tau, cd),
                  NEG_INF)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev = m_ref[...]                                        # (1, Br)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=0, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    # The explicit mask (not just exp(s - m)) keeps a fully-masked block
    # exact: there m_new stays NEG_INF and exp(s - m_new) would be
    # exp(0) = 1 per masked slot.
    p_un = jnp.where(mask, jnp.exp(s - m_new), 0.0)            # (Bc, Br)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        p_un, axis=0, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
        xt_ref[...], p_un.astype(cd),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (dsub, Br)

    @pl.when(jj == nbj - 1)
    def _normalize():
        y_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(y_ref.dtype)


def _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask, cd):
    """Exact normalized transposed P~ block from the saved stats, fully
    masked (band + padding + clipped edge blocks) so garbage stats on
    masked rows can never leak.  Scores quantized exactly as the
    forward's."""
    s = jnp.where(mask, _score_t(wc_ref[...], wr_ref[...], inv_tau, cd),
                  NEG_INF)
    p = jnp.where(mask, jnp.exp(s - m_ref[...])
                  / jnp.maximum(l_ref[...], 1e-30), 0.0)
    return s, p


def _colsum_band_kernel(wr_ref, wc_ref, tau_ref, m_ref, l_ref, c_ref,
                        *, n: int, k: int, blk: int, off: int, cd):
    # Grid (B, Nj, nbi): column block j outer, band row step ii inner so
    # the (Bc, 1) colsum block accumulates in VMEM.
    j = pl.program_id(1)
    ii = pl.program_id(2)
    iu = j - off + ii                             # unclipped row block
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(iu, j, blk, k, n)
    _, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask, cd)

    @pl.when(ii == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.sum(p, axis=1, keepdims=True)


def softsort_apply_fwd_banded_pallas(
    wr: jnp.ndarray,      # (B, 1, Np) sorted keys (matrix rows), f32
    wc: jnp.ndarray,      # (B, Np, 1) sorted keys (matrix cols), f32
    xt: jnp.ndarray,      # (B, dsub, Np) payload, sorted + transposed, cd
    tau: jnp.ndarray,     # (1, 1) — shared across the batch
    *,
    n: int,               # true length
    k: int,               # band half-width in rank space
    blk: int,             # square block edge (multiple of 128)
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Banded forward: (y_t (B, dsub, Np) in the compute dtype, colsum
    (B, Np, 1) in rank order, m, l (B, 1, Np) f32).  Two
    ``pallas_call``s over (N/blk) * nbj grids instead of (N/blk)^2."""
    bsz, dsub, np_ = xt.shape
    ni = np_ // blk
    off = -(-k // blk)
    nbj = 2 * off + 1
    f32 = jnp.float32
    cd = xt.dtype

    def _col(b, i, jj):
        return jnp.clip(i - off + jj, 0, ni - 1)

    y_t, m, l = pl.pallas_call(
        functools.partial(_fwd_band_kernel, n=n, k=k, blk=blk, off=off,
                          nbj=nbj, cd=cd),
        grid=(bsz, ni, nbj),
        in_specs=[
            pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i)),  # wr
            pl.BlockSpec((None, blk, 1),
                         lambda b, i, jj: (b, _col(b, i, jj), 0)),     # wc
            pl.BlockSpec((None, dsub, blk),
                         lambda b, i, jj: (b, 0, _col(b, i, jj))),     # xt
            pl.BlockSpec((1, 1), lambda b, i, jj: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, dsub, blk), lambda b, i, jj: (b, 0, i)),
            pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i)),  # m
            pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i)),  # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, dsub, np_), cd),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        ],
        scratch_shapes=[pltpu.VMEM((dsub, blk), f32)],     # y accumulator
        interpret=interpret,
    )(wr, wc, xt, tau)

    colsum = pl.pallas_call(
        functools.partial(_colsum_band_kernel, n=n, k=k, blk=blk, off=off,
                          cd=cd),
        grid=(bsz, ni, nbj),
        in_specs=[
            pl.BlockSpec((None, 1, blk),
                         lambda b, j, ii: (b, 0, _col(b, j, ii))),     # wr
            pl.BlockSpec((None, blk, 1), lambda b, j, ii: (b, j, 0)),  # wc
            pl.BlockSpec((1, 1), lambda b, j, ii: (0, 0)),             # tau
            pl.BlockSpec((None, 1, blk),
                         lambda b, j, ii: (b, 0, _col(b, j, ii))),     # m
            pl.BlockSpec((None, 1, blk),
                         lambda b, j, ii: (b, 0, _col(b, j, ii))),     # l
        ],
        out_specs=pl.BlockSpec((None, blk, 1), lambda b, j, ii: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        interpret=interpret,
    )(wr, wc, tau, m, l)

    return y_t, colsum, m, l


def _bwd_band_dws_delta_kernel(wr_ref, wc_ref, xt_ref, tau_ref, m_ref,
                               l_ref, dyt_ref, yt_ref, dc_ref, d_ref,
                               dws_ref, a_ref, srow_ref,
                               *, n: int, k: int, blk: int, off: int,
                               nbj: int, cd):
    """Fused delta + dws_row band sweep (the banded 3->2 merge), row
    grid (B, Ni, nbj), everything in the (Bc, Br) transposed layout:

      D_i = dy_i . y_i + sum_{r in band} P~_ir dc~_r   (delta, emitted)
      A_i = sum_r P~_ir dP~_ir sgn_ir                  (f32 scratch)
      S_i = sum_r P~_ir sgn_ir                         (f32 scratch)

    combined at the last band block into
      dws_row_i = -(A_i - D_i * S_i) / tau
    — one band re-score instead of the previous delta + dws pair."""
    i = pl.program_id(1)
    jj = pl.program_id(2)
    u = i - off + jj
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(i, u, blk, k, n)
    _, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask, cd)
    # dP~_ir = dy_i . xs_r + dc~_r, in (Bc, Br) transposed layout.
    dp = jax.lax.dot_general(
        xt_ref[...], dyt_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    sgn = jnp.sign(wr_ref[...] - wc_ref[...])

    @pl.when(jj == 0)
    def _init():
        d_ref[...] = jnp.sum(dyt_ref[...].astype(jnp.float32)
                             * yt_ref[...].astype(jnp.float32),
                             axis=0, keepdims=True)
        a_ref[...] = jnp.zeros_like(a_ref)
        srow_ref[...] = jnp.zeros_like(srow_ref)

    d_ref[...] += jax.lax.dot_general(
        dc_ref[...], p.astype(cd),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (1, Br)
    a_ref[...] += jnp.sum(p * dp * sgn, axis=0, keepdims=True)
    srow_ref[...] += jnp.sum(p * sgn, axis=0, keepdims=True)

    @pl.when(jj == nbj - 1)
    def _combine():
        dws_ref[...] = -(a_ref[...] - d_ref[...] * srow_ref[...]) \
            * inv_tau


def _bwd_band_dcol_kernel(wr_ref, wc_ref, xt_ref, tau_ref, m_ref, l_ref,
                          dyt_ref, dc_ref, d_ref, dxt_ref, dwc_ref, dtc_ref,
                          acc_ref, *, n: int, k: int, blk: int, off: int,
                          nbj: int, cd):
    """Column grid (B, Nj, nbi): per column block accumulate
    dxs_t_r = sum_i P~_ir dy_i (f32 scratch, written once in the
    compute dtype), dws_col_r = sum_i ds_ir sgn_ir / tau, and the
    per-column dtau partial."""
    j = pl.program_id(1)
    ii = pl.program_id(2)
    iu = j - off + ii
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(iu, j, blk, k, n)
    s, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask, cd)
    # dP~_ir = dy_i . xs_r + dc~_r, in (Bc, Br) transposed layout.
    dp = jax.lax.dot_general(
        xt_ref[...], dyt_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])                                 # (Bc, Br)
    sgn = jnp.sign(wr_ref[...] - wc_ref[...])                  # ws_i - ws_r

    @pl.when(ii == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        dwc_ref[...] = jnp.zeros_like(dwc_ref)
        dtc_ref[...] = jnp.zeros_like(dtc_ref)

    acc_ref[...] += jax.lax.dot_general(
        dyt_ref[...], p.astype(cd),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (dsub, Bc)
    dwc_ref[...] += jnp.sum(ds * sgn, axis=1, keepdims=True) * inv_tau
    # ds == 0 exactly on masked slots and NEG_INF is finite, so the
    # 0 * (-NEG_INF) products below are exact zeros.
    dtc_ref[...] += jnp.sum(ds * (-s), axis=1, keepdims=True) * inv_tau

    @pl.when(ii == nbj - 1)
    def _flush():
        dxt_ref[...] = acc_ref[...].astype(dxt_ref.dtype)


def softsort_apply_bwd_banded_pallas(
    wr: jnp.ndarray,      # (B, 1, Np) sorted keys (rows), padded, f32
    wc: jnp.ndarray,      # (B, Np, 1) sorted keys (cols), padded, f32
    xt: jnp.ndarray,      # (B, dsub, Np) payload, sorted + transposed, cd
    tau: jnp.ndarray,     # (1, 1)
    m: jnp.ndarray,       # (B, 1, Np) saved row maxes, f32
    l: jnp.ndarray,       # (B, 1, Np) saved row denominators, f32
    yt: jnp.ndarray,      # (B, dsub, Np) saved forward output, transposed, cd
    dyt: jnp.ndarray,     # (B, dsub, Np) cotangent of y, transposed, cd
    dc: jnp.ndarray,      # (B, Np, 1) cotangent of colsum, rank order, cd
    *,
    n: int,
    k: int,
    blk: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Banded backward from saved residuals — TWO band-grid passes.

    Pass 1 (row band grid) fuses the old delta pass into the dws_row
    pass: one band sweep emits D (consumed by pass 2) AND dws_row.
    Pass 2 (column band grid) produces the column-indexed dxs_t /
    dws_col / dtau_cols.

    Returns (dws_row (B, 1, Np), dws_col (B, Np, 1) — the key gradient's
    row and column components, both f32 and in RANK order, summed and
    scattered through ``perm`` by the caller; dxs_t (B, dsub, Np) —
    payload gradient in rank order, transposed, in the compute dtype;
    dtau_cols (B, Np, 1) f32)."""
    bsz, dsub, np_ = xt.shape
    ni = np_ // blk
    off = -(-k // blk)
    nbj = 2 * off + 1
    f32 = jnp.float32
    cd = xt.dtype

    def _col(b, i, jj):
        return jnp.clip(i - off + jj, 0, ni - 1)

    # Row-aligned operand specs (row grid: i outer, jj band step inner).
    row_keys = pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i))
    row_pay = pl.BlockSpec((None, dsub, blk), lambda b, i, jj: (b, 0, i))
    band_cols = pl.BlockSpec((None, blk, 1),
                             lambda b, i, jj: (b, _col(b, i, jj), 0))
    band_pay = pl.BlockSpec((None, dsub, blk),
                            lambda b, i, jj: (b, 0, _col(b, i, jj)))
    band_keys = pl.BlockSpec((None, 1, blk),
                             lambda b, i, jj: (b, 0, _col(b, i, jj)))
    tau_spec = pl.BlockSpec((1, 1), lambda b, i, jj: (0, 0))

    # Fused delta+dws_row band sweep; A/S partial sums in f32 scratch.
    delta, dws_row = pl.pallas_call(
        functools.partial(_bwd_band_dws_delta_kernel, n=n, k=k, blk=blk,
                          off=off, nbj=nbj, cd=cd),
        grid=(bsz, ni, nbj),
        in_specs=[row_keys, band_cols, band_pay, tau_spec, row_keys,
                  row_keys, row_pay, row_pay, band_cols],
        out_specs=[row_keys, row_keys],                    # D, dws_row
        out_shape=[
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        ],
        scratch_shapes=[pltpu.VMEM((1, blk), f32),         # A
                        pltpu.VMEM((1, blk), f32)],        # S
        interpret=interpret,
    )(wr, wc, xt, tau, m, l, dyt, yt, dc)

    # Column grid (j outer, band row step inner): the column-indexed
    # outputs (dxs_t via scratch, dws_col, dtau_cols) accumulate in VMEM.
    col_keys = pl.BlockSpec((None, blk, 1), lambda b, j, ii: (b, j, 0))
    col_pay = pl.BlockSpec((None, dsub, blk), lambda b, j, ii: (b, 0, j))
    dxt, dwc, dtc = pl.pallas_call(
        functools.partial(_bwd_band_dcol_kernel, n=n, k=k, blk=blk,
                          off=off, nbj=nbj, cd=cd),
        grid=(bsz, ni, nbj),
        in_specs=[band_keys, col_keys, col_pay, tau_spec, band_keys,
                  band_keys, band_pay, col_keys, band_keys],
        out_specs=[col_pay, col_keys, col_keys],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, dsub, np_), cd),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        scratch_shapes=[pltpu.VMEM((dsub, blk), f32)],     # dxt accumulator
        interpret=interpret,
    )(wr, wc, xt, tau, m, l, dyt, dc, delta)

    return dws_row, dwc, dxt, dtc


# --------------------------------------------------------------------------
# v1 split forward (stats + apply + colsum, three pallas_calls) — kept as
# the measured baseline for benchmarks/kernel_bench.py.  Not used by the
# production path; f32 only.
# --------------------------------------------------------------------------

def _stats_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau, jnp.float32)  # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        jnp.exp(s - m_new), axis=-1, keepdims=True)
    m_ref[...] = m_new


def _apply_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, y_ref,
                  *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau, jnp.float32)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(p, x_ref[...], preferred_element_type=jnp.float32)


def softsort_apply_fwd_pallas_v1(
    ws: jnp.ndarray,
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    n: int,
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """v1 baseline forward: three passes (stats, apply, colsum), scores
    computed three times, m/l round-tripping through HBM between passes.
    Returns (y (B, Np, dp), colsum (B, 1, Np))."""
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(ws, w, tau)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, np_, dp), f32),
        interpret=interpret,
    )(ws, w, x, tau, m, l)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc,
                          cd=jnp.float32),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum
