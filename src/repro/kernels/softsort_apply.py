"""Fused flash-style SoftSort-apply Pallas TPU kernels (batched, fwd + bwd).

Computes, without ever materializing the (N, N) soft permutation matrix,
for every instance b of a leading batch axis:

    P[b]_ij   = softmax_j( -|sort(w[b])_i - w[b]_j| / tau )
    y[b]      = P[b] @ x[b]          (B, N, d)
    colsum[b] = sum_i P[b]_ij        (B, N)

Structure is exactly flash attention with an L1-distance score and the
sorted keys playing the role of queries.

Forward — ONE online-softmax sweep (FlashAttention-2 style) plus the
colsum reduction, two ``pallas_call``s total, so the score block is
computed exactly twice and the softmax stats never round-trip to HBM
mid-forward:

  * ``_fwd_fused_kernel`` — streaming row max ``m``, denominator ``l``
    AND the un-normalized y accumulator in one pass (grid = (B, Ni, Nj),
    j innermost; the m/l/y output blocks are revisited consecutively so
    they live in VMEM as accumulators — the TPU sequential-grid idiom).
    Each column block rescales the running y by ``exp(m_prev - m_new)``;
    the final ``1/l`` is applied once at the last column block.  ``m``
    and ``l`` are kernel *outputs*: the backward reuses them as
    residuals instead of re-deriving the softmax.
  * ``_colsum_kernel``    — exact P block = exp(s - m)/l with the i/j
    grid axes transposed (j outer, i inner) so the colsum block
    accumulates over row blocks.

Backward — three Pallas passes driven by the ``custom_vjp`` in
``repro.kernels.ops``, which saves ``(perm, m, l, y)`` from the
forward so no pass re-sorts or re-normalizes.  With
``dP_ij = dy_i . x_j + dc_j`` and ``ds = P * (dP - D)`` where
``D_i = sum_j P_ij dP_ij``:

  * ``_bwd_delta_kernel`` — row grid: ``D_i = dy_i . y_i + (P @ dc)_i``
    (the first term is flash attention's delta trick — ``sum_j P_ij
    (dy_i . x_j) = dy_i . y_i`` because y was saved; only the colsum
    cotangent needs a streamed ``P @ dc``).
  * ``_bwd_dx_kernel``    — transposed grid (j outer, i inner):
    ``dx_j = sum_i P_ij dy_i`` (a (Bc, Br) x (Br, d) MXU contraction),
    plus the column-indexed reductions ``dw_cols_j = sum_i ds_ij
    sgn_ij / tau`` and a per-column ``dtau`` partial.
  * ``_bwd_dws_kernel``   — row grid: ``dws_i = -sum_j ds_ij sgn_ij
    / tau`` (scattered back through ``perm`` by the wrapper).

No (B, chunk, N) ``delta``/``p``/``dp``/``ds`` temporaries ever touch
HBM — every score/probability block is consumed inside its VMEM tile.

The batch axis is the OUTERMOST grid dimension: each instance is an
independent sweep over its own (Ni, Nj) tile space, so the accumulator
idiom above is untouched — b changes only after an instance's tiles are
exhausted.  Instances share one scalar ``tau`` (the trainer anneals a
single schedule across the whole batch).  The batch block size is
``None`` (squeezed), so the kernels themselves see 2-D blocks.

VMEM working set per step ~ Br*Bc (scores) + Bc*d (x block) + Br*d
(y/dy blocks) floats; with the default Br = Bc = 256, d <= 512 this is
well under the ~16 MB/core budget and independent of B.  Block shapes
are (8k, 128m)-aligned so the MXU sees aligned contractions.

All kernels mask columns >= n (true length) with -inf scores and rows
>= n out of every column-indexed reduction, so the wrapper may pad N up
to block multiples with arbitrary finite values.  ``tau`` arrives as a
(1, 1) array so it can be a traced value inside jit without
retriggering compilation.

The v1 split forward (separate stats + apply passes, three
``pallas_call``s) is kept at the bottom as the benchmark baseline for
``benchmarks/kernel_bench.py`` — it is what PR 1/2 shipped, and the
fused-vs-v1 rows in BENCH_kernels.json quantify the win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _score(ws_blk, w_blk, inv_tau):
    # (Br, 1) x (1, Bc) -> (Br, Bc) L1 scores, scaled.
    return -jnp.abs(ws_blk - w_blk) * inv_tau


def _col_mask(j, bc, n):
    col_ids = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    return col_ids < n


def _row_mask(i, br, n):
    row_ids = i * br + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
    return row_ids < n


# --------------------------------------------------------------------------
# Forward: fused online-softmax sweep + colsum.
# --------------------------------------------------------------------------

def _fwd_fused_kernel(ws_ref, w_ref, x_ref, tau_ref, y_ref, m_ref, l_ref,
                      *, n: int, bc: int, nj: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)               # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        y_ref[...] = jnp.zeros_like(y_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    p_un = jnp.exp(s - m_new)                                  # un-normalized
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        p_un, axis=-1, keepdims=True)
    m_ref[...] = m_new
    y_ref[...] = y_ref[...] * correction + jnp.dot(
        p_un, x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _normalize():
        y_ref[...] = y_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _colsum_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, c_ref,
                   *, n: int, br: int, bc: int):
    # Grid is (B, Nj, Ni): i innermost so the c block accumulates in VMEM.
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)                 # mask pad rows

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.sum(p, axis=0, keepdims=True)


def softsort_apply_fwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded
    x: jnp.ndarray,       # (B, Np, dp) payload, padded
    tau: jnp.ndarray,     # (1, 1) — shared across the batch
    *,
    n: int,               # true length
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused forward: (y (B, Np, dp), colsum (B, 1, Np), m, l (B, Np, 1)).

    Two ``pallas_call``s: the fused online-softmax sweep and the
    transposed-grid colsum reduction.  ``m``/``l`` are returned so the
    backward can reuse them as residuals.
    """
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    y, m, l = pl.pallas_call(
        functools.partial(_fwd_fused_kernel, n=n, bc=bc, nj=nj),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # y
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, dp), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(ws, w, x, tau)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum, m, l


# --------------------------------------------------------------------------
# Backward: three Pallas passes over the saved residuals.
# --------------------------------------------------------------------------

def _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n):
    """Exact normalized P block from the saved softmax stats (no re-max,
    no re-sum) — the residual-reuse core of the backward."""
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)
    return s, p


def _bwd_delta_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, dy_ref, y_ref,
                      dc_ref, d_ref, *, n: int, bc: int):
    """D_i = dy_i . y_i + sum_j P_ij dc_j, streamed over column blocks."""
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    _, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = jnp.sum(dy_ref[...] * y_ref[...], axis=-1,
                             keepdims=True)

    d_ref[...] += jax.lax.dot_general(
        p, dc_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def _bwd_dx_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, dy_ref,
                   dc_ref, d_ref, dx_ref, dwc_ref, dtc_ref,
                   *, n: int, br: int, bc: int):
    """Transposed grid (B, Nj, Ni): per column block accumulate
    dx_j = P^T @ dy, dw_cols_j = sum_i ds * sgn / tau, and the
    per-column dtau partial sum_i ds * (-s) / tau."""
    j = pl.program_id(1)
    i = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n)
    p = jnp.where(_row_mask(i, br, n), p, 0.0)      # pad rows are not rows of P
    # dP_ij = dy_i . x_j + dc_j
    dp = jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])                                  # (Br, Bc)
    sgn = jnp.sign(ws_ref[...] - w_ref[...])

    @pl.when(i == 0)
    def _init():
        dx_ref[...] = jnp.zeros_like(dx_ref)
        dwc_ref[...] = jnp.zeros_like(dwc_ref)
        dtc_ref[...] = jnp.zeros_like(dtc_ref)

    dx_ref[...] += jax.lax.dot_general(
        p, dy_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (Bc, dp)
    dwc_ref[...] += jnp.sum(ds * sgn, axis=0, keepdims=True) * inv_tau
    # s = -|delta|/tau  =>  d s / d tau = -s / tau; masked cols have
    # ds == 0 exactly, and NEG_INF is finite, so 0 * (-NEG_INF) == 0.
    dtc_ref[...] += jnp.sum(ds * (-s), axis=0, keepdims=True) * inv_tau


def _bwd_dws_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, dy_ref,
                    dc_ref, d_ref, dws_ref, *, n: int, bc: int):
    """Row grid (B, Ni, Nj): dws_i = -sum_j ds_ij * sgn_ij / tau."""
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    _, p = _p_block(ws_ref, w_ref, m_ref, l_ref, inv_tau, j, bc, n)
    dp = jax.lax.dot_general(
        dy_ref[...], x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])
    sgn = jnp.sign(ws_ref[...] - w_ref[...])

    @pl.when(j == 0)
    def _init():
        dws_ref[...] = jnp.zeros_like(dws_ref)

    dws_ref[...] += jnp.sum(ds * (-sgn), axis=-1, keepdims=True) * inv_tau


def softsort_apply_bwd_pallas(
    ws: jnp.ndarray,      # (B, Np, 1) sorted keys (rows), padded
    w: jnp.ndarray,       # (B, 1, Np) unsorted keys (cols), padded
    x: jnp.ndarray,       # (B, Np, dp) payload, padded
    tau: jnp.ndarray,     # (1, 1)
    m: jnp.ndarray,       # (B, Np, 1) saved row maxes
    l: jnp.ndarray,       # (B, Np, 1) saved row denominators
    y: jnp.ndarray,       # (B, Np, dp) saved forward output
    dy: jnp.ndarray,      # (B, Np, dp) cotangent of y (pad rows zero)
    dc: jnp.ndarray,      # (B, 1, Np) cotangent of colsum (pad cols zero)
    *,
    n: int,
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused backward from saved residuals.

    Returns (dws (B, Np, 1) — gradient w.r.t. the SORTED keys, to be
    scattered through ``perm`` by the caller; dw_cols (B, 1, Np);
    dx (B, Np, dp); dtau_cols (B, 1, Np) — per-column dtau partials,
    summed to a scalar by the caller).
    """
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    row_spec = pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0))
    col_spec = pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j))
    tau_spec = pl.BlockSpec((1, 1), lambda b, i, j: (0, 0))

    delta = pl.pallas_call(
        functools.partial(_bwd_delta_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            row_spec,                                                 # ws
            col_spec,                                                 # w
            tau_spec,                                                 # tau
            row_spec,                                                 # m
            row_spec,                                                 # l
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # dy
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # y
            col_spec,                                                 # dc
        ],
        out_specs=row_spec,                                           # D
        out_shape=jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        interpret=interpret,
    )(ws, w, tau, m, l, dy, y, dc)

    # Transposed grid: j outer, i inner, so the column-indexed outputs
    # (dx, dw_cols, dtau_cols) accumulate in VMEM.
    dx, dw_cols, dtau_cols = pl.pallas_call(
        functools.partial(_bwd_dx_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, j, i: (b, j, 0)),  # x
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
            pl.BlockSpec((None, br, dp), lambda b, j, i: (b, i, 0)),  # dy
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dc
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # D
        ],
        out_specs=[
            pl.BlockSpec((None, bc, dp), lambda b, j, i: (b, j, 0)),  # dx
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dw_cols
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # dtau
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, dp), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        ],
        interpret=interpret,
    )(ws, w, x, tau, m, l, dy, dc, delta)

    dws = pl.pallas_call(
        functools.partial(_bwd_dws_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            row_spec,                                                 # ws
            col_spec,                                                 # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x
            tau_spec,                                                 # tau
            row_spec,                                                 # m
            row_spec,                                                 # l
            pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),  # dy
            col_spec,                                                 # dc
            row_spec,                                                 # D
        ],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        interpret=interpret,
    )(ws, w, x, tau, m, l, dy, dc, delta)

    return dws, dw_cols, dx, dtau_cols


# --------------------------------------------------------------------------
# Banded tier: O(N * K) windowed kernels in sorted-rank coordinates.
#
# The wrapper (ops.softsort_apply_banded) gathers BOTH matrix axes into
# sorted-key order, so the soft permutation matrix P~ is diagonally
# dominant in rank space and only the width-(2K+1) band around the
# diagonal is scored — out-of-band entries are treated as exactly zero
# (neglected mass bounded by core.softsort.band_tail_bound).  Each row
# block i therefore touches only the nbj = 2*ceil(K/blk) + 1 column
# blocks u = i - off .. i + off, shrinking the grid from (N/blk)^2 to
# (N/blk) * nbj cells per pass; edge blocks clip their index map into
# range and mask themselves out entirely.
#
# Two layout changes vs the dense kernels above, both HBM-traffic wins
# at the paper's small payload widths (d = 3..50):
#
#   * scores live TRANSPOSED, (bc, br) with matrix columns on sublanes
#     and rows on lanes, so the running softmax stats m/l are (1, br)
#     lane vectors and every reduction stays a lane-wise op;
#   * the payload is carried transposed, (dsub, Np) with dsub =
#     round_up(d, 8) on SUBLANES — padding d to the 8-sublane quantum
#     instead of the 128-lane quantum cuts payload blocks 16x at d = 8
#     (the (bc, d) @ -> y contraction becomes x_t @ p_un on the MXU).
#
# Same online-softmax + residual-saving custom_vjp structure as the
# fused dense tier: one forward sweep emitting (y_t, m, l), a
# transposed-grid colsum, and three backward passes (delta, column-
# indexed dx/dw/dtau, row-indexed dws).  Because both axes are sorted,
# the key gradient has a row AND a column component here — the wrapper
# sums them before scattering through the saved perm.
# --------------------------------------------------------------------------


def _band_mask(i, u, blk: int, k: int, n: int):
    """(bc, br) validity of a banded score block: |rank_col - rank_row|
    <= K, both ranks real (not padding), both block ids in range (a
    clipped edge block computes its UNCLIPPED ids here, so it masks
    itself out entirely instead of double-counting the block it was
    clamped onto)."""
    rows = i * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    cols = u * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, 1), 0)
    return ((jnp.abs(cols - rows) <= k)
            & (cols >= 0) & (cols < n) & (rows >= 0) & (rows < n))


def _score_t(wc_blk, wr_blk, inv_tau):
    # (Bc, 1) x (1, Br) -> (Bc, Br) transposed L1 scores, scaled.
    return -jnp.abs(wc_blk - wr_blk) * inv_tau


def _fwd_band_kernel(wr_ref, wc_ref, xt_ref, tau_ref, y_ref, m_ref, l_ref,
                     *, n: int, k: int, blk: int, off: int, nbj: int):
    i = pl.program_id(1)
    jj = pl.program_id(2)
    u = i - off + jj                              # unclipped column block
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(i, u, blk, k, n)
    s = jnp.where(mask, _score_t(wc_ref[...], wr_ref[...], inv_tau),
                  NEG_INF)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        y_ref[...] = jnp.zeros_like(y_ref)

    m_prev = m_ref[...]                                        # (1, Br)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=0, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    # The explicit mask (not just exp(s - m)) keeps a fully-masked block
    # exact: there m_new stays NEG_INF and exp(s - m_new) would be
    # exp(0) = 1 per masked slot.
    p_un = jnp.where(mask, jnp.exp(s - m_new), 0.0)            # (Bc, Br)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        p_un, axis=0, keepdims=True)
    m_ref[...] = m_new
    y_ref[...] = y_ref[...] * correction + jax.lax.dot_general(
        xt_ref[...], p_un,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (dsub, Br)

    @pl.when(jj == nbj - 1)
    def _normalize():
        y_ref[...] = y_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask):
    """Exact normalized transposed P~ block from the saved stats, fully
    masked (band + padding + clipped edge blocks) so garbage stats on
    masked rows can never leak."""
    s = jnp.where(mask, _score_t(wc_ref[...], wr_ref[...], inv_tau),
                  NEG_INF)
    p = jnp.where(mask, jnp.exp(s - m_ref[...])
                  / jnp.maximum(l_ref[...], 1e-30), 0.0)
    return s, p


def _colsum_band_kernel(wr_ref, wc_ref, tau_ref, m_ref, l_ref, c_ref,
                        *, n: int, k: int, blk: int, off: int):
    # Grid (B, Nj, nbi): column block j outer, band row step ii inner so
    # the (Bc, 1) colsum block accumulates in VMEM.
    j = pl.program_id(1)
    ii = pl.program_id(2)
    iu = j - off + ii                             # unclipped row block
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(iu, j, blk, k, n)
    _, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask)

    @pl.when(ii == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += jnp.sum(p, axis=1, keepdims=True)


def softsort_apply_fwd_banded_pallas(
    wr: jnp.ndarray,      # (B, 1, Np) sorted keys (matrix rows), padded
    wc: jnp.ndarray,      # (B, Np, 1) sorted keys (matrix cols), padded
    xt: jnp.ndarray,      # (B, dsub, Np) payload, sorted + transposed
    tau: jnp.ndarray,     # (1, 1) — shared across the batch
    *,
    n: int,               # true length
    k: int,               # band half-width in rank space
    blk: int,             # square block edge (multiple of 128)
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Banded forward: (y_t (B, dsub, Np), colsum (B, Np, 1) in rank
    order, m, l (B, 1, Np)).  Two ``pallas_call``s over (N/blk) * nbj
    grids instead of (N/blk)^2."""
    bsz, dsub, np_ = xt.shape
    ni = np_ // blk
    off = -(-k // blk)
    nbj = 2 * off + 1
    f32 = jnp.float32

    def _col(b, i, jj):
        return jnp.clip(i - off + jj, 0, ni - 1)

    y_t, m, l = pl.pallas_call(
        functools.partial(_fwd_band_kernel, n=n, k=k, blk=blk, off=off,
                          nbj=nbj),
        grid=(bsz, ni, nbj),
        in_specs=[
            pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i)),  # wr
            pl.BlockSpec((None, blk, 1),
                         lambda b, i, jj: (b, _col(b, i, jj), 0)),     # wc
            pl.BlockSpec((None, dsub, blk),
                         lambda b, i, jj: (b, 0, _col(b, i, jj))),     # xt
            pl.BlockSpec((1, 1), lambda b, i, jj: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, dsub, blk), lambda b, i, jj: (b, 0, i)),
            pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i)),  # m
            pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i)),  # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, dsub, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
            jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        ],
        interpret=interpret,
    )(wr, wc, xt, tau)

    colsum = pl.pallas_call(
        functools.partial(_colsum_band_kernel, n=n, k=k, blk=blk, off=off),
        grid=(bsz, ni, nbj),
        in_specs=[
            pl.BlockSpec((None, 1, blk),
                         lambda b, j, ii: (b, 0, _col(b, j, ii))),     # wr
            pl.BlockSpec((None, blk, 1), lambda b, j, ii: (b, j, 0)),  # wc
            pl.BlockSpec((1, 1), lambda b, j, ii: (0, 0)),             # tau
            pl.BlockSpec((None, 1, blk),
                         lambda b, j, ii: (b, 0, _col(b, j, ii))),     # m
            pl.BlockSpec((None, 1, blk),
                         lambda b, j, ii: (b, 0, _col(b, j, ii))),     # l
        ],
        out_specs=pl.BlockSpec((None, blk, 1), lambda b, j, ii: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        interpret=interpret,
    )(wr, wc, tau, m, l)

    return y_t, colsum, m, l


def _bwd_band_delta_kernel(wr_ref, wc_ref, tau_ref, m_ref, l_ref, dyt_ref,
                           yt_ref, dc_ref, d_ref,
                           *, n: int, k: int, blk: int, off: int):
    """D_i = dy_i . y_i + sum_{r in band} P~_ir dc~_r, band blocks only."""
    i = pl.program_id(1)
    jj = pl.program_id(2)
    u = i - off + jj
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(i, u, blk, k, n)
    _, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask)

    @pl.when(jj == 0)
    def _init():
        d_ref[...] = jnp.sum(dyt_ref[...] * yt_ref[...], axis=0,
                             keepdims=True)                    # (1, Br)

    d_ref[...] += jax.lax.dot_general(
        dc_ref[...], p,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (1, Br)


def _bwd_band_dcol_kernel(wr_ref, wc_ref, xt_ref, tau_ref, m_ref, l_ref,
                          dyt_ref, dc_ref, d_ref, dxt_ref, dwc_ref, dtc_ref,
                          *, n: int, k: int, blk: int, off: int):
    """Column grid (B, Nj, nbi): per column block accumulate
    dxs_t_r = sum_i P~_ir dy_i, dws_col_r = sum_i ds_ir sgn_ir / tau,
    and the per-column dtau partial."""
    j = pl.program_id(1)
    ii = pl.program_id(2)
    iu = j - off + ii
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(iu, j, blk, k, n)
    s, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask)
    # dP~_ir = dy_i . xs_r + dc~_r, in (Bc, Br) transposed layout.
    dp = jax.lax.dot_general(
        xt_ref[...], dyt_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])                                 # (Bc, Br)
    sgn = jnp.sign(wr_ref[...] - wc_ref[...])                  # ws_i - ws_r

    @pl.when(ii == 0)
    def _init():
        dxt_ref[...] = jnp.zeros_like(dxt_ref)
        dwc_ref[...] = jnp.zeros_like(dwc_ref)
        dtc_ref[...] = jnp.zeros_like(dtc_ref)

    dxt_ref[...] += jax.lax.dot_general(
        dyt_ref[...], p,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                    # (dsub, Bc)
    dwc_ref[...] += jnp.sum(ds * sgn, axis=1, keepdims=True) * inv_tau
    # ds == 0 exactly on masked slots and NEG_INF is finite, so the
    # 0 * (-NEG_INF) products below are exact zeros.
    dtc_ref[...] += jnp.sum(ds * (-s), axis=1, keepdims=True) * inv_tau


def _bwd_band_dws_kernel(wr_ref, wc_ref, xt_ref, tau_ref, m_ref, l_ref,
                         dyt_ref, dc_ref, d_ref, dws_ref,
                         *, n: int, k: int, blk: int, off: int):
    """Row grid (B, Ni, nbj): dws_row_i = -sum_r ds_ir sgn_ir / tau."""
    i = pl.program_id(1)
    jj = pl.program_id(2)
    u = i - off + jj
    inv_tau = 1.0 / tau_ref[0, 0]
    mask = _band_mask(i, u, blk, k, n)
    s, p = _p_band_block(wr_ref, wc_ref, m_ref, l_ref, inv_tau, mask)
    dp = jax.lax.dot_general(
        xt_ref[...], dyt_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) + dc_ref[...]
    ds = p * (dp - d_ref[...])
    sgn = jnp.sign(wr_ref[...] - wc_ref[...])

    @pl.when(jj == 0)
    def _init():
        dws_ref[...] = jnp.zeros_like(dws_ref)

    dws_ref[...] += jnp.sum(ds * (-sgn), axis=0, keepdims=True) * inv_tau


def softsort_apply_bwd_banded_pallas(
    wr: jnp.ndarray,      # (B, 1, Np) sorted keys (rows), padded
    wc: jnp.ndarray,      # (B, Np, 1) sorted keys (cols), padded
    xt: jnp.ndarray,      # (B, dsub, Np) payload, sorted + transposed
    tau: jnp.ndarray,     # (1, 1)
    m: jnp.ndarray,       # (B, 1, Np) saved row maxes
    l: jnp.ndarray,       # (B, 1, Np) saved row denominators
    yt: jnp.ndarray,      # (B, dsub, Np) saved forward output, transposed
    dyt: jnp.ndarray,     # (B, dsub, Np) cotangent of y, transposed
    dc: jnp.ndarray,      # (B, Np, 1) cotangent of colsum, rank order
    *,
    n: int,
    k: int,
    blk: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Banded backward from saved residuals, three band-grid passes.

    Returns (dws_row (B, 1, Np), dws_col (B, Np, 1) — the key gradient's
    row and column components, both in RANK order, summed and scattered
    through ``perm`` by the caller; dxs_t (B, dsub, Np) — payload
    gradient in rank order, transposed; dtau_cols (B, Np, 1))."""
    bsz, dsub, np_ = xt.shape
    ni = np_ // blk
    off = -(-k // blk)
    nbj = 2 * off + 1
    f32 = jnp.float32

    def _col(b, i, jj):
        return jnp.clip(i - off + jj, 0, ni - 1)

    # Row-aligned operand specs (row grid: i outer, jj band step inner).
    row_keys = pl.BlockSpec((None, 1, blk), lambda b, i, jj: (b, 0, i))
    row_pay = pl.BlockSpec((None, dsub, blk), lambda b, i, jj: (b, 0, i))
    band_cols = pl.BlockSpec((None, blk, 1),
                             lambda b, i, jj: (b, _col(b, i, jj), 0))
    band_pay = pl.BlockSpec((None, dsub, blk),
                            lambda b, i, jj: (b, 0, _col(b, i, jj)))
    band_keys = pl.BlockSpec((None, 1, blk),
                             lambda b, i, jj: (b, 0, _col(b, i, jj)))
    tau_spec = pl.BlockSpec((1, 1), lambda b, i, jj: (0, 0))

    delta = pl.pallas_call(
        functools.partial(_bwd_band_delta_kernel, n=n, k=k, blk=blk,
                          off=off),
        grid=(bsz, ni, nbj),
        in_specs=[row_keys, band_cols, tau_spec, row_keys, row_keys,
                  row_pay, row_pay, band_cols],
        out_specs=row_keys,                                    # D
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(wr, wc, tau, m, l, dyt, yt, dc)

    # Column grid (j outer, band row step inner): the column-indexed
    # outputs (dxs_t, dws_col, dtau_cols) accumulate in VMEM.
    col_keys = pl.BlockSpec((None, blk, 1), lambda b, j, ii: (b, j, 0))
    col_pay = pl.BlockSpec((None, dsub, blk), lambda b, j, ii: (b, 0, j))
    dxt, dwc, dtc = pl.pallas_call(
        functools.partial(_bwd_band_dcol_kernel, n=n, k=k, blk=blk,
                          off=off),
        grid=(bsz, ni, nbj),
        in_specs=[band_keys, col_keys, col_pay, tau_spec, band_keys,
                  band_keys, band_pay, col_keys, band_keys],
        out_specs=[col_pay, col_keys, col_keys],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, dsub, np_), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(wr, wc, xt, tau, m, l, dyt, dc, delta)

    dws_row = pl.pallas_call(
        functools.partial(_bwd_band_dws_kernel, n=n, k=k, blk=blk,
                          off=off),
        grid=(bsz, ni, nbj),
        in_specs=[row_keys, band_cols, band_pay, tau_spec, row_keys,
                  row_keys, row_pay, band_cols, row_keys],
        out_specs=row_keys,
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(wr, wc, xt, tau, m, l, dyt, dc, delta)

    return dws_row, dwc, dxt, dtc


# --------------------------------------------------------------------------
# v1 split forward (stats + apply + colsum, three pallas_calls) — kept as
# the measured baseline for benchmarks/kernel_bench.py.  Not used by the
# production path.
# --------------------------------------------------------------------------

def _stats_kernel(ws_ref, w_ref, tau_ref, m_ref, l_ref, *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)               # (Br, Bc)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    m_prev = m_ref[...]                                        # (Br, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * correction + jnp.sum(
        jnp.exp(s - m_new), axis=-1, keepdims=True)
    m_ref[...] = m_new


def _apply_kernel(ws_ref, w_ref, x_ref, tau_ref, m_ref, l_ref, y_ref,
                  *, n: int, bc: int):
    j = pl.program_id(2)
    inv_tau = 1.0 / tau_ref[0, 0]
    s = _score(ws_ref[...], w_ref[...], inv_tau)
    s = jnp.where(_col_mask(j, bc, n), s, NEG_INF)
    p = jnp.exp(s - m_ref[...]) / jnp.maximum(l_ref[...], 1e-30)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(p, x_ref[...], preferred_element_type=jnp.float32)


def softsort_apply_fwd_pallas_v1(
    ws: jnp.ndarray,
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: jnp.ndarray,
    *,
    n: int,
    br: int,
    bc: int,
    interpret: bool,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """v1 baseline forward: three passes (stats, apply, colsum), scores
    computed three times, m/l round-tripping through HBM between passes.
    Returns (y (B, Np, dp), colsum (B, 1, Np))."""
    bsz, np_, dp = x.shape
    ni, nj = np_ // br, np_ // bc
    f32 = jnp.float32

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws rows
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w cols
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
        ],
        out_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
            jax.ShapeDtypeStruct((bsz, np_, 1), f32),
        ],
        interpret=interpret,
    )(ws, w, tau)

    y = pl.pallas_call(
        functools.partial(_apply_kernel, n=n, bc=bc),
        grid=(bsz, ni, nj),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, i, j: (b, 0, j)),   # w
            pl.BlockSpec((None, bc, dp), lambda b, i, j: (b, j, 0)),  # x block
            pl.BlockSpec((1, 1), lambda b, i, j: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, i, j: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, br, dp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, np_, dp), f32),
        interpret=interpret,
    )(ws, w, x, tau, m, l)

    colsum = pl.pallas_call(
        functools.partial(_colsum_kernel, n=n, br=br, bc=bc),
        grid=(bsz, nj, ni),
        in_specs=[
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # ws
            pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),   # w
            pl.BlockSpec((1, 1), lambda b, j, i: (0, 0)),             # tau
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # m
            pl.BlockSpec((None, br, 1), lambda b, j, i: (b, i, 0)),   # l
        ],
        out_specs=pl.BlockSpec((None, 1, bc), lambda b, j, i: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, 1, np_), f32),
        interpret=interpret,
    )(ws, w, tau, m, l)

    return y, colsum
