"""jit'd public wrapper for the fused SoftSort-apply kernel.

``softsort_apply(w, x, tau)`` returns ``(P_soft @ x, column_sums(P_soft))``
in O(N * block) memory with a custom VJP whose backward pass re-streams
the score blocks (flash-attention style recomputation) instead of saving
an N^2 residual.

The forward runs the Pallas TPU kernels from ``softsort_apply.py``
(``interpret=True`` automatically off-TPU); the backward is a chunked
``lax.scan`` in plain jnp — it is bandwidth-bound and XLA fuses it well,
so a hand kernel there would add risk without a roofline win (see
EXPERIMENTS.md §Perf for the measurement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.softsort_apply import softsort_apply_fwd_pallas

_LANE = 128      # TPU lane width: pad d and pick Bc as multiples
_SUBLANE = 8


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def softsort_apply(w, x, tau, block_rows: int = 256, block_cols: int = 256,
                   bwd_chunk: int = 256):
    """Fused (P_soft @ x, colsum(P_soft)); w: (N,), x: (N, d), tau scalar."""
    y, c = _fwd_impl(w, x, tau, block_rows, block_cols)
    return y, c


def _fwd_impl(w, x, tau, block_rows, block_cols):
    n, d = x.shape
    assert w.shape == (n,), (w.shape, n)
    br = min(block_rows, _round_up(n, _SUBLANE))
    bc = min(block_cols, _round_up(n, _LANE))
    np_ = _round_up(n, max(br, bc))
    # Re-derive block sizes that tile the padded length exactly.
    br = min(br, np_)
    bc = min(bc, np_)
    dp = _round_up(d, _LANE)

    perm = jnp.argsort(jax.lax.stop_gradient(w))
    ws = w[perm]

    pad_n = np_ - n
    # Pad rows of ws with increasing finite values (sliced off), cols of w
    # with anything (masked in-kernel), x with zeros.
    ws_p = jnp.pad(ws, (0, pad_n), constant_values=0.0).reshape(np_, 1)
    w_p = jnp.pad(w, (0, pad_n), constant_values=0.0).reshape(1, np_)
    x_p = jnp.pad(x.astype(jnp.float32), ((0, pad_n), (0, dp - d)))
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)

    y_p, c_p = softsort_apply_fwd_pallas(
        ws_p.astype(jnp.float32), w_p.astype(jnp.float32), x_p, tau_arr,
        n=n, br=br, bc=bc, interpret=not _on_tpu())
    return y_p[:n, :d], c_p[0, :n]


def _fwd_rule(w, x, tau, block_rows, block_cols, bwd_chunk):
    y, c = _fwd_impl(w, x, tau, block_rows, block_cols)
    return (y, c), (w, x, jnp.asarray(tau, jnp.float32))


def _bwd_rule(block_rows, block_cols, bwd_chunk, res, cot):
    w, x, tau = res
    dy, dc = cot
    n, d = x.shape
    chunk = min(bwd_chunk, n)
    # Pad the row dimension so chunks tile evenly; padded rows get zero
    # cotangent so they contribute nothing.
    np_ = _round_up(n, chunk)
    pad = np_ - n

    perm = jnp.argsort(jax.lax.stop_gradient(w))
    ws = w[perm]
    big = jnp.max(jax.lax.stop_gradient(ws)) + 1.0 if n else 0.0
    ws_p = jnp.pad(ws, (0, pad), constant_values=big)
    dy_p = jnp.pad(dy.astype(jnp.float32), ((0, pad), (0, 0)))

    row_valid = (jnp.arange(np_) < n).astype(jnp.float32)

    ws_blocks = ws_p.reshape(np_ // chunk, chunk)
    dy_blocks = dy_p.reshape(np_ // chunk, chunk, d)
    valid_blocks = row_valid.reshape(np_ // chunk, chunk)

    xf = x.astype(jnp.float32)
    dcf = dc.astype(jnp.float32)

    def body(carry, blk):
        dws_prev_unused, dw_cols, dx, dtau = carry
        ws_b, dy_b, valid_b = blk              # (chunk,), (chunk, d), (chunk,)
        delta = ws_b[:, None] - w[None, :]     # (chunk, N)
        s = -jnp.abs(delta) / tau
        p = jax.nn.softmax(s, axis=-1)
        # dP_ij = dy_i . x_j + dc_j   (padded rows are not rows of P: mask)
        dp = dy_b @ xf.T + dcf[None, :]        # (chunk, N)
        dsum = jnp.sum(p * dp, axis=-1, keepdims=True)
        ds = p * (dp - dsum) * valid_b[:, None]  # (chunk, N)
        p = p * valid_b[:, None]               # mask dx contribution too
        sgn = jnp.sign(delta)
        dws_b = jnp.sum(ds * (-sgn), axis=-1) / tau       # (chunk,)
        dw_cols = dw_cols + jnp.sum(ds * sgn, axis=0) / tau
        dx = dx + p.T @ dy_b
        dtau = dtau + jnp.sum(ds * (-s)) / tau
        return (dws_prev_unused, dw_cols, dx, dtau), dws_b

    init = (jnp.zeros(()), jnp.zeros_like(w, jnp.float32),
            jnp.zeros_like(xf), jnp.zeros((), jnp.float32))
    (_, dw_cols, dx, dtau), dws_stack = jax.lax.scan(
        body, init, (ws_blocks, dy_blocks, valid_blocks))
    dws = dws_stack.reshape(np_)[:n]
    # Scatter the sorted-row gradient back through the permutation.
    dw = dw_cols.at[perm].add(dws)
    return dw.astype(w.dtype), dx.astype(x.dtype), dtau


softsort_apply.defvjp(_fwd_rule, _bwd_rule)
