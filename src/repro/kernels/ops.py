"""jit'd public wrapper for the fused SoftSort-apply kernel.

``softsort_apply(w, x, tau)`` returns ``(P_soft @ x, column_sums(P_soft))``
in O(N * block) memory with a custom VJP whose backward pass re-streams
the score blocks (flash-attention style recomputation) instead of saving
an N^2 residual.

Shape convention (batched throughput path, used by
``shuffle_soft_sort_batched`` and the serving layer):

  * unbatched — ``w: (N,)``, ``x: (N, d)``  ->  ``y: (N, d)``, ``c: (N,)``
  * batched   — ``w: (B, N)``, ``x: (B, N, d)``  ->  ``y: (B, N, d)``,
    ``c: (B, N)``; every batch instance is an independent SoftSort with
    a shared scalar ``tau``.

Internally everything runs batched: the unbatched call is the B = 1
special case, so there is exactly one kernel code path.  The forward
runs the Pallas TPU kernels from ``softsort_apply.py`` with the batch as
the outermost grid dimension (``interpret=True`` automatically off-TPU);
the backward is a chunked ``lax.scan`` in plain jnp — it is
bandwidth-bound and XLA fuses it well, so a hand kernel there would add
risk without a roofline win (see EXPERIMENTS.md §Perf for the
measurement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.softsort_apply import softsort_apply_fwd_pallas

_LANE = 128      # TPU lane width: pad d and pick Bc as multiples
_SUBLANE = 8


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def softsort_apply(w, x, tau, block_rows: int = 256, block_cols: int = 256,
                   bwd_chunk: int = 256):
    """Fused (P_soft @ x, colsum(P_soft)); w: (N,) or (B, N), tau scalar."""
    y, c = _fwd_impl(w, x, tau, block_rows, block_cols)
    return y, c


def _fwd_impl(w, x, tau, block_rows, block_cols):
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    assert xb.shape == (bsz, n, d), (w.shape, x.shape)
    br = min(block_rows, _round_up(n, _SUBLANE))
    bc = min(block_cols, _round_up(n, _LANE))
    np_ = _round_up(n, max(br, bc))
    # Re-derive block sizes that tile the padded length exactly.
    br = min(br, np_)
    bc = min(bc, np_)
    dp = _round_up(d, _LANE)

    perm = jnp.argsort(jax.lax.stop_gradient(wb), axis=-1)
    ws = jnp.take_along_axis(wb, perm, axis=-1)

    pad_n = np_ - n
    # Pad rows of ws with finite values (masked as rows, sliced off), cols
    # of w with anything (masked in-kernel), x with zeros.
    ws_p = jnp.pad(ws, ((0, 0), (0, pad_n))).reshape(bsz, np_, 1)
    w_p = jnp.pad(wb, ((0, 0), (0, pad_n))).reshape(bsz, 1, np_)
    x_p = jnp.pad(xb.astype(jnp.float32), ((0, 0), (0, pad_n), (0, dp - d)))
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)

    y_p, c_p = softsort_apply_fwd_pallas(
        ws_p.astype(jnp.float32), w_p.astype(jnp.float32), x_p, tau_arr,
        n=n, br=br, bc=bc, interpret=not _on_tpu())
    y, c = y_p[:, :n, :d], c_p[:, 0, :n]
    return (y, c) if batched else (y[0], c[0])


def _fwd_rule(w, x, tau, block_rows, block_cols, bwd_chunk):
    y, c = _fwd_impl(w, x, tau, block_rows, block_cols)
    return (y, c), (w, x, jnp.asarray(tau, jnp.float32))


def _bwd_rule(block_rows, block_cols, bwd_chunk, res, cot):
    w, x, tau = res
    dy, dc = cot
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    dyb = dy if batched else dy[None]
    dcb = dc if batched else dc[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    chunk = min(bwd_chunk, n)
    # Pad the row dimension so chunks tile evenly; padded rows get zero
    # cotangent so they contribute nothing.
    np_ = _round_up(n, chunk)
    pad = np_ - n

    perm = jnp.argsort(jax.lax.stop_gradient(wb), axis=-1)
    ws = jnp.take_along_axis(wb, perm, axis=-1)
    big = jnp.max(jax.lax.stop_gradient(ws)) + 1.0 if n else 0.0
    ws_p = jnp.pad(ws, ((0, 0), (0, pad)), constant_values=big)
    dy_p = jnp.pad(dyb.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))

    row_valid = (jnp.arange(np_) < n).astype(jnp.float32)

    nb = np_ // chunk
    # Scan over row blocks; batch stays a vectorized leading dim inside
    # each step, so peak live memory is O(B * chunk * N).
    ws_blocks = ws_p.reshape(bsz, nb, chunk).transpose(1, 0, 2)
    dy_blocks = dy_p.reshape(bsz, nb, chunk, d).transpose(1, 0, 2, 3)
    valid_blocks = row_valid.reshape(nb, chunk)

    xf = xb.astype(jnp.float32)
    dcf = dcb.astype(jnp.float32)

    def body(carry, blk):
        dw_cols, dx, dtau = carry
        ws_b, dy_b, valid_b = blk      # (B, chunk), (B, chunk, d), (chunk,)
        delta = ws_b[:, :, None] - wb[:, None, :]          # (B, chunk, N)
        s = -jnp.abs(delta) / tau
        p = jax.nn.softmax(s, axis=-1)
        # dP_ij = dy_i . x_j + dc_j   (padded rows are not rows of P: mask)
        dp = jnp.einsum("bcd,bnd->bcn", dy_b, xf) + dcf[:, None, :]
        dsum = jnp.sum(p * dp, axis=-1, keepdims=True)
        ds = p * (dp - dsum) * valid_b[None, :, None]      # (B, chunk, N)
        p = p * valid_b[None, :, None]     # mask dx contribution too
        sgn = jnp.sign(delta)
        dws_b = jnp.sum(ds * (-sgn), axis=-1) / tau        # (B, chunk)
        dw_cols = dw_cols + jnp.sum(ds * sgn, axis=1) / tau
        dx = dx + jnp.einsum("bcn,bcd->bnd", p, dy_b)
        dtau = dtau + jnp.sum(ds * (-s)) / tau
        return (dw_cols, dx, dtau), dws_b

    init = (jnp.zeros_like(wb, jnp.float32), jnp.zeros_like(xf),
            jnp.zeros((), jnp.float32))
    (dw_cols, dx, dtau), dws_stack = jax.lax.scan(
        body, init, (ws_blocks, dy_blocks, valid_blocks))
    dws = dws_stack.transpose(1, 0, 2).reshape(bsz, np_)[:, :n]
    # Scatter the sorted-row gradient back through the permutation.
    dw = dw_cols.at[jnp.arange(bsz)[:, None], perm].add(dws)
    if not batched:
        dw, dx = dw[0], dx[0]
    return dw.astype(w.dtype), dx.astype(x.dtype), dtau


softsort_apply.defvjp(_fwd_rule, _bwd_rule)
