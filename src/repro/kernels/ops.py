"""jit'd public wrappers for the SoftSort-apply kernel tiers.

``softsort_apply(w, x, tau)`` returns ``(P_soft @ x, column_sums(P_soft))``
in O(N * block) memory with a custom VJP that runs BOTH directions in
Pallas.  The forward is one fused online-softmax sweep plus a colsum
reduction (two ``pallas_call``s); it hands ``(perm, m, l, y)`` to
the backward as residuals, so the backward neither re-sorts nor
re-derives the softmax normalizers — it streams TWO Pallas passes
(a fused delta+dws row sweep, then the transposed-grid ``dx = P^T @
dy`` + ``dw``/``dtau`` column reductions) that never materialize a
``(B, chunk, N)`` temporary in HBM.  Exact, but still O(N^2) compute:
every key pair is scored.

Mixed precision (``compute_dtype``): every kernel wrapper accepts
``compute_dtype`` ("float32" default, or "bfloat16").  At bf16 the
payload-sided arrays (x, the dy/dc cotangents, the saved y residual,
and the dx gradient) are cast ONCE here before entering the kernels, so
every payload block fetched from HBM is half the bytes, scores are
rounded to bf16 in-kernel, and every MXU matmul takes bf16 inputs —
while the KEYS stay float32 (they are the paper's N parameters;
quantizing them collapses unit rank gaps into ties above N = 256), as
do the softmax stats, every accumulator (f32 VMEM scratch where the
HBM form is bf16), the (m, l) residuals and the key/tau gradients
(``preferred_element_type=jnp.float32`` everywhere).  The public
forward output and every gradient are returned upcast to the primals'
dtypes, so the trainer's loss and Adam math are untouched f32 whatever
the kernel precision.  Measured parity envelope: EXPERIMENTS.md §Perf.

Block sizes: ``block_rows``/``block_cols``/``block`` default to None,
which consults the committed autotune table
(``repro.kernels.autotune.lookup_blocks`` — per (tier, N, d, K, dtype,
backend) winners from the kernel-bench timing harness) and falls back
to the safe hardcoded 256-square tiling on a miss.  An explicit integer
always wins over the table.

``softsort_apply_banded(w, x, tau, band)`` is the O(N * K) tier on top:
both matrix axes are gathered into sorted-rank order, only the
width-(2K+1) diagonal band is scored (out-of-band mass exactly zero,
analytically bounded by ``core.softsort.band_tail_bound``), and the
payload rides d-on-sublanes so small paper-scale d stops paying the
128-lane pad.  Same custom-VJP structure — band-grid forward sweep +
colsum, two band-grid backward passes over the saved ``(perm, m, l,
y)`` residuals — with the key gradient's row and column components
summed and scattered through the saved permutation.  The engine
dispatcher (``core.shufflesoftsort``) runs dense while tau is hot and
switches to this path once the tail bound clears its epsilon.

See ``repro.kernels.softsort_apply`` for the kernel structure and
EXPERIMENTS.md §Perf for the measured pass-count / HBM traffic wins
(fused-over-v1, and banded-over-fused), which retired the earlier claim
that a hand backward "would add risk without a roofline win": with
residual reuse it is a straight HBM-traffic win.

Shape convention (batched throughput path, used by
``shuffle_soft_sort_batched`` and the serving layer):

  * unbatched — ``w: (N,)``, ``x: (N, d)``  ->  ``y: (N, d)``, ``c: (N,)``
  * batched   — ``w: (B, N)``, ``x: (B, N, d)``  ->  ``y: (B, N, d)``,
    ``c: (B, N)``; every batch instance is an independent SoftSort with
    a shared scalar ``tau``.

Internally everything runs batched: the unbatched call is the B = 1
special case, so there is exactly one kernel code path.  Kernels run
with the batch as the outermost grid dimension (``interpret=True``
automatically off-TPU), which keeps the whole train step — forward AND
backward — on the kernel tier.

``softsort_apply_v1`` preserves the previous design (three forward
passes, chunked ``lax.scan`` jnp backward that re-sorts and re-softmaxes
from scratch) purely as the benchmark baseline for
``benchmarks/kernel_bench.py``; production callers should never use it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.softsort_apply import (
    softsort_apply_bwd_banded_pallas,
    softsort_apply_bwd_pallas,
    softsort_apply_fwd_banded_pallas,
    softsort_apply_fwd_pallas,
    softsort_apply_fwd_pallas_v1,
)

_LANE = 128      # TPU lane width: pad d and pick Bc as multiples
_SUBLANE = 8


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _block_geometry(n: int, d: int, block_rows: int, block_cols: int):
    """Resolve (br, bc, padded N, padded d) exactly as the forward does —
    the backward re-derives the same geometry from the same statics, so
    residual shapes always line up."""
    br = min(block_rows, _round_up(n, _SUBLANE))
    bc = min(block_cols, _round_up(n, _LANE))
    np_ = _round_up(n, max(br, bc))
    # Re-derive block sizes that tile the padded length exactly.
    br = min(br, np_)
    bc = min(bc, np_)
    dp = _round_up(d, _LANE)
    return br, bc, np_, dp


def _cd(compute_dtype) -> jnp.dtype:
    """Resolve the compute-dtype knob (a hashable string on the configs
    and custom_vjp statics) to a jnp dtype, validating the choice."""
    dt = jnp.dtype(compute_dtype)
    assert dt in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)), (
        f"compute_dtype must be float32 or bfloat16, got {compute_dtype}")
    return dt


def _pad_operands(wb, xb, n, np_, dp, perm=None, cd=jnp.float32):
    """Pad (B, N)/(B, N, d) operands to kernel tiles.  Pad rows of ws are
    masked out of every reduction in-kernel, pad cols of w are masked via
    the score mask, x pads with zeros.  Pass the forward's saved ``perm``
    to gather the sorted keys without re-running argsort (the backward
    path).  ``cd`` is the kernel compute dtype: the PAYLOAD is cast
    HERE, once, so at bf16 its HBM blocks are half-width; the keys stay
    f32 (see the kernels' precision contract)."""
    bsz = wb.shape[0]
    d = xb.shape[-1]
    pad_n = np_ - n
    if perm is None:
        perm = jnp.argsort(jax.lax.stop_gradient(wb), axis=-1)
    ws = jnp.take_along_axis(wb, perm, axis=-1)
    ws_p = jnp.pad(ws, ((0, 0), (0, pad_n))).reshape(bsz, np_, 1)
    w_p = jnp.pad(wb, ((0, 0), (0, pad_n))).reshape(bsz, 1, np_)
    x_p = jnp.pad(xb.astype(jnp.float32), ((0, 0), (0, pad_n), (0, dp - d)))
    return (perm, ws_p.astype(jnp.float32), w_p.astype(jnp.float32),
            x_p.astype(cd))


def softsort_apply(w, x, tau, block_rows: int | None = None,
                   block_cols: int | None = None,
                   bwd_chunk: int = 256, descending: bool = False,
                   compute_dtype: str = "float32"):
    """Fused (P_soft @ x, colsum(P_soft)); w: (N,) or (B, N), tau scalar.

    ``block_rows``/``block_cols`` default to None = consult the
    committed autotune table for this (N, d, dtype, backend), falling
    back to the safe 256-square tiling on a miss; an explicit int always
    wins.  ``bwd_chunk`` is accepted for API stability but unused: the
    backward is a Pallas kernel tiled by (block_rows, block_cols), not a
    chunked jnp scan.  ``descending`` matches ``softsort_matrix(...,
    descending=True)``: reversing the sorted keys only reverses the row
    order of P, so it is a flip of y (colsum is row-order invariant) —
    applied outside the custom VJP, where autodiff handles it.
    ``compute_dtype`` ("float32"/"bfloat16") selects the kernel score/
    payload precision — see the module docstring's precision contract.
    """
    if block_rows is None or block_cols is None:
        from repro.kernels.autotune import lookup_blocks
        br_t, bc_t = lookup_blocks(
            "fused", n=w.shape[-1], d=x.shape[-1], dtype=compute_dtype)
        block_rows = block_rows or br_t
        block_cols = block_cols or bc_t
    y, c = _softsort_apply_dense(w, x, tau, block_rows, block_cols,
                                 bwd_chunk, compute_dtype)
    if descending:
        y = jnp.flip(y, axis=-2)
    return y, c


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _softsort_apply_dense(w, x, tau, block_rows: int = 256,
                          block_cols: int = 256, bwd_chunk: int = 256,
                          compute_dtype: str = "float32"):
    (y, c), _ = _fwd_impl(w, x, tau, block_rows, block_cols, compute_dtype)
    return y, c


def _fwd_impl(w, x, tau, block_rows, block_cols, compute_dtype):
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    assert xb.shape == (bsz, n, d), (w.shape, x.shape)
    br, bc, np_, dp = _block_geometry(n, d, block_rows, block_cols)
    perm, ws_p, w_p, x_p = _pad_operands(wb, xb, n, np_, dp,
                                         cd=_cd(compute_dtype))
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)

    y_p, c_p, m, l = softsort_apply_fwd_pallas(
        ws_p, w_p, x_p, tau_arr,
        n=n, br=br, bc=bc, interpret=not _on_tpu())
    # The kernel emits y in the compute dtype; the public output is
    # upcast so downstream loss math stays f32, while the residual
    # keeps the compute-dtype copy (half the residual HBM at bf16).
    y, c = y_p[:, :n, :d], c_p[:, 0, :n]
    y_out = y.astype(jnp.float32)
    out = (y_out, c) if batched else (y_out[0], c[0])
    # The y residual is the SLICED (B, N, d) output, not the lane-padded
    # kernel buffer: dp = round_up(d, 128) would inflate residual HBM by
    # dp/d (16x at the paper's d=8); the backward re-pads it with zeros
    # alongside x for the cost of an O(N d) pad.
    return out, (perm, m, l, y)


def _fwd_rule(w, x, tau, block_rows, block_cols, bwd_chunk, compute_dtype):
    out, (perm, m, l, y) = _fwd_impl(w, x, tau, block_rows, block_cols,
                                     compute_dtype)
    # Residuals: primals plus (perm, m, l, y) — everything the backward
    # needs to skip the argsort and the softmax-stats recomputation.
    return out, (w, x, jnp.asarray(tau, jnp.float32), perm, m, l, y)


def _bwd_rule(block_rows, block_cols, bwd_chunk, compute_dtype, res, cot):
    del bwd_chunk                      # legacy knob of the jnp-scan backward
    w, x, tau, perm, m, l, y = res
    dy, dc = cot
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    yb = y                             # saved in batched (B, N, d) form
    dyb = dy if batched else dy[None]
    dcb = dc if batched else dc[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    br, bc, np_, dp = _block_geometry(n, d, block_rows, block_cols)
    pad_n = np_ - n

    # Same padded operand layout as the forward (the sorted keys are
    # re-gathered through the SAVED perm — a cheap O(B N) gather, no
    # argsort here); cotangent pads are zero so pad slots contribute
    # nothing to any reduction.  Cotangents ride the compute dtype like
    # the payload; the y residual already does (saved straight from the
    # kernel), while m/l and the gradient accumulators stay f32.
    cd = _cd(compute_dtype)
    _, ws_p, w_p, x_p = _pad_operands(wb, xb, n, np_, dp, perm=perm, cd=cd)
    y_p = jnp.pad(yb, ((0, 0), (0, pad_n), (0, dp - d)))
    dy_p = jnp.pad(dyb.astype(jnp.float32),
                   ((0, 0), (0, pad_n), (0, dp - d))).astype(cd)
    dc_p = jnp.pad(dcb.astype(jnp.float32),
                   ((0, 0), (0, pad_n))).reshape(bsz, 1, np_).astype(cd)
    tau_arr = tau.reshape(1, 1)

    dws, dw_cols, dx_p, dtau_cols = softsort_apply_bwd_pallas(
        ws_p, w_p, x_p, tau_arr,
        m, l, y_p, dy_p, dc_p,
        n=n, br=br, bc=bc, interpret=not _on_tpu())

    dws = dws[:, :n, 0]                                  # (B, N) sorted rows
    dw = dw_cols[:, 0, :n]                               # (B, N) column part
    # Scatter the sorted-row gradient back through the saved permutation.
    dw = dw.at[jnp.arange(bsz)[:, None], perm].add(dws)
    dx = dx_p[:, :n, :d]
    dtau = jnp.sum(dtau_cols)
    if not batched:
        dw, dx = dw[0], dx[0]
    return dw.astype(w.dtype), dx.astype(x.dtype), dtau


_softsort_apply_dense.defvjp(_fwd_rule, _bwd_rule)


# --------------------------------------------------------------------------
# Banded tier: O(N * K) windowed apply in sorted-rank coordinates.
# --------------------------------------------------------------------------

def _band_geometry(n: int, d: int, block: int):
    """Resolve (square block edge, padded N, sublane-padded d) for the
    banded kernels — shared by forward and backward so residual shapes
    always line up.  Blocks are square (the band offset arithmetic wants
    one edge length) and 128-aligned; the payload pads d to the 8-row
    SUBLANE quantum instead of the 128-lane quantum because it is
    carried transposed (see kernels docstring)."""
    blk = min(block, _round_up(n, _LANE))
    np_ = _round_up(n, blk)
    dsub = _round_up(max(d, 1), _SUBLANE)
    return blk, np_, dsub


def _band_operands(wb, xb, n, np_, dsub, perm=None, cd=jnp.float32):
    """Gather both matrix axes into sorted-rank order and pad to kernel
    tiles: (perm, wr (B, 1, Np), wc (B, Np, 1), xt (B, dsub, Np)).
    Pad slots are masked in-kernel via the rank bounds, so the pad value
    is irrelevant.  ``cd`` casts the PAYLOAD to the kernel compute
    dtype; the keys stay f32 (see ``_pad_operands``)."""
    bsz, _ = wb.shape
    d = xb.shape[-1]
    pad_n = np_ - n
    if perm is None:
        perm = jnp.argsort(jax.lax.stop_gradient(wb), axis=-1)
    ws = jnp.take_along_axis(wb, perm, axis=-1).astype(jnp.float32)
    xs = jnp.take_along_axis(xb.astype(jnp.float32), perm[..., None],
                             axis=1)
    ws_p = jnp.pad(ws, ((0, 0), (0, pad_n)))
    xt = jnp.pad(xs, ((0, 0), (0, pad_n), (0, dsub - d))).transpose(
        0, 2, 1).astype(cd)
    return (perm, ws_p.reshape(bsz, 1, np_), ws_p.reshape(bsz, np_, 1), xt)


def softsort_apply_banded(w, x, tau, band: int, block: int | None = None,
                          descending: bool = False,
                          compute_dtype: str = "float32"):
    """Banded (P_soft @ x, colsum(P_soft)) in O(N * K) compute and HBM
    traffic; w: (N,) or (B, N), tau scalar, ``band`` = K the static band
    half-width in rank space.

    Kernel twin of ``repro.core.softsort.softsort_apply_banded`` — the
    identical truncated math (out-of-band mass exactly zero, bounded by
    ``core.softsort.band_tail_bound``), with forward AND backward as
    band-grid Pallas passes reusing the fused tier's online-softmax +
    residual-saving custom_vjp design.  ``band >= N - 1`` covers every
    pair, so it delegates to the exact fused dense path.  ``block``
    defaults to None = the autotuned square block edge for this
    (N, d, K, dtype, backend), hardcoded-256 fallback; ``compute_dtype``
    as in ``softsort_apply``.
    """
    n = w.shape[-1]
    band = int(band)
    assert band >= 1, band
    if band >= n - 1:
        return softsort_apply(w, x, tau, descending=descending,
                              compute_dtype=compute_dtype)
    if block is None:
        from repro.kernels.autotune import lookup_blocks
        block, _ = lookup_blocks("banded", n=n, d=x.shape[-1], k=band,
                                 dtype=compute_dtype)
    y, c = _softsort_apply_banded(w, x, tau, band, int(block),
                                  compute_dtype)
    if descending:
        y = jnp.flip(y, axis=-2)
    return y, c


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _softsort_apply_banded(w, x, tau, band: int, block: int,
                           compute_dtype: str = "float32"):
    (y, c), _ = _fwd_impl_banded(w, x, tau, band, block, compute_dtype)
    return y, c


def _fwd_impl_banded(w, x, tau, band, block, compute_dtype):
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    assert xb.shape == (bsz, n, d), (w.shape, x.shape)
    blk, np_, dsub = _band_geometry(n, d, block)
    perm, wr, wc, xt = _band_operands(wb, xb, n, np_, dsub,
                                      cd=_cd(compute_dtype))
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)

    y_t, c_s, m, l = softsort_apply_fwd_banded_pallas(
        wr, wc, xt, tau_arr,
        n=n, k=band, blk=blk, interpret=not _on_tpu())
    y = y_t[:, :d, :n].transpose(0, 2, 1)                # (B, N, d), cd
    y_out = y.astype(jnp.float32)
    # Column sums come back in rank order; scatter to original columns.
    bidx = jnp.arange(bsz)[:, None]
    c = jnp.zeros((bsz, n), jnp.float32).at[bidx, perm].set(c_s[:, :n, 0])
    out = (y_out, c) if batched else (y_out[0], c[0])
    # Same residual economy as the dense tier: y is saved SLICED and
    # untransposed (and in the compute dtype); the backward re-pads/
    # re-transposes it for O(N d).
    return out, (perm, m, l, y)


def _fwd_rule_banded(w, x, tau, band, block, compute_dtype):
    out, (perm, m, l, y) = _fwd_impl_banded(w, x, tau, band, block,
                                            compute_dtype)
    return out, (w, x, jnp.asarray(tau, jnp.float32), perm, m, l, y)


def _bwd_rule_banded(band, block, compute_dtype, res, cot):
    w, x, tau, perm, m, l, y = res
    dy, dc = cot
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    dyb = dy if batched else dy[None]
    dcb = dc if batched else dc[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    blk, np_, dsub = _band_geometry(n, d, block)
    pad_n = np_ - n

    # Re-gather through the SAVED perm (no argsort here) and mirror the
    # forward's padded transposed layout; cotangent pads are zero so pad
    # slots contribute nothing to any reduction.  Cotangents ride in the
    # compute dtype; the y residual stays f32.
    cd = _cd(compute_dtype)
    _, wr, wc, xt = _band_operands(wb, xb, n, np_, dsub, perm=perm, cd=cd)

    def to_t(a, dt=jnp.float32):                         # (B, N, d) pads
        return jnp.pad(a.astype(jnp.float32),
                       ((0, 0), (0, pad_n), (0, dsub - d))).transpose(
                           0, 2, 1).astype(dt)

    yt, dyt = to_t(y, cd), to_t(dyb, cd)
    # colsum cotangent into rank order (c[perm[r]] = c_sorted[r]).
    dc_s = jnp.take_along_axis(dcb.astype(jnp.float32), perm, axis=-1)
    dc_p = jnp.pad(dc_s, ((0, 0), (0, pad_n))).reshape(
        bsz, np_, 1).astype(cd)

    dws_row, dws_col, dxt, dtau_cols = softsort_apply_bwd_banded_pallas(
        wr, wc, xt, tau.reshape(1, 1), m, l, yt, dyt, dc_p,
        n=n, k=band, blk=blk, interpret=not _on_tpu())

    # Both matrix axes are sorted keys here, so the key gradient has a
    # row and a column component; sum them in rank order, then scatter
    # through the permutation (likewise the payload gradient).
    dws = dws_row[:, 0, :n] + dws_col[:, :n, 0]          # (B, N)
    bidx = jnp.arange(bsz)[:, None]
    dw = jnp.zeros((bsz, n), jnp.float32).at[bidx, perm].add(dws)
    dxs = dxt[:, :d, :n].transpose(0, 2, 1)              # (B, N, d)
    dx = jnp.zeros((bsz, n, d), jnp.float32).at[bidx, perm].add(dxs)
    dtau = jnp.sum(dtau_cols)
    if not batched:
        dw, dx = dw[0], dx[0]
    return dw.astype(w.dtype), dx.astype(x.dtype), dtau


_softsort_apply_banded.defvjp(_fwd_rule_banded, _bwd_rule_banded)


# --------------------------------------------------------------------------
# v1 baseline: split three-pass forward + chunked jnp-scan backward.
# Benchmark-only (benchmarks/kernel_bench.py measures fused vs this); the
# backward re-sorts and re-normalizes from scratch and materializes
# (B, chunk, N) temporaries — exactly the HBM traffic the fused path
# eliminates.
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def softsort_apply_v1(w, x, tau, block_rows: int = 256,
                      block_cols: int = 256, bwd_chunk: int = 256):
    """Previous-generation (P_soft @ x, colsum(P_soft)) — baseline only."""
    return _fwd_impl_v1(w, x, tau, block_rows, block_cols)


def _fwd_impl_v1(w, x, tau, block_rows, block_cols):
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    assert xb.shape == (bsz, n, d), (w.shape, x.shape)
    br, bc, np_, dp = _block_geometry(n, d, block_rows, block_cols)
    _, ws_p, w_p, x_p = _pad_operands(wb, xb, n, np_, dp)
    tau_arr = jnp.asarray(tau, jnp.float32).reshape(1, 1)

    y_p, c_p = softsort_apply_fwd_pallas_v1(
        ws_p, w_p, x_p, tau_arr,
        n=n, br=br, bc=bc, interpret=not _on_tpu())
    y, c = y_p[:, :n, :d], c_p[:, 0, :n]
    return (y, c) if batched else (y[0], c[0])


def _fwd_rule_v1(w, x, tau, block_rows, block_cols, bwd_chunk):
    y, c = _fwd_impl_v1(w, x, tau, block_rows, block_cols)
    return (y, c), (w, x, jnp.asarray(tau, jnp.float32))


def _bwd_rule_v1(block_rows, block_cols, bwd_chunk, res, cot):
    w, x, tau = res
    dy, dc = cot
    batched = w.ndim == 2
    wb = w if batched else w[None]
    xb = x if batched else x[None]
    dyb = dy if batched else dy[None]
    dcb = dc if batched else dc[None]
    bsz, n = wb.shape
    d = xb.shape[-1]
    chunk = min(bwd_chunk, n)
    # Pad the row dimension so chunks tile evenly; padded rows get zero
    # cotangent so they contribute nothing.
    np_ = _round_up(n, chunk)
    pad = np_ - n

    perm = jnp.argsort(jax.lax.stop_gradient(wb), axis=-1)
    ws = jnp.take_along_axis(wb, perm, axis=-1)
    big = jnp.max(jax.lax.stop_gradient(ws)) + 1.0 if n else 0.0
    ws_p = jnp.pad(ws, ((0, 0), (0, pad)), constant_values=big)
    dy_p = jnp.pad(dyb.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))

    row_valid = (jnp.arange(np_) < n).astype(jnp.float32)

    nb = np_ // chunk
    # Scan over row blocks; batch stays a vectorized leading dim inside
    # each step, so peak live memory is O(B * chunk * N).
    ws_blocks = ws_p.reshape(bsz, nb, chunk).transpose(1, 0, 2)
    dy_blocks = dy_p.reshape(bsz, nb, chunk, d).transpose(1, 0, 2, 3)
    valid_blocks = row_valid.reshape(nb, chunk)

    xf = xb.astype(jnp.float32)
    dcf = dcb.astype(jnp.float32)

    def body(carry, blk):
        dw_cols, dx, dtau = carry
        ws_b, dy_b, valid_b = blk      # (B, chunk), (B, chunk, d), (chunk,)
        delta = ws_b[:, :, None] - wb[:, None, :]          # (B, chunk, N)
        s = -jnp.abs(delta) / tau
        p = jax.nn.softmax(s, axis=-1)
        # dP_ij = dy_i . x_j + dc_j   (padded rows are not rows of P: mask)
        dp = jnp.einsum("bcd,bnd->bcn", dy_b, xf) + dcf[:, None, :]
        dsum = jnp.sum(p * dp, axis=-1, keepdims=True)
        ds = p * (dp - dsum) * valid_b[None, :, None]      # (B, chunk, N)
        p = p * valid_b[None, :, None]     # mask dx contribution too
        sgn = jnp.sign(delta)
        dws_b = jnp.sum(ds * (-sgn), axis=-1) / tau        # (B, chunk)
        dw_cols = dw_cols + jnp.sum(ds * sgn, axis=1) / tau
        dx = dx + jnp.einsum("bcn,bcd->bnd", p, dy_b)
        dtau = dtau + jnp.sum(ds * (-s)) / tau
        return (dw_cols, dx, dtau), dws_b

    init = (jnp.zeros_like(wb, jnp.float32), jnp.zeros_like(xf),
            jnp.zeros((), jnp.float32))
    (dw_cols, dx, dtau), dws_stack = jax.lax.scan(
        body, init, (ws_blocks, dy_blocks, valid_blocks))
    dws = dws_stack.transpose(1, 0, 2).reshape(bsz, np_)[:, :n]
    # Scatter the sorted-row gradient back through the permutation.
    dw = dw_cols.at[jnp.arange(bsz)[:, None], perm].add(dws)
    if not batched:
        dw, dx = dw[0], dx[0]
    return dw.astype(w.dtype), dx.astype(x.dtype), dtau


softsort_apply_v1.defvjp(_fwd_rule_v1, _bwd_rule_v1)
