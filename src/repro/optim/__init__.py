from repro.optim.adam import (  # noqa: F401
    AdamState,
    adam_init,
    adam_update,
    Optimizer,
    adam,
    adamw,
    sgd,
    clip_by_global_norm,
    cosine_schedule,
    linear_warmup_cosine,
)
