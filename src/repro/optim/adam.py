"""Optimizers built from scratch (optax is not available in this env).

Pytree-generic Adam/AdamW/SGD with global-norm clipping and LR schedules.
Used by the permutation-learning core and the LM training substrate.

The state is a pytree of the same structure as the params, so it shards
with the same NamedSharding rules as the parameters (ZeRO-style: the
moments live wherever the param shard lives).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class AdamState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: PyTree         # first moment
    nu: PyTree         # second moment


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """A minimal (init, update) pair; update returns (new_params, new_state)."""

    init: Callable[[PyTree], Any]
    update: Callable[[PyTree, Any, PyTree], tuple[PyTree, Any]]


def _tree_zeros_like(params: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def adam_init(params: PyTree, moment_dtype=jnp.float32) -> AdamState:
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=_tree_zeros_like(params, moment_dtype),
        nu=_tree_zeros_like(params, moment_dtype),
    )


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adam_update(
    grads: PyTree,
    state: AdamState,
    params: PyTree,
    *,
    lr: float | jnp.ndarray | Callable[[jnp.ndarray], jnp.ndarray],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> tuple[PyTree, AdamState]:
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    class _Upd:
        """Plain holder (NOT a pytree) so arbitrary param-tree container
        types (tuples of block stacks etc.) survive the tree.map."""
        __slots__ = ("p", "m", "v")

        def __init__(self, p, m, v):
            self.p, self.m, self.v = p, m, v

    def upd(p, g, m, v):
        gf = g.astype(m.dtype)
        m2 = b1 * m + (1.0 - b1) * gf
        v2 = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(m.dtype)
        return _Upd(p - (lr_t * delta).astype(p.dtype), m2, v2)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    is_upd = lambda t: isinstance(t, _Upd)  # noqa: E731
    new_params = jax.tree.map(lambda t: t.p, out, is_leaf=is_upd)
    new_mu = jax.tree.map(lambda t: t.m, out, is_leaf=is_upd)
    new_nu = jax.tree.map(lambda t: t.v, out, is_leaf=is_upd)
    return new_params, AdamState(step=step, mu=new_mu, nu=new_nu)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    return Optimizer(
        init=adam_init,
        update=lambda g, s, p: adam_update(g, s, p, lr=lr, b1=b1, b2=b2, eps=eps),
    )


def adamw(lr, weight_decay: float = 0.01, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8) -> Optimizer:
    return Optimizer(
        init=adam_init,
        update=lambda g, s, p: adam_update(
            g, s, p, lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay),
    )


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    def init(params):
        return _tree_zeros_like(params) if momentum else None

    def update(grads, state, params):
        if momentum:
            new_state = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
            vel = new_state
        else:
            new_state, vel = None, grads
        lr_t = jnp.asarray(lr, jnp.float32)
        new_params = jax.tree.map(lambda p, v: p - (lr_t * v).astype(p.dtype), params, vel)
        return new_params, new_state

    return Optimizer(init=init, update=update)


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32) / total_steps, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1.0 - final_frac) * cos)
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        return jnp.where(step <= warmup, warm, cos(step - warmup))
    return fn
