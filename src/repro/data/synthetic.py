"""Deterministic synthetic data pipeline.

Batches are derived from a counter-based PRNG keyed on (seed, step), so:
  * every host generates exactly its own shard without coordination
    (shard index folds into the key) — no host-side data movement;
  * restarts resume bit-identically (the step index is in the key);
  * elastic re-sharding changes nothing (the global batch is a pure
    function of the step).

Each sequence is an arithmetic token progression from a per-sequence
random start (next = prev + 1 mod V).  Unlike i.i.d.-uniform tokens —
whose next-token cross entropy starts AND stays at ln(V), so a
"training works" smoke test reduces to a coin flip — the shared
successor rule gives the optimizer real signal, making loss decrease a
meaningful assertion while keeping the stream deterministic.

``batch_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable,
zero allocation) for the dry-run path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeCell


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, step: int,
                    seed: int = 0):
    """Materialize one global batch (small scales / CPU training only)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    starts = jax.random.randint(k1, (batch, 1), 0, cfg.vocab_size,
                                dtype=jnp.int32)
    tokens = (starts + jnp.arange(seq + 1, dtype=jnp.int32)) % cfg.vocab_size
    out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    ctx = _context(cfg, batch, k2)
    if ctx is not None:
        out["context"] = ctx
    return out


def _context(cfg: ModelConfig, batch: int, key):
    if cfg.family == "vlm":
        return jax.random.normal(key, (batch, cfg.vision_tokens,
                                       cfg.vision_d), jnp.bfloat16)
    if cfg.is_encdec:
        return jax.random.normal(key, (batch, cfg.audio_frames,
                                       cfg.d_model), jnp.bfloat16)
    return None


# ------------------------------------------------------ dry-run specs

def token_spec(batch: int, seq: int):
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def context_spec(cfg: ModelConfig, batch: int):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct(
            (batch, cfg.vision_tokens, cfg.vision_d), jnp.bfloat16)
    if cfg.is_encdec:
        return jax.ShapeDtypeStruct(
            (batch, cfg.audio_frames, cfg.d_model), jnp.bfloat16)
    return None


def batch_specs(cfg: ModelConfig, cell: ShapeCell):
    """Training-batch ShapeDtypeStructs for one shape cell."""
    specs = {
        "tokens": token_spec(cell.global_batch, cell.seq_len),
        "labels": token_spec(cell.global_batch, cell.seq_len),
    }
    ctx = context_spec(cfg, cell.global_batch)
    if ctx is not None:
        specs["context"] = ctx
    return specs
