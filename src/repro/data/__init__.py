from repro.data.synthetic import (  # noqa: F401
    synthetic_batch,
    token_spec,
    batch_specs,
    context_spec,
)
