"""Production-style training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen1.5-0.5b --preset 10m --steps 200 --batch 8 --seq 128 \
        --ckpt-dir /tmp/run1 [--resume] [--compress-grads] [--fail-at 60]

Composes the full runtime: synthetic deterministic data pipeline, Adam,
checkpoint/restart supervision, straggler monitoring, optional int8
error-feedback gradient compression, and (single-process here) the same
pjit step the dry-run lowers for the production meshes.  ``--fail-at``
injects a WorkerFailure to demonstrate recovery end-to-end.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.data.synthetic import synthetic_batch
from repro.models import init_model, loss_fn, param_count, reduced_config
from repro.optim.adam import adam_init, adam_update, clip_by_global_norm
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compression import compress_gradients, init_compression
from repro.runtime.fault_tolerance import TrainSupervisor, WorkerFailure
from repro.runtime.straggler import StragglerMonitor

PRESETS = {
    # name: overrides on top of reduced_config for CPU-runnable scales
    "tiny": dict(d_model=64, num_layers=2, d_ff=128, vocab_size=512),
    "10m": dict(d_model=256, num_layers=6, d_ff=1024, vocab_size=8192,
                num_heads=8, num_kv_heads=8, head_dim=32),
    "100m": dict(d_model=768, num_layers=12, d_ff=3072, vocab_size=32768,
                 num_heads=12, num_kv_heads=12, head_dim=64),
}


def build(arch: str, preset: str, lr: float, compress: bool):
    cfg = reduced_config(get_config(arch), **PRESETS[preset])
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    opt = adam_init(params)
    state = {"params": params, "opt": opt}
    if compress:
        state["comp"] = init_compression(params)

    @jax.jit
    def step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], cfg, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_state = dict(state)
        if "comp" in state:
            grads, new_state["comp"], _ = compress_gradients(
                grads, state["comp"])
        new_state["params"], new_state["opt"] = adam_update(
            grads, state["opt"], state["params"], lr=lr, b1=0.9, b2=0.95)
        return new_state, dict(metrics, loss=loss, grad_norm=gnorm)

    return cfg, state, step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=PRESETS, default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a worker failure at this step (demo)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg, state, base_step = build(args.arch, args.preset, args.lr,
                                  args.compress_grads)
    print(f"arch={args.arch} preset={args.preset} "
          f"params={param_count(state['params']):,}")

    fail = {"armed": args.fail_at > 0}

    def step_fn(state, batch):
        if fail["armed"] and int(batch["step"]) == args.fail_at:
            fail["armed"] = False
            raise WorkerFailure(f"injected failure at step {args.fail_at}")
        s, m = base_step(state, {k: v for k, v in batch.items()
                                 if k != "step"})
        return s, m

    def data_fn(step):
        b = synthetic_batch(cfg, args.batch, args.seq, step)
        b["step"] = step
        return b

    mgr = CheckpointManager(args.ckpt_dir, keep=3)
    mon = StragglerMonitor()
    sup = TrainSupervisor(step_fn, data_fn, mgr,
                          checkpoint_every=args.ckpt_every, straggler=mon)

    t0 = time.time()
    state, step = sup.run(state, 0, args.steps)
    dt = time.time() - t0

    losses = [h["metrics"]["loss"] for h in sup.history if "metrics" in h]
    print(f"done: {step} steps in {dt:.1f}s "
          f"({dt / max(len(losses), 1):.2f}s/step), "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"restarts={sup.restarts}, stragglers={len(mon.flagged)}")
    return {"first_loss": losses[0], "last_loss": losses[-1],
            "restarts": sup.restarts, "steps": step}


if __name__ == "__main__":
    main()
