"""Batched serving drivers: continuous-batching loops on CPU scale.

Two workloads share this entrypoint:

* ``--workload lm`` (default) — LM decode serving.  Requests arrive with
  different prompt lengths; the scheduler right-pads into a fixed decode
  batch, prefills once, then decodes step-locked with per-request stop
  positions (the fixed-shape analogue of continuous batching — slot
  reuse keeps XLA shapes static, which is what a TPU serving stack
  needs).

      PYTHONPATH=src python -m repro.launch.serve \
          --arch qwen1.5-0.5b --preset tiny --requests 8 --max-new 32

* ``--workload sort`` — grid-sorting serving.  ``SortServer`` is a
  continuous-batching scheduler: concurrent ``submit()`` calls (e.g.
  one per user upload) join the annealing loop at the next ROUND
  boundary and leave at the boundary where they finish — the
  tournament's rung structure as the preemption point — so a slow
  large request no longer stalls the traffic coalesced behind it the
  way the old fixed-boundary drain loop did.  Requests carry optional
  deadlines and priorities; admission control sheds load past a
  bounded queue depth as a typed ``QueueFull`` raised by ``submit()``
  (backpressure, never a hang); mixed (N, d) traffic is batched per
  shape bucket with batch sizes padded to powers of two so the compile
  cache stays bounded; and a failed (or straggling) device dispatch
  re-queues its requests from their last committed round boundary
  under a retry budget with exponential backoff
  (``runtime.fault_tolerance.RetryPolicy``) instead of failing every
  coalesced future — semantics and measurements: EXPERIMENTS.md
  §Serving, fault-injection proofs: tests/test_serving.py.

      PYTHONPATH=src python -m repro.launch.serve \
          --workload sort --requests 8 --sort-n 256 --rounds 30

  Scale-out: ``--mesh-devices D`` shards each coalesced batch across a
  D-device "data" mesh, and ``--tournament-rungs K --restarts S`` runs
  the S seeds per request as a successive-halving tournament
  (EXPERIMENTS.md §Scaling).  ``--use-kernel`` routes every instance's
  SoftSort apply — forward AND backward — through the fused Pallas
  kernel tier (EXPERIMENTS.md §Perf) instead of the chunked-jnp stream,
  and ``--band K`` / ``--band auto`` additionally switches the apply to
  the O(N*K) banded tier once the anneal is cold enough for its tail
  bound (EXPERIMENTS.md §Perf) — both compose with the mesh and the
  tournament.  ``--dtype bfloat16`` (with ``--use-kernel``) selects the
  mixed-precision kernel tier: bf16 score/payload compute and half the
  payload HBM traffic, f32 keys/stats/Adam (EXPERIMENTS.md §Perf).

  Elastic capacity (EXPERIMENTS.md §Robustness, "Elastic capacity"):
  ``--device-health K`` classifies dispatch failures through a
  ``DeviceHealthMonitor`` — a device named by ``DeviceLost`` K times is
  evicted, the mesh re-shards over the survivors at the next rung
  boundary (bit-identical per seed; the carry is layout-free), and
  returning devices grow it back.  ``--brownout`` arms the overload
  brownout ladder: under capacity loss or queue pressure, new requests
  degrade culled → adaptive → banded → bf16 before anything is shed.
"""
from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.train import PRESETS
from repro.models import (
    decode_step,
    init_model,
    make_caches,
    prefill,
    reduced_config,
)


# --------------------------------------------------------------------------
# Sort serving: continuous-batching scheduler over run_round_segment.
# --------------------------------------------------------------------------

class RequestRejected(RuntimeError):
    """Base of every typed SortServer rejection.  A request the server
    cannot serve resolves with a subclass of this — never a hang."""


class QueueFull(RequestRejected):
    """Admission control: the bounded queue is at depth.  Raised
    synchronously by ``submit()`` so callers see backpressure at the
    moment they offer load, not as a future that never resolves."""


class DeadlineExceeded(RequestRejected):
    """The request's deadline passed before its anneal finished; it was
    shed at a round boundary (or at admission)."""


class ServerClosed(RequestRejected):
    """The server was closed while this request was queued/in flight."""


class RequestFailed(RequestRejected):
    """Device dispatch failed more than the retry budget allows;
    ``__cause__`` carries the last device error."""


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


# Brownout ladder rungs, mildest first.  Level k applies rungs 1..k:
#   culled   — run the request's restarts as a maximally-culled
#              tournament (keep=1 at every interior rung boundary)
#   adaptive — force schedule="adaptive" so converged restarts exit at
#              the first plateaued boundary instead of running all R
#   banded   — snap the dense apply to the O(N*K) banded tier
#   bf16     — drop the kernel tier to bfloat16 compute
_BROWNOUT_LADDER = ("culled", "adaptive", "banded", "bf16")


@dataclasses.dataclass(frozen=True)
class BrownoutPolicy:
    """Overload brownout: degrade per-request quality BEFORE shedding.

    When measured capacity drops (a device eviction, the straggler
    monitor halving the batch bucket cap) or queue depth crosses the
    watermarks, the server walks a monotone degradation ladder
    (``_BROWNOUT_LADDER``) one level per scheduler tick — and walks it
    back down, one level per tick, as capacity returns (hysteresis:
    the low watermark must clear before pressure stops counting).

    Degradations are applied to a request ONCE, at first admission, so
    an in-flight anneal never changes config mid-run (bit-identity per
    admitted config is preserved); they are keyed to deadline slack —
    a request with more than ``slack_full_s`` of slack (or no deadline
    at all) is degraded one level more gently, since shedding risk is
    what the ladder exists to avoid.  Every applied rung is recorded in
    ``stats["degradations"]`` and the per-request ``degraded`` tuple.

    ``high_watermark`` / ``low_watermark`` are fractions of
    ``queue_depth``.
    """
    high_watermark: float = 0.5
    low_watermark: float = 0.25
    slack_full_s: float = 2.0


@dataclasses.dataclass(eq=False)      # identity semantics: requests are
class _SortRequest:                   # tracked in lists via `is`, and the
                                      # generated field-wise __eq__ would
                                      # compare numpy arrays
    """One in-flight request: its problem, bookkeeping, and — once
    admitted — its per-restart engine state.  The state committed at
    each round boundary (orders/keys/losses) doubles as the restart
    checkpoint: a failed dispatch re-queues the request and it resumes
    from here, TrainSupervisor-style."""
    x: np.ndarray                      # (N, d)
    hw: tuple[int, int]
    d: int
    key: np.ndarray                    # (2,) uint32 base PRNG key
    future: Future
    priority: int
    seq: int
    deadline: float | None             # absolute monotonic, None = none
    submitted: float
    progress: int = 0                  # rounds executed so far
    attempts: int = 0                  # failed dispatches so far
    eligible_at: float = 0.0           # backoff gate for re-admission
    norm: float = 0.0
    orders: np.ndarray | None = None   # (S_live, N) int32
    keys: np.ndarray | None = None     # (S_live, 2) uint32 chained keys
    alive: np.ndarray | None = None    # (S_live,) original restart idx
    losses: np.ndarray | None = None   # (S, R) f32, NaN where culled
    # Guardrail state (runtime.guardrails): the request's probe policy
    # (None = server default at admission), its stateful monitor, the
    # integrity-strike count, and the self-healed config override a
    # DivergencePolicy rung installed (None = serve with server cfg).
    guardrail: object | None = None    # GuardrailPolicy
    monitor: object | None = None      # GuardrailMonitor (lazy, not saved)
    strikes: int = 0
    cfg_override: object | None = None  # ShuffleSoftSortConfig
    # adaptive mode only: the request's plateau controller (indexed by
    # ORIGINAL restart id) and which alive rows have already left the
    # anneal (converged early; frozen, but still winner candidates).
    ctrl: object | None = None
    done_mask: np.ndarray | None = None  # (S_live,) bool
    # Brownout bookkeeping: which ladder rungs were applied to this
    # request at admission (monotone: never grows after admission), and
    # whether the "culled" rung forces keep=1 at tournament boundaries.
    degraded: tuple = ()
    brownout_cull: bool = False

    @property
    def n_live(self) -> int:
        """Instances the next dispatch must carry (pre-admission: 1)."""
        if self.alive is None:
            return 1
        if self.done_mask is not None:
            return int((~self.done_mask).sum())
        return len(self.alive)


@dataclasses.dataclass
class WarmHandoff:
    """In-flight state a preempted ``SortServer`` hands its successor.

    ``close(drain=False)`` returns one: every unresolved request — with
    its future, committed round-boundary engine state, and controller —
    plus the server-owned PRNG stream position and sequence counter.  A
    new server constructed with ``resume=handoff`` adopts the requests
    and finishes them from their last committed rung: the original
    futures resolve from the new server, exactly once, bit-identical to
    what the first server would have produced (tests/test_serving.py,
    EXPERIMENTS.md §Robustness).  When the first server had a
    ``checkpoint_dir``, the same state is also persisted there, so a
    successor in a NEW process can ``resume=<dir>`` (fresh futures,
    exposed as ``server.resumed``)."""
    requests: list            # unresolved _SortRequests, seq order
    rng_state: dict           # np.random PCG64 bit-generator state
    seq: int                  # next submission sequence number
    # When the server's engine_fn is a FaultInjector (chaos tests), its
    # injection cursor/schedules ride along so a resumed chaos scenario
    # keeps exact fault accounting (FaultInjector.state_dict()).
    injector_state: dict | None = None
    # Elastic-capacity state: a successor preempted mid-brownout must
    # resume at the same ladder position, with the same evicted-device
    # set (its mesh rebuilt over the survivors) and the health
    # monitor's strike counts (DeviceHealthMonitor.state_dict()).
    brownout_level: int = 0
    evicted_devices: tuple = ()
    health_state: dict | None = None


class SortServer:
    """Continuous-batching scheduler for grid-sort requests.

    Requests join and leave the annealing loop at ROUND boundaries: the
    R-round schedule is split into ``sched_rungs`` equal rungs (the
    tournament's rung structure as the preemption quantum) and each
    scheduler tick advances every active instance by one rung via
    ``core.shufflesoftsort.run_round_segment`` — one scanned device
    call per (shape bucket, apply regime) in which instances at
    DIFFERENT anneal positions coexist, each consuming its own slice of
    the tau schedule.  A finished request leaves at its boundary while
    its batchmates keep annealing, and a newly admitted one joins at
    the next tick — no cohort barriers, so one slow large request no
    longer stalls everything coalesced behind it.

    Semantics (per seed, ``n_restarts == 1``, no culling): results are
    bit-identical to a sequential ``shuffle_soft_sort`` call with the
    same key, whatever traffic pattern interleaved the rounds — the
    per-instance tau promotion and chained keys/orders are exact
    (tests/test_serving.py).  With ``cfg.band`` the dense->banded
    switch snaps UP to the next rung boundary
    (``core.shufflesoftsort.rung_aligned_switch``) so no segment
    straddles regimes; a few extra rounds run dense, exactly.

    Production behaviors (EXPERIMENTS.md §Serving):

    * **Deadlines / priorities** — ``submit(..., deadline_s=,
      priority=)``; expired requests are shed at boundaries with a
      typed ``DeadlineExceeded``; admission is priority-then-FIFO.
    * **Backpressure** — at most ``queue_depth`` requests may wait for
      admission; past that ``submit()`` raises ``QueueFull``.
    * **Shape buckets** — mixed (N, d) traffic batches per ``(hw, d)``
      signature, with per-call batch sizes padded to the next power of
      two (capped at ``max_batch``), so compiled programs are bounded
      by |signatures| x |regimes| x log2(max_batch), not by the traffic.
    * **Fault tolerance** — a dispatch that raises re-queues its
      requests from their last committed boundary under
      ``retry: RetryPolicy`` (budget + exponential backoff); budget
      exhaustion resolves the future with ``RequestFailed``.  Every
      future resolves exactly once, result or typed rejection.
    * **Straggler rerouting** — per-dispatch wall time, normalized per
      instance-round, feeds a ``StragglerMonitor``; a flagged dispatch
      halves the batch bucket cap (restored after a healthy streak) so
      traffic reroutes into smaller batches around the slow path.
    * **Elastic capacity** (``device_health=DeviceHealthMonitor(...)``,
      EXPERIMENTS.md §Robustness "Elastic capacity") — a dispatch
      failure naming a device (``DeviceLost``) past the strike budget
      EVICTS it: the mesh is rebuilt over the survivors and the rung's
      requests replay from their last committed boundary on the next
      tick — a one-rung-boundary hiccup, bit-identical per seed to an
      uninterrupted run (the rung carry is layout-free).  Evicted
      devices that probe healthy again grow the mesh back at a tick
      boundary.  Counted in ``stats["evictions"]`` /
      ``stats["reshards"]`` / ``stats["device_returns"]``.
    * **Brownout ladder** (``brownout=BrownoutPolicy()``) — under
      capacity loss or queue pressure, newly admitted requests degrade
      through culled → adaptive → banded → bf16 (keyed to deadline
      slack) BEFORE anything is shed; the ladder steps one level per
      tick each way.  Counted per rung in ``stats["degradations"]``.
    * **Reproducibility** — requests submitted without a key draw from
      a server-owned PRNG stream seeded by ``seed``: same seed + same
      submission order = bit-identical results, end to end.

    Scale-out knobs (EXPERIMENTS.md §Scaling): ``mesh`` shard_maps every
    segment's instance axis across a 1-D "data" mesh;
    ``tournament_rungs > 1`` (with ``n_restarts > 1``) culls the worst
    ``cull_fraction`` of each request's restarts at its interior rung
    boundaries — successive halving, bit-identical survivors.

    Adaptive annealing (``cfg.schedule="adaptive"``, EXPERIMENTS.md
    §Adaptive): each request carries its own
    ``core.annealing.AdaptiveController`` (decision quantum == the
    scheduler rung), restarts jump to colder tau when their loss EWMA
    plateaus, the dense->banded switch comes from the MEASURED tail
    bound on their own keys, and the request resolves at the FIRST
    boundary where every surviving restart has converged — fewer
    rounds per request at equal final loss, counted in
    ``stats["adaptive_exits"]`` / ``stats["rounds_saved"]``.  With
    ``n_restarts == 1`` adaptive serving results are bit-identical to
    the adaptive engine paths per seed; controller state commits only
    on successful dispatches, so retries after a fault resume
    bit-exactly.
    """

    def __init__(self, hw, d, cfg=None, max_batch: int = 8,
                 max_wait_ms: float = 2.0, n_restarts: int = 1,
                 mesh=None, tournament_rungs: int = 1,
                 cull_fraction: float = 0.5, *,
                 queue_depth: int = 64, max_active: int | None = None,
                 sched_rungs: int | None = None, seed: int = 0,
                 default_deadline_s: float | None = None,
                 retry=None, straggler=None,
                 straggler_recovery: int = 8,
                 checkpoint_dir: str | None = None, resume=None,
                 engine_fn=None, autostart: bool = True,
                 guardrail=None, degrade=None,
                 brownout=None, device_health=None):
        from repro.core.shufflesoftsort import (
            ShuffleSoftSortConfig,
            _rung_boundaries,
            run_round_segment,
        )
        from repro.runtime.fault_tolerance import (
            DivergencePolicy,
            RetryPolicy,
        )
        from repro.runtime.guardrails import GuardrailPolicy
        from repro.runtime.straggler import StragglerMonitor

        self.hw = tuple(hw)
        self.n = self.hw[0] * self.hw[1]
        self.d = d
        self.cfg = cfg or ShuffleSoftSortConfig()
        self.max_batch = int(max_batch)
        self.max_wait_s = max_wait_ms / 1e3
        self.n_restarts = int(n_restarts)
        self.mesh = mesh
        self.tournament_rungs = int(tournament_rungs)
        self.cull_fraction = float(cull_fraction)
        self.queue_depth = int(queue_depth)
        self.max_active = (2 * self.max_batch if max_active is None
                           else int(max_active))
        self.default_deadline_s = default_deadline_s
        self.retry = retry or RetryPolicy()
        self.straggler = straggler or StragglerMonitor()
        self.straggler_recovery = int(straggler_recovery)
        self._engine = engine_fn or run_round_segment
        if guardrail is not None and not isinstance(guardrail,
                                                    GuardrailPolicy):
            raise TypeError(
                f"guardrail must be a GuardrailPolicy or None, "
                f"got {guardrail!r}")
        self.guardrail = guardrail          # server-default probe policy
        self.degrade = degrade or DivergencePolicy()
        if brownout is not None and not isinstance(brownout,
                                                   BrownoutPolicy):
            raise TypeError(
                f"brownout must be a BrownoutPolicy or None, "
                f"got {brownout!r}")
        self.brownout = brownout
        self.device_health = device_health  # DeviceHealthMonitor or None

        rounds = self.cfg.rounds
        self.adaptive = self.cfg.schedule == "adaptive"
        tournament = self.tournament_rungs > 1 and self.n_restarts > 1
        if sched_rungs is None:
            if self.adaptive:
                # Scheduler rung == controller decision quantum, so
                # every boundary the server preempts at is a boundary
                # the controller observed at — adaptive server results
                # stay bit-identical to the engine's adaptive runs.
                from repro.core.annealing import adaptive_seg_len
                sched_rungs = rounds // adaptive_seg_len(self.cfg)
            else:
                sched_rungs = (
                    self.tournament_rungs if tournament else
                    next(k for k in (4, 3, 2, 1) if rounds % k == 0))
        self.sched_rungs = int(sched_rungs)
        if not 1 <= self.sched_rungs <= rounds or rounds % self.sched_rungs:
            raise ValueError(
                f"sched_rungs={self.sched_rungs} must divide "
                f"cfg.rounds={rounds} (uniform preemption quantum)")
        if tournament and (rounds % self.tournament_rungs
                           or self.sched_rungs % self.tournament_rungs):
            raise ValueError(
                f"tournament_rungs={self.tournament_rungs} must divide "
                f"cfg.rounds={rounds} and sched_rungs={self.sched_rungs} "
                "so cull boundaries land on scheduler boundaries")
        self.seg_len = rounds // self.sched_rungs
        self._cull_edges = (
            set(_rung_boundaries(rounds, self.tournament_rungs)[:-1])
            if tournament else set())

        self._rng = np.random.Generator(np.random.PCG64(seed))
        self.stats = {
            "requests": 0, "batches": 0, "batch_sizes": [],
            "completed": 0, "failed": 0, "deadline_missed": 0,
            "queue_rejected": 0, "retries": 0, "recoveries": 0,
            "stragglers": 0, "culled": 0, "latencies_ms": [],
            "adaptive_exits": 0, "rounds_saved": 0, "resumed": 0,
            "integrity_violations": 0, "self_heals": 0,
            "integrity_incidents": [],
            "compile_keys": set(),
            "evictions": 0, "reshards": 0, "device_returns": 0,
            "brownouts": 0,
            "degradations": {r: 0 for r in _BROWNOUT_LADDER},
        }
        self.events: list[dict] = []
        self._cv = threading.Condition()
        self._pending: list[_SortRequest] = []
        self._active: list[_SortRequest] = []
        self._stop = False
        self._preempt = False
        self._seq = 0
        self._dispatch_idx = 0
        self._bucket_cap = self.max_batch
        self._healthy_streak = 0
        self._switch_cache: dict[tuple, int] = {}
        # Elastic-capacity state: the mesh as constructed (the full
        # device complement a returning device can grow back into),
        # the currently-evicted device ids, and the brownout ladder
        # position (0 = full quality).
        self._mesh_devices = (None if mesh is None
                              else list(mesh.devices.flat))
        self._evicted: list[int] = []
        self._brownout_level = 0
        self.checkpoint_dir = checkpoint_dir
        self.resumed: list[_SortRequest] = []
        if resume is not None:
            handoff = (resume if isinstance(resume, WarmHandoff)
                       else self._load_handoff(resume))
            self._adopt(handoff)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._started = False
        if autostart:
            self.start()

    def start(self):
        """Start the scheduler thread (no-op if already running).
        ``autostart=False`` + ``start()`` lets tests enqueue a batch of
        requests and observe one deterministic admission pass."""
        if not self._started:
            self._started = True
            self._worker.start()

    # ---- client API ------------------------------------------------------

    def submit(self, x: np.ndarray, key=None, *, hw=None,
               priority: int = 0, deadline_s: float | None = None,
               guardrail=None) -> Future:
        """Enqueue one (N, d) problem; returns a Future of
        ``(order (N,), sorted (N, d), losses (R,))``.

        ``hw`` defaults to the server's construction signature; passing
        a different grid (with a matching x) routes the request to its
        own shape bucket.  ``priority`` — higher admits first.
        ``deadline_s`` — relative seconds; past it the request is shed
        with ``DeadlineExceeded``.  Missing ``key`` draws from the
        server-owned seeded stream (reproducible per server seed).
        ``guardrail`` — a per-request ``GuardrailPolicy`` overriding the
        server default (``GuardrailPolicy(mode="off")`` opts a request
        out of a guarded server's probes).
        Raises ``QueueFull`` / ``ServerClosed`` synchronously.
        """
        from repro.runtime.guardrails import GuardrailPolicy
        if guardrail is not None and not isinstance(guardrail,
                                                    GuardrailPolicy):
            raise TypeError(
                f"guardrail must be a GuardrailPolicy or None, "
                f"got {guardrail!r}")
        x = np.asarray(x, np.float32)
        req_hw = self.hw if hw is None else tuple(hw)
        if x.ndim != 2 or x.shape[0] != req_hw[0] * req_hw[1]:
            raise ValueError(
                f"x shape {x.shape} does not fit grid {req_hw}")
        if hw is None and x.shape != (self.n, self.d):
            raise ValueError(
                f"x shape {x.shape} != server signature "
                f"{(self.n, self.d)}; pass hw= to use another bucket")
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        now = time.monotonic()
        with self._cv:
            if self._stop:
                raise ServerClosed("SortServer is closed")
            if len(self._pending) >= self.queue_depth:
                self.stats["queue_rejected"] += 1
                raise QueueFull(
                    f"queue depth {self.queue_depth} reached; retry later")
            if key is None:
                key = jax.random.PRNGKey(
                    int(self._rng.integers(0, 2**31 - 1)))
            if jnp.issubdtype(jnp.asarray(key).dtype, jax.dtypes.prng_key):
                key = jax.random.key_data(key)
            fut: Future = Future()
            req = _SortRequest(
                x=x, hw=req_hw, d=x.shape[1],
                key=np.asarray(key, np.uint32).reshape(2),
                future=fut, priority=int(priority), seq=self._seq,
                deadline=None if deadline_s is None else now + deadline_s,
                submitted=now,
                guardrail=self.guardrail if guardrail is None
                else guardrail)
            self._seq += 1
            self.stats["requests"] += 1
            self._pending.append(req)
            self._cv.notify()
        return fut

    def close(self, drain: bool = True):
        """Stop the scheduler.

        ``drain=True`` (the default): every queued or in-flight future
        resolves with ``ServerClosed`` — no caller blocks forever.

        ``drain=False`` — **warm restart** (simulated preemption): stop
        WITHOUT rejecting.  Returns a ``WarmHandoff`` carrying every
        unresolved request at its last committed round boundary; a
        successor server built with ``resume=`` finishes them and the
        original futures resolve exactly once.  If ``checkpoint_dir``
        is set the handoff is also persisted there for cross-process
        resume (``resume=<dir>``).
        """
        with self._cv:
            self._stop = True
            if not drain:
                self._preempt = True
            self._cv.notify_all()
        if self._started:
            self._worker.join(timeout=120)
        if drain:
            self._reject_all(ServerClosed("SortServer closed"))
            return None
        with self._cv:
            inflight = self._pending + self._active
            self._pending, self._active = [], []
        inflight = sorted((r for r in inflight if not r.future.done()),
                          key=lambda r: r.seq)
        handoff = WarmHandoff(requests=inflight,
                              rng_state=self._rng.bit_generator.state,
                              seq=self._seq,
                              injector_state=(
                                  self._engine.state_dict()
                                  if hasattr(self._engine, "state_dict")
                                  else None),
                              brownout_level=self._brownout_level,
                              evicted_devices=tuple(self._evicted),
                              health_state=(
                                  self.device_health.state_dict()
                                  if self.device_health is not None
                                  else None))
        self.events.append({"event": "preempt",
                            "inflight": len(inflight)})
        if self.checkpoint_dir is not None:
            self._save_handoff(handoff)
        return handoff

    # ---- warm restart (preemption handoff) ------------------------------

    def _adopt(self, handoff: WarmHandoff):
        """Adopt a predecessor's in-flight requests: they re-enter the
        admission queue at their last committed round boundary (backoff
        gates cleared — the fault was the preemption, not the request)
        and their futures resolve from THIS server."""
        self._rng.bit_generator.state = handoff.rng_state
        self._seq = max(self._seq, int(handoff.seq))
        if (handoff.injector_state is not None
                and hasattr(self._engine, "load_state_dict")):
            self._engine.load_state_dict(handoff.injector_state)
        # Resume the elastic-capacity state: same brownout ladder
        # position, same evicted-device set (mesh rebuilt over the
        # survivors), same health-monitor strikes.
        self._brownout_level = int(handoff.brownout_level)
        evicted = [int(dv) for dv in (handoff.evicted_devices or ())]
        if evicted:
            self._evicted = evicted
            self._reshard()
        if (handoff.health_state is not None
                and self.device_health is not None):
            self.device_health.load_state_dict(handoff.health_state)
        for req in handoff.requests:
            if req.future.done():       # pragma: no cover - defensive
                continue
            req.eligible_at = 0.0
            self.stats["requests"] += 1
            self.stats["resumed"] += 1
            self.resumed.append(req)
            self._pending.append(req)
            self.events.append({"event": "adopt", "seq": req.seq,
                                "progress": req.progress})

    # ---- elastic capacity: eviction / re-shard / brownout ---------------

    def _reshard(self):
        """Rebuild ``self.mesh`` over the non-evicted devices of the
        construction-time complement.  No carry ever moves: request
        state lives host-side in logical layout between rungs, so the
        next dispatch simply re-pads onto the new mesh (``mesh=None``
        when every device is out — the vmap engine still serves)."""
        if self._mesh_devices is None:
            return
        from repro.launch.mesh import make_sort_mesh
        gone = set(self._evicted)
        survivors = [dv for dv in self._mesh_devices if dv.id not in gone]
        self.mesh = (make_sort_mesh(len(survivors), devices=survivors)
                     if survivors else None)

    def _device_failure(self, reqs: list[_SortRequest], exc) -> bool:
        """Classify a dispatch failure through the health monitor.  A
        LOST verdict evicts the device, re-shards, and re-queues the
        rung's requests WITHOUT consuming retry budget (the fault was
        the device, not the request) — the replay at the next tick runs
        on the survivor mesh, so the detection→re-shard gap is exactly
        one rung boundary.  Returns True when handled elastically."""
        if self.device_health is None:
            return False
        dev = self.device_health.classify(exc)
        if dev is None:
            return False
        self._evicted.append(int(dev))
        self.stats["evictions"] += 1
        self._reshard()
        self.stats["reshards"] += 1
        n_surv = (0 if self.mesh is None
                  else int(self.mesh.shape["data"]))
        self.events.append({"event": "evict", "device": int(dev),
                            "survivors": n_surv,
                            "requeued": len(reqs)})
        now = time.monotonic()
        for req in reqs:
            self._active.remove(req)
            req.eligible_at = now
            with self._cv:
                self._pending.append(req)
        return True

    def _poll_device_returns(self):
        """Grow the mesh back at a tick boundary when evicted devices
        probe healthy again (``DeviceHealthMonitor.poll_returns``)."""
        if self.device_health is None or not self._evicted:
            return
        back = self.device_health.poll_returns()
        grew = False
        for dev in back:
            dev = int(dev)
            if dev in self._evicted:
                self._evicted.remove(dev)
                self.stats["device_returns"] += 1
                grew = True
                self.events.append({"event": "device_return",
                                    "device": dev})
        if grew:
            self._reshard()

    def _update_brownout(self, queue_len: int):
        """Step the brownout ladder one level per tick toward the
        pressure target: +1 while capacity is down (eviction, straggler
        cap halving) or the queue is past a watermark, -1 as it
        returns.  One step per tick is the hysteresis — a transient
        spike cannot slam the ladder to bf16 and back within a rung."""
        if self.brownout is None:
            return
        qfrac = queue_len / max(1, self.queue_depth)
        pressure = (2 if qfrac >= self.brownout.high_watermark
                    else 1 if qfrac >= self.brownout.low_watermark else 0)
        target = min(len(_BROWNOUT_LADDER),
                     (1 if self._evicted else 0)
                     + (1 if self._bucket_cap < self.max_batch else 0)
                     + pressure)
        if target > self._brownout_level:
            self._brownout_level += 1
            self.events.append({"event": "brownout_up",
                                "level": self._brownout_level,
                                "target": target, "queue": queue_len})
        elif target < self._brownout_level:
            self._brownout_level -= 1
            self.events.append({"event": "brownout_down",
                                "level": self._brownout_level,
                                "target": target, "queue": queue_len})

    def _apply_brownout(self, req: _SortRequest, now: float):
        """Apply the current ladder level to a request at FIRST
        admission (never mid-anneal: an admitted request's config is
        immutable, so its results stay bit-identical to an unloaded
        server given the same admitted config).  Requests with more
        than ``slack_full_s`` of deadline slack — or no deadline — take
        one level less: the ladder exists to protect deadline-bound
        traffic from shedding."""
        if (self.brownout is None or self._brownout_level <= 0
                or req.orders is not None):
            return
        level = self._brownout_level
        slack = None if req.deadline is None else req.deadline - now
        if slack is None or slack > self.brownout.slack_full_s:
            level -= 1
        if level <= 0:
            return
        cfg = self._cfg_for(req)
        applied = []
        if (level >= 1 and self._cull_edges and self.n_restarts > 1
                and not req.brownout_cull):
            req.brownout_cull = True
            applied.append("culled")
        if level >= 2 and cfg.schedule != "adaptive":
            cfg = dataclasses.replace(cfg, schedule="adaptive")
            applied.append("adaptive")
        if level >= 3 and cfg.band is None:
            from repro.core.shufflesoftsort import resolve_band
            auto = dataclasses.replace(cfg, band="auto")
            if resolve_band(auto, req.x.shape[0]) is not None:
                cfg = auto
                applied.append("banded")
        if level >= 4 and cfg.use_kernel and cfg.compute_dtype == "float32":
            cfg = dataclasses.replace(cfg, compute_dtype="bfloat16")
            applied.append("bf16")
        if not applied:
            return
        if cfg is not self._cfg_for(req):
            req.cfg_override = cfg
        req.degraded = tuple(applied)
        for rung in applied:
            self.stats["degradations"][rung] += 1
        self.stats["brownouts"] += 1
        self.events.append({"event": "brownout_degrade", "seq": req.seq,
                            "level": self._brownout_level,
                            "applied": applied})

    def _save_handoff(self, handoff: WarmHandoff):
        """Persist the handoff to ``checkpoint_dir`` (atomic, via
        CheckpointManager): flat per-request arrays + a JSON manifest of
        the scalars, so a successor in a new process can resume."""
        from repro.core.annealing import AdaptiveController
        from repro.runtime.checkpoint import CheckpointManager
        now = time.monotonic()
        arrays: dict[str, np.ndarray] = {}
        metas = []
        for i, req in enumerate(handoff.requests):
            arrays[f"req{i}_x"] = req.x
            arrays[f"req{i}_key"] = req.key
            has_state = req.orders is not None
            if has_state:
                arrays[f"req{i}_orders"] = req.orders
                arrays[f"req{i}_keys"] = req.keys
                arrays[f"req{i}_alive"] = req.alive
                arrays[f"req{i}_losses"] = req.losses
                if req.done_mask is not None:
                    arrays[f"req{i}_done"] = req.done_mask
                if req.ctrl is not None:
                    for f in AdaptiveController._STATE_FIELDS:
                        arrays[f"req{i}_ctrl_{f}"] = getattr(req.ctrl, f)
            metas.append({
                "hw": list(req.hw), "d": int(req.d),
                "priority": int(req.priority), "seq": int(req.seq),
                "progress": int(req.progress),
                "attempts": int(req.attempts), "norm": float(req.norm),
                "deadline_left": (None if req.deadline is None
                                  else max(0.0, req.deadline - now)),
                "has_state": has_state,
                "has_ctrl": req.ctrl is not None,
                "has_done": req.done_mask is not None,
                "strikes": int(req.strikes),
                "guardrail": (None if req.guardrail is None
                              else dataclasses.asdict(req.guardrail)),
                "cfg_override": (None if req.cfg_override is None
                                 else dataclasses.asdict(req.cfg_override)),
                "degraded": list(req.degraded),
                "brownout_cull": bool(req.brownout_cull),
            })
        mgr = CheckpointManager(self.checkpoint_dir, keep=1,
                                async_save=False)
        mgr.save(0, arrays, extra={
            "kind": "sort-server-handoff",
            "rng_state": handoff.rng_state,
            "seq": int(handoff.seq),
            "requests": metas,
            "injector_state": handoff.injector_state,
            "brownout_level": int(handoff.brownout_level),
            "evicted_devices": [int(dv) for dv in
                                handoff.evicted_devices],
            "health_state": handoff.health_state,
        })

    def _load_handoff(self, path: str) -> WarmHandoff:
        """Rebuild a ``WarmHandoff`` persisted by ``_save_handoff``.
        Requests get FRESH futures (the writer's died with its process);
        they are exposed on ``self.resumed`` after adoption.  Adaptive
        controllers are reconstructed from this server's config and
        restored bit-exactly via ``load_state_dict``."""
        from repro.core.annealing import AdaptiveController
        from repro.runtime.checkpoint import CheckpointManager
        mgr = CheckpointManager(path, keep=1, async_save=False)
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no server handoff in {path}")
        extra = mgr.manifest(step).get("extra", {})
        if extra.get("kind") != "sort-server-handoff":
            raise ValueError(
                f"{path} step {step} is not a SortServer handoff "
                f"(kind={extra.get('kind')!r})")
        metas = extra["requests"]
        names: list[str] = []
        for i, m in enumerate(metas):
            names += [f"req{i}_x", f"req{i}_key"]
            if m["has_state"]:
                names += [f"req{i}_orders", f"req{i}_keys",
                          f"req{i}_alive", f"req{i}_losses"]
                if m["has_done"]:
                    names.append(f"req{i}_done")
                if m["has_ctrl"]:
                    names += [f"req{i}_ctrl_{f}"
                              for f in AdaptiveController._STATE_FIELDS]
        # int placeholder leaves carry no dtype, so restore() returns
        # the arrays exactly as saved — no cast on the resume path.
        arrays, _ = mgr.restore({k: 0 for k in names}, step)
        now = time.monotonic()
        reqs = []
        for i, m in enumerate(metas):
            req = _SortRequest(
                x=arrays[f"req{i}_x"], hw=tuple(m["hw"]), d=int(m["d"]),
                key=arrays[f"req{i}_key"], future=Future(),
                priority=int(m["priority"]), seq=int(m["seq"]),
                deadline=(None if m["deadline_left"] is None
                          else now + float(m["deadline_left"])),
                submitted=now, progress=int(m["progress"]),
                attempts=int(m["attempts"]), norm=float(m["norm"]))
            req.strikes = int(m.get("strikes", 0))
            req.degraded = tuple(m.get("degraded", ()))
            req.brownout_cull = bool(m.get("brownout_cull", False))
            if m.get("guardrail") is not None:
                from repro.runtime.guardrails import GuardrailPolicy
                req.guardrail = GuardrailPolicy(**m["guardrail"])
            if m.get("cfg_override") is not None:
                from repro.core.shufflesoftsort import (
                    ShuffleSoftSortConfig,
                )
                req.cfg_override = ShuffleSoftSortConfig(
                    **m["cfg_override"])
            if m["has_state"]:
                req.orders = arrays[f"req{i}_orders"]
                req.keys = arrays[f"req{i}_keys"]
                req.alive = arrays[f"req{i}_alive"]
                req.losses = arrays[f"req{i}_losses"]
                if m["has_done"]:
                    req.done_mask = arrays[f"req{i}_done"].astype(bool)
                if m["has_ctrl"]:
                    from repro.core.shufflesoftsort import (
                        make_adaptive_controller,
                    )
                    # A brownout-forced-adaptive request on a fixed
                    # server carries the adaptive schedule in its
                    # cfg_override, not the server config.
                    ctrl_cfg = (req.cfg_override
                                if req.cfg_override is not None
                                else self.cfg)
                    ctrl = make_adaptive_controller(
                        ctrl_cfg, len(req.losses), req.x.shape[0],
                        seg_len=self.seg_len)
                    ctrl.load_state_dict(
                        {f: arrays[f"req{i}_ctrl_{f}"]
                         for f in AdaptiveController._STATE_FIELDS})
                    req.ctrl = ctrl
            reqs.append(req)
        return WarmHandoff(requests=reqs, rng_state=extra["rng_state"],
                           seq=int(extra["seq"]),
                           injector_state=extra.get("injector_state"),
                           brownout_level=int(
                               extra.get("brownout_level", 0)),
                           evicted_devices=tuple(
                               extra.get("evicted_devices", []) or []),
                           health_state=extra.get("health_state"))

    # ---- resolution bookkeeping (every future resolves exactly once) ----

    def _resolve_ok(self, req: _SortRequest, result):
        if req.future.done():       # pragma: no cover - defensive
            return
        self.stats["completed"] += 1
        if req.attempts > 0:
            self.stats["recoveries"] += 1
        latency_ms = (time.monotonic() - req.submitted) * 1e3
        self.stats["latencies_ms"].append(latency_ms)
        self.events.append({"event": "complete", "seq": req.seq,
                            "latency_ms": latency_ms,
                            "attempts": req.attempts})
        req.future.set_result(result)

    def _resolve_exc(self, req: _SortRequest, exc: Exception, counter: str):
        if req.future.done():       # pragma: no cover - defensive
            return
        self.stats[counter] += 1
        self.events.append({"event": counter, "seq": req.seq})
        req.future.set_exception(exc)

    def _reject_all(self, exc: Exception):
        with self._cv:
            doomed = self._pending + self._active
            self._pending, self._active = [], []
        for req in doomed:
            if not req.future.done():
                self._resolve_exc(req, exc, "failed")

    # ---- scheduler -------------------------------------------------------

    def _run(self):
        while True:
            with self._cv:
                while (not self._stop and not self._pending
                       and not self._active):
                    self._cv.wait(timeout=0.5)
                if self._stop:
                    break
                fresh_wake = not self._active
            if fresh_wake and self.max_wait_s > 0:
                time.sleep(self.max_wait_s)   # let a submit burst coalesce
            try:
                did_work = self._tick()
            except Exception as e:  # pragma: no cover - defensive
                # A scheduler bug must never strand futures: fail
                # everything in flight (typed) and keep serving.
                err = RequestFailed(f"scheduler error: {e!r}")
                err.__cause__ = e
                self._reject_all(err)
                continue
            if not did_work:
                time.sleep(0.02)              # pending all in backoff
        # Warm restart: a preempted server leaves its in-flight requests
        # intact for close(drain=False) to hand off.
        if not self._preempt:
            self._reject_all(ServerClosed("SortServer closed"))

    def _admit(self, req: _SortRequest):
        """First admission: derive restart keys + engine state.  Restart
        0 keeps the raw key so the single-restart result reproduces a
        sequential run; re-admissions after a fault keep their state."""
        if req.orders is not None:
            return
        from repro.core.losses import mean_pairwise_distance
        s, n = self.n_restarts, req.x.shape[0]
        base = jnp.asarray(req.key)
        if s == 1:
            keys = base[None]
        else:
            keys = jnp.concatenate(
                [base[None],
                 jax.random.split(jax.random.fold_in(base, 1), s - 1)])
        req.keys = np.array(keys, np.uint32).reshape(s, 2)  # writable copy
        req.norm = float(np.float32(
            mean_pairwise_distance(jnp.asarray(req.x))))
        req.orders = np.tile(np.arange(n, dtype=np.int32), (s, 1))
        req.alive = np.arange(s)
        req.losses = np.full((s, self.cfg.rounds), np.nan, np.float32)
        # Adaptivity is per REQUEST config, not per server: a brownout
        # rung (or a caller) can force schedule="adaptive" via
        # cfg_override on an otherwise fixed-schedule server.
        cfg_req = self._cfg_for(req)
        if cfg_req.schedule == "adaptive":
            from repro.core.shufflesoftsort import make_adaptive_controller
            req.ctrl = make_adaptive_controller(
                cfg_req, s, n, seg_len=self.seg_len)
            req.done_mask = np.zeros(s, bool)
        self.events.append({"event": "admit", "seq": req.seq})

    def _cfg_for(self, req: _SortRequest):
        """The config this request dispatches under: the server config,
        unless an integrity self-heal installed a per-request override
        (kernel retired, band widened, dtype promoted)."""
        return self.cfg if req.cfg_override is None else req.cfg_override

    def _regime(self, req: _SortRequest) -> str:
        from repro.core.shufflesoftsort import (
            resolve_band,
            rung_aligned_switch,
        )
        cfg = self._cfg_for(req)
        n = req.x.shape[0]
        if resolve_band(cfg, n) is None:
            return "dense"
        if req.ctrl is not None:
            # Measured switch, from the request's controller: the
            # request runs banded once EVERY live restart's own tail
            # bound has cleared (conservative — the laggard holds its
            # batchmates dense a rung longer, which is exact, just
            # costlier; with n_restarts == 1 this is exactly the
            # engine's per-instance rule, so single-restart serving
            # stays bit-identical to the adaptive engine paths).
            live = req.alive[~req.done_mask]
            return ("banded" if live.size and req.ctrl.banded[live].all()
                    else "dense")
        ck = (n, cfg)
        if ck not in self._switch_cache:
            self._switch_cache[ck] = rung_aligned_switch(
                cfg, n, self.seg_len)
        return "banded" if req.progress >= self._switch_cache[ck] else "dense"

    def _tick(self) -> bool:
        """One scheduler pass: grow back returned devices, step the
        brownout ladder, shed expired, admit (applying the ladder),
        dispatch one rung per (shape bucket, regime) group, cull,
        finalize."""
        now = time.monotonic()
        self._poll_device_returns()
        with self._cv:
            self._update_brownout(len(self._pending))
        admitted: list[_SortRequest] = []
        with self._cv:
            keep = []
            for req in self._pending:
                if req.deadline is not None and now > req.deadline:
                    self._resolve_exc(
                        req, DeadlineExceeded(
                            f"deadline passed while queued (seq {req.seq})"),
                        "deadline_missed")
                else:
                    keep.append(req)
            keep.sort(key=lambda r: (-r.priority, r.seq))
            active_inst = sum(r.n_live for r in self._active)
            rest = []
            for req in keep:
                need = req.n_live
                fits = (active_inst + need <= self.max_active
                        or (active_inst == 0 and not admitted))
                if now >= req.eligible_at and fits:
                    admitted.append(req)
                    active_inst += need
                else:
                    rest.append(req)
            self._pending = rest
        for req in admitted:
            self._apply_brownout(req, now)
            self._admit(req)
        self._active.extend(admitted)
        if not self._active:
            return False

        # shed expired active requests at the round boundary
        still = []
        for req in self._active:
            if req.deadline is not None and now > req.deadline:
                self._resolve_exc(
                    req, DeadlineExceeded(
                        f"deadline passed at round {req.progress} "
                        f"(seq {req.seq})"),
                    "deadline_missed")
            else:
                still.append(req)
        self._active = still

        groups: dict[tuple, list[_SortRequest]] = {}
        for req in self._active:
            # Guardrail policy and self-healed config extend the group
            # key: every request in one device call must share a config
            # (one compiled program) and a probe policy (uniform
            # verification of the call's slices).
            groups.setdefault(
                ((req.hw, req.d), self._regime(req),
                 req.guardrail, req.cfg_override), []).append(req)
        for (sig, regime, _pol, _ovr), reqs in groups.items():
            chunk: list[_SortRequest] = []
            size = 0
            for req in reqs:
                if chunk and size + req.n_live > self._bucket_cap:
                    self._dispatch(chunk, regime)
                    chunk, size = [], 0
                chunk.append(req)
                size += req.n_live
            if chunk:
                self._dispatch(chunk, regime)
        return True

    def _dispatch(self, reqs: list[_SortRequest], regime: str):
        """One coalesced device call advancing ``reqs`` by one rung.

        Adaptive mode dispatches only each request's LIVE restarts
        (early-stopped rows stay frozen at their converged state), at
        their controller's schedule positions — a plateau jump shows up
        here as a request whose next segment reads a colder slice of
        the tau schedule than its executed-round count suggests.
        """
        hw = reqs[0].hw
        cfg_use = self._cfg_for(reqs[0])   # uniform per group (key'd)
        # Adaptivity is a property of the GROUP's config (cfg_override
        # is in the group key), so brownout-forced-adaptive requests
        # dispatch adaptively on an otherwise fixed server.
        adaptive = cfg_use.schedule == "adaptive"
        pol = reqs[0].guardrail
        guarded = pol is not None and pol.mode != "off"
        # Per-request rows going into this call (adaptive: live only).
        sels = [np.flatnonzero(~r.done_mask) if adaptive
                else np.arange(len(r.alive)) for r in reqs]
        xs = np.concatenate(
            [np.repeat(r.x[None], len(sel), axis=0)
             for r, sel in zip(reqs, sels)])
        orders = np.concatenate(
            [r.orders[sel] for r, sel in zip(reqs, sels)])
        keys = np.concatenate([r.keys[sel] for r, sel in zip(reqs, sels)])
        norms = np.concatenate(
            [np.full(len(sel), r.norm, np.float32)
             for r, sel in zip(reqs, sels)])
        if adaptive:
            progress = np.concatenate(
                [r.ctrl.pos[r.alive[sel]] for r, sel in zip(reqs, sels)])
        else:
            progress = np.concatenate(
                [np.full(len(sel), r.progress, np.int64)
                 for r, sel in zip(reqs, sels)])
        bs = len(progress)
        # Guardrail probes need this rung's INPUT state after the
        # commit loop overwrites per-request state: alias the pre-pad
        # arrays (padding below reallocates, so these stay intact).
        xs_in, orders_in, keys_in = xs, orders, keys
        norms_in, progress_in = norms, progress
        # pad to the next power of two (capped at max_batch when the
        # chunk fits under it) so compiled programs stay bounded by
        # |signatures| x |regimes| x log2(max_batch), not traffic
        bucket = (min(_next_pow2(bs), self.max_batch)
                  if bs <= self.max_batch else _next_pow2(bs))
        if bucket > bs:
            pad = bucket - bs
            xs = np.concatenate([xs, np.repeat(xs[:1], pad, axis=0)])
            orders = np.concatenate(
                [orders, np.repeat(orders[:1], pad, axis=0)])
            keys = np.concatenate([keys, np.repeat(keys[:1], pad, axis=0)])
            norms = np.concatenate([norms, np.repeat(norms[:1], pad)])
            progress = np.concatenate(
                [progress, np.repeat(progress[:1], pad)])
        self.stats["compile_keys"].add(
            (hw, reqs[0].d, regime, bucket, self.seg_len))

        t0 = time.perf_counter()
        try:
            if adaptive:
                # regime= bypasses the model-based switch check (the
                # controller owns the grouping); with_w= feeds the
                # measured tail bound.
                o, k, l, w = self._engine(
                    xs, orders, keys, norms, progress, self.seg_len,
                    hw=hw, cfg=cfg_use, mesh=self.mesh,
                    regime=regime, with_w=True)
                w = np.asarray(w)
            else:
                w = None
                o, k, l = self._engine(xs, orders, keys, norms, progress,
                                       self.seg_len, hw=hw, cfg=cfg_use,
                                       mesh=self.mesh)
            o, k, l = np.asarray(o), np.asarray(k), np.asarray(l)
        except Exception as e:
            if not self._device_failure(reqs, e):
                self._on_failure(reqs, e)
            return
        if self.device_health is not None and self.mesh is not None:
            self.device_health.record_success(
                dv.id for dv in self.mesh.devices.flat)
        # Divergence sentinel: a non-finite loss (or soft-sort key) must
        # never commit into request state — route it through the retry
        # path as a typed NumericalDivergence BEFORE the commit below,
        # so the re-dispatch replays from the last finite boundary.
        # Guarded groups instead attribute non-finite state per request
        # slice (the monitor's "finite" probe), so one corrupted request
        # never fails its clean batchmates.
        if not guarded and (
                not np.isfinite(l).all()
                or (adaptive and not np.isfinite(w).all())):
            from repro.core.shufflesoftsort import NumericalDivergence
            self._on_failure(reqs, NumericalDivergence(
                f"non-finite loss in serving dispatch (regime {regime})",
                round=int(progress_in.min()),
                dtype=str(cfg_use.compute_dtype), context="serving"))
            return
        dt = time.perf_counter() - t0
        self._record_timing(dt, self.seg_len * bucket)
        self.stats["batches"] += 1
        self.stats["batch_sizes"].append(bs)

        bad: list = []
        if guarded:
            bad = self._verify_slices(
                reqs, sels, regime, cfg_use, hw,
                xs_in, orders_in, keys_in, norms_in, progress_in,
                o, k, l, w)
        bad_set = {id(r) for r, _ in bad}
        off = 0
        for req, sel in zip(reqs, sels):
            nl = len(sel)
            if id(req) in bad_set:
                off += nl           # corrupted: do NOT commit; the
                continue            # retry replays this rung exactly
            if adaptive:
                orig = req.alive[sel]
                exec0 = int(req.ctrl.executed[orig[0]])
                req.orders[sel] = o[off:off + nl]
                req.keys[sel] = k[off:off + nl]
                seg_losses = l[:, off:off + nl].T        # (nl, seg)
                req.losses[orig, exec0:exec0 + self.seg_len] = seg_losses
                # Controller state commits only on a SUCCESSFUL
                # dispatch (we are past the except above), so a retried
                # request re-observes nothing and resumes bit-exactly.
                req.ctrl.observe(orig, seg_losses, w[off:off + nl])
                req.done_mask[sel] = req.ctrl.done[orig]
            else:
                req.orders = o[off:off + nl]
                req.keys = k[off:off + nl]
                req.losses[req.alive,
                           req.progress:req.progress + self.seg_len] = (
                    l[:, off:off + nl].T)
            req.progress += self.seg_len
            off += nl
            self._post_rung(req)
        for req, exc in bad:
            self._integrity_failure(req, exc)

    # ---- guardrails: per-request probe verification + self-healing ------

    def _verify_slices(self, reqs, sels, regime, cfg_use, hw,
                       xs_in, orders_in, keys_in, norms_in, progress_in,
                       o, k, l, w):
        """Run this dispatch's guardrail probes per request slice,
        BEFORE any commit.  Returns ``[(req, IntegrityViolation), ...]``
        for the slices that failed — only those requests re-queue; their
        clean batchmates commit normally (the committed request state is
        the last *verified* rung, so the retry replays exactly the
        corrupted segment).

        The shadow recompute calls ``run_round_segment`` directly (not
        ``self._engine`` — chaos tests wrap the engine in a
        ``FaultInjector``; the oracle must stay clean) with the kernel
        tier retired, on the request's own input slice.
        """
        from repro.core.shufflesoftsort import (
            _tau_schedule,
            run_round_segment,
        )
        from repro.runtime.guardrails import (
            GuardrailMonitor,
            IntegrityViolation,
        )
        taus = _tau_schedule(cfg_use)
        bad = []
        off = 0
        for req, sel in zip(reqs, sels):
            nl = len(sel)
            sl = slice(off, off + nl)
            off += nl
            if nl == 0:         # pragma: no cover - defensive
                continue
            mon = req.monitor
            if mon is None or mon.policy is not req.guardrail:
                mon = req.monitor = GuardrailMonitor(
                    req.guardrail, context="serving",
                    dtype=cfg_use.compute_dtype)
            start = int(progress_in[sl].min())
            try:
                # Adaptive w rows must be finite before ctrl.observe —
                # the unguarded global sentinel is skipped for guarded
                # groups, so attribute it here, per slice.
                if w is not None and not np.isfinite(w[sl]).all():
                    mon._fail("finite",
                              "non-finite soft-sort keys in serving "
                              f"dispatch at round {start}",
                              round=start)
                oracle_l = oracle_o = None
                if mon.wants_shadow(start):
                    ocfg = dataclasses.replace(cfg_use, use_kernel=False)
                    if w is not None:
                        sh = run_round_segment(
                            xs_in[sl], orders_in[sl], keys_in[sl],
                            norms_in[sl], progress_in[sl], self.seg_len,
                            hw=hw, cfg=ocfg, regime=regime)
                    else:
                        sh = run_round_segment(
                            xs_in[sl], orders_in[sl], keys_in[sl],
                            norms_in[sl], progress_in[sl], self.seg_len,
                            hw=hw, cfg=ocfg)
                    oracle_l = np.asarray(sh[2], np.float32)
                    if mon.compare_orders():
                        oracle_o = np.asarray(sh[0])
                band = None
                if regime == "banded" and req.ctrl is not None:
                    band = req.ctrl.band
                mon.check_rung(
                    start=start,
                    losses=l[:, sl],
                    orders=o[sl],
                    n=req.x.shape[0],
                    keys_in=keys_in[sl], keys_out=k[sl],
                    seg_len=self.seg_len,
                    ws=None if w is None else w[sl],
                    tau=taus[np.asarray(progress_in[sl], np.int64)],
                    band=band,
                    oracle_losses=oracle_l, oracle_orders=oracle_o)
            except IntegrityViolation as e:
                bad.append((req, e))
        return bad

    def _integrity_failure(self, req: _SortRequest, exc):
        """Remediation for a probe failure on one request: record the
        structured incident, count a strike, and past the policy's
        ``heal_after`` budget consume a ``DivergencePolicy`` rung as a
        per-request config override (kernel→oracle, band widening,
        dtype promotion) — then re-queue the request from its last
        verified boundary through the normal retry path."""
        rec = exc.incident() if hasattr(exc, "incident") else {
            "probe": None, "message": str(exc)}
        rec["seq"] = int(req.seq)
        self.stats["integrity_violations"] += 1
        self.stats["integrity_incidents"].append(rec)
        self.events.append({"event": "integrity", "seq": req.seq,
                            "probe": getattr(exc, "probe", None),
                            "round": getattr(exc, "round", None)})
        req.strikes += 1
        if req.strikes > req.guardrail.heal_after:
            cfg_use = self._cfg_for(req)
            step = self.degrade.apply(cfg_use, exc)
            if step is not None:
                healed, note = step
                req.cfg_override = healed
                req.monitor = None      # dtype/config may have changed
                req.strikes = 0
                self.stats["self_heals"] += 1
                self.events.append({"event": "self_heal",
                                    "seq": req.seq, "action": note})
        self._on_failure([req], exc)

    def _post_rung(self, req: _SortRequest):
        """Rung-boundary bookkeeping: tournament cull, then finalize.

        Adaptive mode ranks every not-yet-culled restart (including
        early-stopped ones — they converged, they still compete) by its
        LAST-EXECUTED loss, and finalizes the request at the first
        boundary where no restart is still annealing — the adaptive
        early exit the ``adaptive_exits`` / ``rounds_saved`` counters
        measure.
        """
        from repro.core.shufflesoftsort import _tournament_cull
        s_k = len(req.alive)
        if req.progress in self._cull_edges and s_k > 1:
            # The brownout "culled" rung degrades the tournament to its
            # floor: keep only the current best restart at every
            # interior boundary.
            keep = (1 if req.brownout_cull else
                    max(1, int(np.ceil(s_k * (1.0 - self.cull_fraction)))))
            if keep < s_k:
                if req.ctrl is not None:
                    last = req.ctrl.executed[req.alive] - 1
                    final = req.losses[req.alive, last][None, :]
                else:
                    final = req.losses[req.alive, req.progress - 1][None, :]
                sel = _tournament_cull(final, keep)[0]
                if req.ctrl is not None:
                    kept = np.zeros(s_k, bool)
                    kept[sel] = True
                    req.ctrl.mark_culled(req.alive[~kept])
                    req.done_mask = req.done_mask[sel]
                req.alive = req.alive[sel]
                req.orders = req.orders[sel]
                req.keys = req.keys[sel]
                self.stats["culled"] += s_k - keep
                self.events.append({"event": "cull", "seq": req.seq,
                                    "kept": keep, "of": s_k})
        if req.ctrl is not None:
            if req.done_mask.all():
                last = req.ctrl.executed[req.alive] - 1
                final = req.losses[req.alive, last]
                win = int(np.argmin(final))
                order = req.orders[win]
                saved = self.cfg.rounds - int(
                    req.ctrl.executed[req.alive].max())
                if saved > 0:
                    self.stats["adaptive_exits"] += 1
                    self.stats["rounds_saved"] += saved
                    self.events.append(
                        {"event": "adaptive_exit", "seq": req.seq,
                         "round": self.cfg.rounds - saved,
                         "saved": saved})
                self._active.remove(req)
                self._resolve_ok(
                    req, (order, req.x[order], req.losses[req.alive[win]]))
            return
        if req.progress >= self.cfg.rounds:
            final = req.losses[req.alive, -1]
            win = int(np.argmin(final))
            order = req.orders[win]
            self._active.remove(req)
            self._resolve_ok(
                req, (order, req.x[order], req.losses[req.alive[win]]))

    def _on_failure(self, reqs: list[_SortRequest], exc: Exception):
        """TrainSupervisor-style restart semantics for a failed
        dispatch: each request re-queues from its last committed round
        boundary with exponential backoff, until its budget runs out."""
        now = time.monotonic()
        for req in reqs:
            req.attempts += 1
            self._active.remove(req)
            if req.attempts > self.retry.max_retries:
                self._resolve_exc(
                    req,
                    RequestFailed(
                        f"dispatch failed {req.attempts} times "
                        f"(budget {self.retry.max_retries}): {exc}"),
                    "failed")
                continue
            backoff = self.retry.backoff(req.attempts)
            req.eligible_at = now + backoff
            self.stats["retries"] += 1
            self.events.append({"event": "retry", "seq": req.seq,
                                "attempt": req.attempts,
                                "backoff_s": backoff,
                                "error": str(exc)})
            with self._cv:
                self._pending.append(req)
        # exception chains into RequestFailed via ``from`` semantics:
        for req in reqs:
            if req.future.done():
                exc_set = req.future.exception()
                if isinstance(exc_set, RequestFailed):
                    exc_set.__cause__ = exc

    def _record_timing(self, dt: float, instance_rounds: int):
        """Feed the straggler monitor (per instance-round, so batch and
        rung sizes don't masquerade as stragglers) and adapt the bucket
        cap: flag -> halve (reroute traffic into smaller batches),
        healthy streak -> restore toward max_batch."""
        flagged = self.straggler.record(
            self._dispatch_idx, dt / max(instance_rounds, 1))
        self._dispatch_idx += 1
        if flagged:
            self.stats["stragglers"] += 1
            self._bucket_cap = max(1, self._bucket_cap // 2)
            self._healthy_streak = 0
            self.events.append({"event": "straggler", "dt_s": dt,
                                "bucket_cap": self._bucket_cap})
        else:
            self._healthy_streak += 1
            if (self._healthy_streak >= self.straggler_recovery
                    and self._bucket_cap < self.max_batch):
                self._bucket_cap = min(self.max_batch,
                                       self._bucket_cap * 2)
                self._healthy_streak = 0


def _parse_band(value):
    """CLI ``--band`` -> ShuffleSoftSortConfig.band: "none" (or unset) =
    always dense, "auto" = tau-adaptive auto-sized band, an integer =
    explicit band half-width K."""
    if value is None or value == "none":
        return None
    if value == "auto":
        return "auto"
    return int(value)


def serve_sorts(args):
    """CLI driver: fire concurrent sort requests at a SortServer.
    CLI validation (grid divisibility, dtype/kernel coupling) lives in
    ``main()`` as argparse errors — survives ``python -O``, unlike the
    bare asserts it replaced."""
    from repro.core.metrics import mean_neighbor_distance
    from repro.core.shufflesoftsort import ShuffleSoftSortConfig
    from repro.launch.mesh import make_sort_mesh
    from repro.runtime.guardrails import GuardrailPolicy
    from repro.runtime.straggler import DeviceHealthMonitor

    guardrail = (None if args.guardrail == "off" else
                 GuardrailPolicy(mode=args.guardrail,
                                 shadow_rate=args.shadow_rate,
                                 seed=args.seed))
    brownout = BrownoutPolicy() if args.brownout else None
    device_health = (DeviceHealthMonitor(lost_after=args.device_health)
                     if args.device_health else None)
    hw = (args.sort_hw, args.sort_n // args.sort_hw)
    cfg = ShuffleSoftSortConfig(rounds=args.rounds,
                                chunk=min(256, args.sort_n),
                                use_kernel=args.use_kernel,
                                band=_parse_band(args.band),
                                compute_dtype=args.dtype,
                                schedule=args.schedule)
    mesh = make_sort_mesh(args.mesh_devices) if args.mesh_devices else None
    server = SortServer(hw, d=args.sort_d, cfg=cfg,
                        max_batch=args.max_batch, max_wait_ms=args.wait_ms,
                        n_restarts=args.restarts, mesh=mesh,
                        tournament_rungs=args.tournament_rungs,
                        cull_fraction=args.cull_fraction,
                        queue_depth=args.queue_depth,
                        sched_rungs=args.sched_rungs or None,
                        seed=args.seed, guardrail=guardrail,
                        brownout=brownout, device_health=device_health)
    rng = np.random.RandomState(0)
    xs = rng.rand(args.requests, args.sort_n, args.sort_d).astype(np.float32)

    t0 = time.time()
    futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
            for i in range(args.requests)]
    results = [f.result(timeout=600) for f in futs]
    wall = time.time() - t0
    server.close()

    improved = sum(
        mean_neighbor_distance(r[1], hw) < mean_neighbor_distance(x, hw)
        for r, x in zip(results, xs))
    sps = args.requests / max(wall, 1e-9)
    sizes = server.stats["batch_sizes"]
    lat = np.asarray(server.stats["latencies_ms"], np.float64)
    p50 = float(np.percentile(lat, 50)) if lat.size else 0.0
    p99 = float(np.percentile(lat, 99)) if lat.size else 0.0
    adaptive_note = ""
    if cfg.schedule == "adaptive":
        adaptive_note = (
            f"; adaptive: {server.stats['adaptive_exits']} early exits, "
            f"{server.stats['rounds_saved']} rounds saved")
    guard_note = ""
    if guardrail is not None:
        guard_note = (
            f"; guardrail {guardrail.mode}: "
            f"{server.stats['integrity_violations']} violations, "
            f"{server.stats['self_heals']} self-heals")
    elastic_note = ""
    deg = server.stats["degradations"]
    if brownout is not None or device_health is not None:
        deg_str = " ".join(f"{r}={deg[r]}" for r in _BROWNOUT_LADDER)
        elastic_note = (
            f"; elastic: {server.stats['evictions']} evictions, "
            f"{server.stats['reshards']} reshards, "
            f"{server.stats['device_returns']} returns; "
            f"degradations {deg_str}")
    print(f"served {args.requests} sort requests in {wall:.2f}s "
          f"({sps:.2f} sorts/s) across {server.stats['batches']} device "
          f"batches (sizes {sizes}); p50 {p50:.1f}ms p99 {p99:.1f}ms; "
          f"{improved}/{args.requests} layouts improved"
          f"{adaptive_note}{guard_note}{elastic_note}")
    return {"sorts_per_s": sps, "batches": server.stats["batches"],
            "improved": int(improved), "p50_ms": p50, "p99_ms": p99,
            "adaptive_exits": server.stats["adaptive_exits"],
            "rounds_saved": server.stats["rounds_saved"],
            "integrity_violations": server.stats["integrity_violations"],
            "self_heals": server.stats["self_heals"],
            "evictions": server.stats["evictions"],
            "reshards": server.stats["reshards"],
            "device_returns": server.stats["device_returns"],
            "degradations": dict(deg)}


# --------------------------------------------------------------------------
# LM decode serving.
# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "sort"), default="lm")
    ap.add_argument("--arch", choices=list_archs(), default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    # sort-workload knobs
    ap.add_argument("--sort-n", type=int, default=256)
    ap.add_argument("--sort-hw", type=int, default=16,
                    help="grid height; width = sort-n / sort-hw")
    ap.add_argument("--sort-d", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--restarts", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--wait-ms", type=float, default=5.0)
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help="shard coalesced batches over this many devices "
                         "(0 = single-device vmap engine)")
    ap.add_argument("--tournament-rungs", type=int, default=1,
                    help=">1 runs restarts as a successive-halving "
                         "tournament (needs --restarts > 1)")
    ap.add_argument("--cull-fraction", type=float, default=0.5)
    ap.add_argument("--use-kernel", action="store_true",
                    help="run the SoftSort apply (fwd+bwd) through the "
                         "fused Pallas kernel tier instead of chunked jnp")
    ap.add_argument("--dtype", choices=("float32", "bfloat16"),
                    default="float32",
                    help="kernel-tier compute precision (with "
                         "--use-kernel): bfloat16 halves the kernels' "
                         "payload HBM traffic; keys, stats, and Adam "
                         "math stay f32 (EXPERIMENTS.md §Perf)")
    ap.add_argument("--band", default=None,
                    help="banded O(N*K) apply: an integer half-width K, "
                         "'auto' to size it from N and the tau schedule, "
                         "or 'none' (default) for the dense apply; hot "
                         "early rounds stay dense until the tail bound "
                         "clears (EXPERIMENTS.md §Perf)")
    ap.add_argument("--queue-depth", type=int, default=64,
                    help="admission-control bound: submits past this many "
                         "waiting requests raise QueueFull")
    ap.add_argument("--sched-rungs", type=int, default=0,
                    help="scheduler preemption quantum: split the round "
                         "schedule into this many rungs (0 = auto)")
    ap.add_argument("--schedule", choices=("fixed", "adaptive"),
                    default="fixed",
                    help="'adaptive' runs the plateau-driven controller: "
                         "requests leave the anneal at the first "
                         "converged rung boundary (EXPERIMENTS.md "
                         "§Adaptive)")
    ap.add_argument("--seed", type=int, default=0,
                    help="server-owned PRNG seed for requests submitted "
                         "without a key (reproducible serving runs)")
    ap.add_argument("--guardrail", choices=("off", "invariants", "shadow"),
                    default="off",
                    help="permutation-integrity probes at every rung "
                         "boundary: 'invariants' runs the free host-side "
                         "checks (valid permutation, loss sanity, PRNG "
                         "key chain), 'shadow' adds sampled pure-jnp "
                         "oracle recompute (EXPERIMENTS.md §Robustness, "
                         "'Silent corruption')")
    ap.add_argument("--shadow-rate", type=float, default=None,
                    help="fraction of rungs to shadow-recompute under "
                         "--guardrail shadow (default 1/32; overhead "
                         "scales with the rate)")
    ap.add_argument("--brownout", action="store_true",
                    help="arm the overload brownout ladder: under "
                         "capacity loss or queue pressure, degrade new "
                         "requests culled -> adaptive -> banded -> bf16 "
                         "before shedding (EXPERIMENTS.md §Robustness, "
                         "'Elastic capacity')")
    ap.add_argument("--device-health", type=int, default=0,
                    metavar="STRIKES",
                    help="evict a device after this many DeviceLost "
                         "dispatch failures and re-shard the mesh over "
                         "the survivors at the next rung boundary "
                         "(0 = off; needs --mesh-devices)")
    args = ap.parse_args(argv)

    if args.workload == "sort":
        # CLI validation as argparse errors (not asserts: those vanish
        # under ``python -O`` and print bare tracebacks).
        if args.sort_hw <= 0 or args.sort_n % args.sort_hw != 0:
            ap.error(f"--sort-hw {args.sort_hw} must be a positive "
                     f"divisor of --sort-n {args.sort_n} (grid height)")
        # compute_dtype is a kernel-tier knob; without --use-kernel the
        # chunked-jnp apply runs f32 regardless, so a bare --dtype
        # bfloat16 would silently do nothing — refuse instead.
        if args.dtype != "float32" and not args.use_kernel:
            ap.error("--dtype bfloat16 requires --use-kernel (the jnp "
                     "apply tier has no bf16 mode)")
        # --shadow-rate only modulates the shadow tier; a rate with the
        # probes off (or invariants-only) would silently do nothing.
        if args.shadow_rate is not None and args.guardrail != "shadow":
            ap.error("--shadow-rate requires --guardrail shadow (the "
                     f"'{args.guardrail}' tier runs no shadow "
                     "recompute)")
        if args.shadow_rate is not None and not (
                0.0 <= args.shadow_rate <= 1.0):
            ap.error(f"--shadow-rate {args.shadow_rate} must be in "
                     "[0, 1]")
        if args.shadow_rate is None:
            args.shadow_rate = 0.03125
        if args.device_health < 0:
            ap.error(f"--device-health {args.device_health} must be "
                     ">= 0 (strike budget; 0 disables)")
        if args.device_health and not args.mesh_devices:
            ap.error("--device-health needs --mesh-devices (eviction "
                     "re-shards a device mesh; the vmap engine has no "
                     "devices to lose)")
        return serve_sorts(args)

    cfg = reduced_config(get_config(args.arch), **PRESETS[args.preset])
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.RandomState(0)
    lens = rng.randint(4, args.prompt_len + 1, size=args.requests)
    max_len = int(lens.max())
    total = max_len + args.max_new
    prompts = rng.randint(0, cfg.vocab_size,
                          size=(args.requests, max_len)).astype(np.int32)

    ctx = None
    if cfg.family == "vlm":
        ctx = jnp.zeros((args.requests, cfg.vision_tokens, cfg.vision_d))
    if cfg.is_encdec:
        ctx = jnp.zeros((args.requests, cfg.audio_frames, cfg.d_model))

    jit_prefill = jax.jit(lambda p, t, c: prefill(p, cfg, t, c))
    jit_decode = jax.jit(
        lambda p, t, caches, pos, c: decode_step(p, cfg, t, caches, pos, c))

    t0 = time.time()
    logits, caches = jit_prefill(params, jnp.asarray(prompts), ctx)
    # pad caches to the full decode horizon
    def pad_cache(a):
        if a.ndim >= 4 and a.shape[2] == max_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, args.max_new)
            return jnp.pad(a, pad)
        return a
    caches = jax.tree.map(pad_cache, caches)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, 1)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.max_new - 1):
        tok_logits, caches = jit_decode(params, tok, caches,
                                        jnp.int32(max_len + i), ctx)
        tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    decode_s = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)

    tps = args.requests * (args.max_new - 1) / max(decode_s, 1e-9)
    print(f"served {args.requests} requests (prompt<= {max_len}): "
          f"prefill {prefill_s:.2f}s, decode {decode_s:.2f}s "
          f"({tps:.1f} tok/s), output shape {gen.shape}")
    assert gen.shape == (args.requests, args.max_new)
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    return {"tok_per_s": tps, "prefill_s": prefill_s}


if __name__ == "__main__":
    main()
