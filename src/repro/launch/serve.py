"""Batched serving drivers: continuous-batching loops on CPU scale.

Two workloads share this entrypoint:

* ``--workload lm`` (default) — LM decode serving.  Requests arrive with
  different prompt lengths; the scheduler right-pads into a fixed decode
  batch, prefills once, then decodes step-locked with per-request stop
  positions (the fixed-shape analogue of continuous batching — slot
  reuse keeps XLA shapes static, which is what a TPU serving stack
  needs).

      PYTHONPATH=src python -m repro.launch.serve \
          --arch qwen1.5-0.5b --preset tiny --requests 8 --max-new 32

* ``--workload sort`` — grid-sorting serving.  ``SortServer`` runs a
  request-coalescing queue: concurrent ``submit()`` calls (e.g. one per
  user upload) are drained into one ``shuffle_soft_sort_batched`` device
  call, so R requests cost one batched program of B = R instances
  instead of R sequential ShuffleSoftSort runs.

      PYTHONPATH=src python -m repro.launch.serve \
          --workload sort --requests 8 --sort-n 256 --rounds 30

  Scale-out: ``--mesh-devices D`` shards each coalesced batch across a
  D-device "data" mesh, and ``--tournament-rungs K --restarts S`` runs
  the S seeds per request as a successive-halving tournament
  (EXPERIMENTS.md §Scaling).  ``--use-kernel`` routes every instance's
  SoftSort apply — forward AND backward — through the fused Pallas
  kernel tier (EXPERIMENTS.md §Perf) instead of the chunked-jnp stream,
  and ``--band K`` / ``--band auto`` additionally switches the apply to
  the O(N*K) banded tier once the anneal is cold enough for its tail
  bound (EXPERIMENTS.md §Perf) — both compose with the mesh and the
  tournament.  ``--dtype bfloat16`` (with ``--use-kernel``) selects the
  mixed-precision kernel tier: bf16 score/payload compute and half the
  payload HBM traffic, f32 keys/stats/Adam (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import argparse
import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.train import PRESETS
from repro.models import (
    decode_step,
    init_model,
    make_caches,
    prefill,
    reduced_config,
)


# --------------------------------------------------------------------------
# Sort serving: request-coalescing queue over shuffle_soft_sort_batched.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _SortRequest:
    x: np.ndarray            # (N, d)
    key: jax.Array           # PRNG key for this request
    future: Future


class SortServer:
    """Coalesces concurrent grid-sort requests into batched device calls.

    All requests must share one problem signature (N = hw[0] * hw[1] and
    feature dim d) — the fixed-shape contract that keeps XLA from
    recompiling, mirroring the LM driver's static decode batch.  A
    background worker blocks on the queue, drains up to ``max_batch``
    requests that arrive within ``max_wait_ms`` of the first, stacks
    them, and runs ONE ``shuffle_soft_sort_batched`` call (optionally
    with ``n_restarts`` seeds per request).  Each future resolves to the
    per-request ``(order, sorted, losses)`` triple of the winning
    restart — bit-identical to a sequential ``shuffle_soft_sort`` call
    with the same key when ``n_restarts == 1``.

    Scale-out knobs (EXPERIMENTS.md §Scaling):

    * ``mesh`` — a 1-D "data" mesh (``repro.launch.mesh.make_sort_mesh``);
      the coalesced batch's flattened requests x restarts grid is
      shard_mapped across its devices.  Per-seed results are unchanged.
    * ``tournament_rungs > 1`` (with ``n_restarts > 1``) — restarts run
      as a successive-halving tournament instead of all-to-the-end, so
      the same latency budget affords more seeds per request.
    """

    def __init__(self, hw, d, cfg=None, max_batch: int = 8,
                 max_wait_ms: float = 2.0, n_restarts: int = 1,
                 mesh=None, tournament_rungs: int = 1,
                 cull_fraction: float = 0.5):
        from repro.core.shufflesoftsort import ShuffleSoftSortConfig
        self.hw = tuple(hw)
        self.n = self.hw[0] * self.hw[1]
        self.d = d
        self.cfg = cfg or ShuffleSoftSortConfig()
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        self.n_restarts = n_restarts
        self.mesh = mesh
        self.tournament_rungs = int(tournament_rungs)
        self.cull_fraction = float(cull_fraction)
        self.stats = {"requests": 0, "batches": 0, "batch_sizes": []}
        self._q: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def submit(self, x: np.ndarray, key=None) -> Future:
        """Enqueue one (N, d) problem; returns a Future of
        ``(order (N,), sorted (N, d), losses (R,))``."""
        if self._stop.is_set():
            raise RuntimeError("SortServer is closed")
        x = np.asarray(x, np.float32)
        assert x.shape == (self.n, self.d), (x.shape, (self.n, self.d))
        if key is None:
            key = jax.random.PRNGKey(np.random.randint(0, 2**31 - 1))
        fut: Future = Future()
        self._q.put(_SortRequest(x, key, fut))
        return fut

    def close(self):
        self._stop.set()
        self._q.put(None)                    # wake the worker
        self._worker.join(timeout=30)

    # ---- worker ----------------------------------------------------------

    def _drain(self):
        """Block for the first request, then coalesce a batch."""
        first = self._q.get()
        if first is None:
            return []
        batch = [first]
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            timeout = deadline - time.monotonic()
            try:
                req = self._q.get(timeout=max(timeout, 0.0))
            except queue.Empty:
                break
            if req is None:
                break
            batch.append(req)
        return batch

    def _dispatch(self, xs, keys):
        """One coalesced device call: plain batched engine, or the
        successive-halving tournament when configured.  Both honour
        ``self.mesh``.  Returns per-request (order, sorted, losses)."""
        from repro.core.shufflesoftsort import (
            restart_tournament,
            shuffle_soft_sort_batched,
        )
        if self.tournament_rungs > 1 and self.n_restarts > 1:
            res = restart_tournament(
                xs, self.hw, self.cfg, n_restarts=self.n_restarts,
                keys=keys, cull_fraction=self.cull_fraction,
                n_rungs=self.tournament_rungs, mesh=self.mesh)
            losses = res.all_losses[
                np.arange(xs.shape[0]), res.best_restart]
        else:
            res = shuffle_soft_sort_batched(
                xs, self.hw, self.cfg, n_restarts=self.n_restarts,
                keys=keys, mesh=self.mesh)
            losses = res.losses
        return res.order, res.sorted, losses

    def _run(self):
        while not self._stop.is_set():
            batch = self._drain()
            if not batch:
                continue
            try:
                xs = jnp.asarray(np.stack([r.x for r in batch]))
                if self.n_restarts == 1:
                    keys = jnp.stack([r.key for r in batch])[:, None]
                else:
                    # Distinct per-restart streams derived from each
                    # request key (restart 0 keeps the raw key so the
                    # single-restart result stays reproducible).
                    keys = jnp.stack([
                        jnp.concatenate(
                            [r.key[None], jax.random.split(
                                jax.random.fold_in(r.key, 1),
                                self.n_restarts - 1)])
                        for r in batch])
                orders, sorteds, losses = self._dispatch(xs, keys)
                self.stats["requests"] += len(batch)
                self.stats["batches"] += 1
                self.stats["batch_sizes"].append(len(batch))
                for i, r in enumerate(batch):
                    r.future.set_result(
                        (orders[i], sorteds[i], losses[i]))
            except Exception as e:      # pragma: no cover - defensive
                for r in batch:
                    if not r.future.done():
                        r.future.set_exception(e)
        # Shutdown: fail any request still queued so no caller blocks
        # forever on a future the worker will never fill.
        while True:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                break
            if req is not None and not req.future.done():
                req.future.set_exception(RuntimeError("SortServer closed"))


def _parse_band(value):
    """CLI ``--band`` -> ShuffleSoftSortConfig.band: "none" (or unset) =
    always dense, "auto" = tau-adaptive auto-sized band, an integer =
    explicit band half-width K."""
    if value is None or value == "none":
        return None
    if value == "auto":
        return "auto"
    return int(value)


def serve_sorts(args):
    """CLI driver: fire concurrent sort requests at a SortServer."""
    from repro.core.metrics import mean_neighbor_distance
    from repro.core.shufflesoftsort import ShuffleSoftSortConfig
    from repro.launch.mesh import make_sort_mesh

    hw = (args.sort_hw, args.sort_n // args.sort_hw)
    assert hw[0] * hw[1] == args.sort_n, (args.sort_n, args.sort_hw)
    # compute_dtype is a kernel-tier knob; without --use-kernel the
    # chunked-jnp apply runs f32 regardless, so a bare --dtype bfloat16
    # would silently do nothing — refuse instead.
    assert args.dtype == "float32" or args.use_kernel, (
        "--dtype bfloat16 requires --use-kernel (the jnp apply tier "
        "has no bf16 mode)")
    cfg = ShuffleSoftSortConfig(rounds=args.rounds,
                                chunk=min(256, args.sort_n),
                                use_kernel=args.use_kernel,
                                band=_parse_band(args.band),
                                compute_dtype=args.dtype)
    mesh = make_sort_mesh(args.mesh_devices) if args.mesh_devices else None
    server = SortServer(hw, d=args.sort_d, cfg=cfg,
                        max_batch=args.max_batch, max_wait_ms=args.wait_ms,
                        n_restarts=args.restarts, mesh=mesh,
                        tournament_rungs=args.tournament_rungs,
                        cull_fraction=args.cull_fraction)
    rng = np.random.RandomState(0)
    xs = rng.rand(args.requests, args.sort_n, args.sort_d).astype(np.float32)

    t0 = time.time()
    futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
            for i in range(args.requests)]
    results = [f.result(timeout=600) for f in futs]
    wall = time.time() - t0
    server.close()

    improved = sum(
        mean_neighbor_distance(r[1], hw) < mean_neighbor_distance(x, hw)
        for r, x in zip(results, xs))
    sps = args.requests / max(wall, 1e-9)
    sizes = server.stats["batch_sizes"]
    print(f"served {args.requests} sort requests in {wall:.2f}s "
          f"({sps:.2f} sorts/s) across {server.stats['batches']} device "
          f"batches (sizes {sizes}); {improved}/{args.requests} layouts "
          f"improved")
    return {"sorts_per_s": sps, "batches": server.stats["batches"],
            "improved": int(improved)}


# --------------------------------------------------------------------------
# LM decode serving.
# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=("lm", "sort"), default="lm")
    ap.add_argument("--arch", choices=list_archs(), default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    # sort-workload knobs
    ap.add_argument("--sort-n", type=int, default=256)
    ap.add_argument("--sort-hw", type=int, default=16,
                    help="grid height; width = sort-n / sort-hw")
    ap.add_argument("--sort-d", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--restarts", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--wait-ms", type=float, default=5.0)
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help="shard coalesced batches over this many devices "
                         "(0 = single-device vmap engine)")
    ap.add_argument("--tournament-rungs", type=int, default=1,
                    help=">1 runs restarts as a successive-halving "
                         "tournament (needs --restarts > 1)")
    ap.add_argument("--cull-fraction", type=float, default=0.5)
    ap.add_argument("--use-kernel", action="store_true",
                    help="run the SoftSort apply (fwd+bwd) through the "
                         "fused Pallas kernel tier instead of chunked jnp")
    ap.add_argument("--dtype", choices=("float32", "bfloat16"),
                    default="float32",
                    help="kernel-tier compute precision (with "
                         "--use-kernel): bfloat16 halves the kernels' "
                         "payload HBM traffic; keys, stats, and Adam "
                         "math stay f32 (EXPERIMENTS.md §Perf)")
    ap.add_argument("--band", default=None,
                    help="banded O(N*K) apply: an integer half-width K, "
                         "'auto' to size it from N and the tau schedule, "
                         "or 'none' (default) for the dense apply; hot "
                         "early rounds stay dense until the tail bound "
                         "clears (EXPERIMENTS.md §Perf)")
    args = ap.parse_args(argv)

    if args.workload == "sort":
        return serve_sorts(args)

    cfg = reduced_config(get_config(args.arch), **PRESETS[args.preset])
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.RandomState(0)
    lens = rng.randint(4, args.prompt_len + 1, size=args.requests)
    max_len = int(lens.max())
    total = max_len + args.max_new
    prompts = rng.randint(0, cfg.vocab_size,
                          size=(args.requests, max_len)).astype(np.int32)

    ctx = None
    if cfg.family == "vlm":
        ctx = jnp.zeros((args.requests, cfg.vision_tokens, cfg.vision_d))
    if cfg.is_encdec:
        ctx = jnp.zeros((args.requests, cfg.audio_frames, cfg.d_model))

    jit_prefill = jax.jit(lambda p, t, c: prefill(p, cfg, t, c))
    jit_decode = jax.jit(
        lambda p, t, caches, pos, c: decode_step(p, cfg, t, caches, pos, c))

    t0 = time.time()
    logits, caches = jit_prefill(params, jnp.asarray(prompts), ctx)
    # pad caches to the full decode horizon
    def pad_cache(a):
        if a.ndim >= 4 and a.shape[2] == max_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, args.max_new)
            return jnp.pad(a, pad)
        return a
    caches = jax.tree.map(pad_cache, caches)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, 1)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.max_new - 1):
        tok_logits, caches = jit_decode(params, tok, caches,
                                        jnp.int32(max_len + i), ctx)
        tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    decode_s = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)

    tps = args.requests * (args.max_new - 1) / max(decode_s, 1e-9)
    print(f"served {args.requests} requests (prompt<= {max_len}): "
          f"prefill {prefill_s:.2f}s, decode {decode_s:.2f}s "
          f"({tps:.1f} tok/s), output shape {gen.shape}")
    assert gen.shape == (args.requests, args.max_new)
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    return {"tok_per_s": tps, "prefill_s": prefill_s}


if __name__ == "__main__":
    main()
