"""Batched serving driver: continuous-batching-style loop on CPU scale.

    PYTHONPATH=src python -m repro.launch.serve \
        --arch qwen1.5-0.5b --preset tiny --requests 8 --max-new 32

Requests arrive with different prompt lengths; the scheduler right-pads
into a fixed decode batch, prefills once, then decodes step-locked with
per-request stop positions (the fixed-shape analogue of continuous
batching — slot reuse keeps XLA shapes static, which is what a TPU
serving stack needs).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.train import PRESETS
from repro.models import (
    decode_step,
    init_model,
    make_caches,
    prefill,
    reduced_config,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen1.5-0.5b")
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch), **PRESETS[args.preset])
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    rng = np.random.RandomState(0)
    lens = rng.randint(4, args.prompt_len + 1, size=args.requests)
    max_len = int(lens.max())
    total = max_len + args.max_new
    prompts = rng.randint(0, cfg.vocab_size,
                          size=(args.requests, max_len)).astype(np.int32)

    ctx = None
    if cfg.family == "vlm":
        ctx = jnp.zeros((args.requests, cfg.vision_tokens, cfg.vision_d))
    if cfg.is_encdec:
        ctx = jnp.zeros((args.requests, cfg.audio_frames, cfg.d_model))

    jit_prefill = jax.jit(lambda p, t, c: prefill(p, cfg, t, c))
    jit_decode = jax.jit(
        lambda p, t, caches, pos, c: decode_step(p, cfg, t, caches, pos, c))

    t0 = time.time()
    logits, caches = jit_prefill(params, jnp.asarray(prompts), ctx)
    # pad caches to the full decode horizon
    def pad_cache(a):
        if a.ndim >= 4 and a.shape[2] == max_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, args.max_new)
            return jnp.pad(a, pad)
        return a
    caches = jax.tree.map(pad_cache, caches)
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, 1)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.max_new - 1):
        tok_logits, caches = jit_decode(params, tok, caches,
                                        jnp.int32(max_len + i), ctx)
        tok = jnp.argmax(tok_logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    decode_s = time.time() - t0
    gen = np.concatenate(out_tokens, axis=1)

    tps = args.requests * (args.max_new - 1) / max(decode_s, 1e-9)
    print(f"served {args.requests} requests (prompt<= {max_len}): "
          f"prefill {prefill_s:.2f}s, decode {decode_s:.2f}s "
          f"({tps:.1f} tok/s), output shape {gen.shape}")
    assert gen.shape == (args.requests, args.max_new)
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab_size)
    return {"tok_per_s": tps, "prefill_s": prefill_s}


if __name__ == "__main__":
    main()
