import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import (jax locks device count on first init).

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
#     python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k --mesh multi
#     python -m repro.launch.dryrun --all --mesh single --out dryrun.json
#     python -m repro.launch.dryrun --paper --mesh multi
#
# Per cell this prints/records:
#   * compiled.memory_analysis()  (bytes per device — proves it fits)
#   * compiled.cost_analysis()    (FLOPs / bytes for the roofline)
#   * collective bytes parsed from the partitioned HLO

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models.config import SHAPE_CELLS, cell_by_name

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64"
                      r"|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device bytes moved per collective kind, from the partitioned
    module.  Bytes-on-the-wire model per op (g = group size):
      all-gather:   result * (g-1)/g      all-reduce: 2 * size * (g-1)/g
      reduce-scatter: result * (g-1)      all-to-all: size * (g-1)/g
      collective-permute: size
    """
    stats = {k: {"count": 0, "bytes": 0.0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue   # counted at -start
        type_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        g = 1
        rg = re.search(r"replica_groups=\{?\{([0-9, ]+)\}", line)
        if rg:
            g = len(rg.group(1).split(","))
        else:
            rg2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
            if rg2:
                g = int(rg2.group(2))
        if g <= 1:
            wire = 0.0 if kind != "collective-permute" else float(size)
        elif kind == "all-gather":
            wire = size * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = float(size) * (g - 1)
        elif kind == "all-to-all":
            wire = size * (g - 1) / g
        else:
            wire = float(size)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += wire
    stats["total_bytes"] = sum(v["bytes"] for v in stats.values()
                               if isinstance(v, dict))
    return stats


def _cell_costs(cfg, cell, mesh, opts=None) -> dict:
    """lower+compile one config and extract (flops, bytes, collectives)."""
    lowered = lower_cell(cfg, cell, mesh, opts)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["total_bytes"],
        "collectives": coll,
        "compiled": compiled,
    }


def probe_corrected_costs(cfg, cell, mesh, opts=None) -> dict:
    """XLA cost_analysis counts a while(scan) body ONCE regardless of trip
    count.  We therefore lower two fully-unrolled probes with 1 and 2
    repeats of the block unit: p1 = fixed + body, p2 = fixed + 2*body
    (exact — trip-count-1/2 unrolled scans have no while op), and
    extrapolate: total(R) = p1 + (R-1) * (p2 - p1).

    Whisper's encoder scan has the same repeat count as its decoder scan,
    so the combined-body linear model stays exact for the enc-dec arch.
    """
    import dataclasses
    unit, repeats = cfg.block_program()

    def probe_cfg(k):
        return dataclasses.replace(
            cfg,
            num_layers=k * len(unit),
            encoder_layers=(k if cfg.encoder_layers else 0),
            scan_unroll=True)

    p1 = _cell_costs(probe_cfg(1), cell, mesh, opts)
    p2 = _cell_costs(probe_cfg(2), cell, mesh, opts)
    out = {}
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        body = max(p2[key] - p1[key], 0.0)
        out[key] = p1[key] + (repeats - 1) * body
    out["probe1"] = {k: p1[k] for k in
                     ("flops", "bytes_accessed", "collective_bytes")}
    out["probe2"] = {k: p2[k] for k in
                     ("flops", "bytes_accessed", "collective_bytes")}
    out["repeats"] = repeats
    return out


def run_cell(arch: str, shape: str, mesh, mesh_name: str,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    cell = cell_by_name(shape)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "kind": cell.kind}

    if cell.name == "long_500k" and not cfg.supports_long_context:
        rec["status"] = "skipped"
        rec["reason"] = ("pure full-attention arch: 500k dense decode is "
                        "quadratic in sequence length, so the cell is "
                        "excluded by design rather than left to OOM")
        return rec

    t0 = time.time()
    lowered = lower_cell(cfg, cell, mesh)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:                                  # noqa: BLE001
        rec["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        rec["cost_analysis"] = {
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        }
    except Exception as e:                                  # noqa: BLE001
        rec["cost_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["collectives"] = collective_stats(hlo)

    # probe-corrected totals (scan bodies multiplied by true trip count)
    try:
        t0 = time.time()
        rec["roofline_inputs"] = probe_corrected_costs(cfg, cell, mesh)
        rec["probe_s"] = round(time.time() - t0, 1)
    except Exception as e:                                  # noqa: BLE001
        rec["roofline_inputs"] = {"error": repr(e)}

    rec["status"] = "ok"
    if verbose:
        ri = rec.get("roofline_inputs", {})
        print(f"  [{arch} x {shape} x {mesh_name}] "
              f"compile={rec['compile_s']}s "
              f"flops={ri.get('flops', 0):.3e} "
              f"bytes={ri.get('bytes_accessed', 0):.3e} "
              f"coll={ri.get('collective_bytes', 0):.3e}B", flush=True)
    return rec


def run_paper_cell(mesh, mesh_name: str, n: int = 1 << 20, d: int = 59,
                   chunk: int = 512) -> dict:
    """The paper's own workload at Self-Organizing-Gaussians scale: one
    ShuffleSoftSort inner step over N = 2^20 splat attribute vectors
    (d = 59 attrs), rows sharded over the whole mesh."""
    import functools
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.shufflesoftsort import (ShuffleSoftSortConfig,
                                            _outer_round)
    from repro.core.softsort import softsort_apply_chunked

    cfg = ShuffleSoftSortConfig(inner_steps=2, chunk=chunk)
    hw = (1 << 10, 1 << 10)
    apply_fn = functools.partial(softsort_apply_chunked, chunk=cfg.chunk)
    shard_rows = NamedSharding(mesh, P(mesh.axis_names[0]))
    shard_x = NamedSharding(mesh, P(mesh.axis_names[0], None))

    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    order = jax.ShapeDtypeStruct((n,), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    tau = jax.ShapeDtypeStruct((), jnp.float32)
    norm = jax.ShapeDtypeStruct((), jnp.float32)

    fn = functools.partial(_outer_round.__wrapped__, hw=hw, cfg=cfg,
                           apply_fn=apply_fn)
    jfn = jax.jit(fn, in_shardings=(shard_x, shard_rows, None, None, None),
                  out_shardings=(shard_rows, None))
    rec = {"arch": "paper-sort-2^20x59", "shape": f"N={n} d={d}",
           "mesh": mesh_name, "kind": "paper"}
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jfn.lower(x, order, key, tau, norm)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    cost = compiled.cost_analysis()
    rec["cost_analysis"] = {"flops": float(cost.get("flops", -1.0)),
                            "bytes_accessed": float(cost.get("bytes accessed", -1.0))}
    rec["collectives"] = collective_stats(compiled.as_text())
    rec["status"] = "ok"
    print(f"  [paper-sort x {mesh_name}] compile={rec['compile_s']}s "
          f"flops={rec['cost_analysis']['flops']:.3e} "
          f"coll={rec['collectives']['total_bytes']:.3e}B", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--paper", action="store_true",
                    help="run the paper's own sorting workload")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    print(f"mesh: {dict(mesh.shape)} ({len(mesh.devices.flat)} devices)",
          flush=True)

    records = []
    if args.paper:
        records.append(run_paper_cell(mesh, args.mesh))
    if args.all:
        for arch in list_archs():
            for cell in SHAPE_CELLS:
                try:
                    records.append(run_cell(arch, cell.name, mesh, args.mesh))
                except Exception as e:                      # noqa: BLE001
                    records.append({"arch": arch, "shape": cell.name,
                                    "mesh": args.mesh, "status": "error",
                                    "error": repr(e)})
                    print(f"  [{arch} x {cell.name}] ERROR: {e}",
                          flush=True)
    elif args.arch:
        shapes = [args.shape] if args.shape else [c.name for c in SHAPE_CELLS]
        for s in shapes:
            records.append(run_cell(args.arch, s, mesh, args.mesh))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    failures = [r for r in records if r.get("status") == "error"]
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
