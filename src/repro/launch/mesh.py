"""Mesh factories + per-mesh axis rules.

Every factory here is a FUNCTION (not a module constant) so importing
this module never touches jax device state — entrypoints set XLA_FLAGS
(e.g. ``--xla_force_host_platform_device_count``) before any jax
initialization.

Two workload families share this module:

* **LM training/serving** (``make_production_mesh``) — 2-D / 3-D meshes:

      single-pod: (16, 16)      axes ("data", "model")        — 256 chips
      multi-pod : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

  Rationale: the batch shards over ("pod", "data"); params/optimizer
  FSDP over "data" (ZeRO-3 inside a pod, pure DP across pods — the
  gradient all-reduce over "pod" is the only cross-DCN collective in
  the baseline); tensor/expert parallelism over "model".  The "model"
  axis is kept innermost so TP collectives stay on the fastest (ICI)
  links.  The factory generalizes to any (P, D, T) for elastic
  restarts.

* **Permutation workloads** (``make_sort_mesh``) — a 1-D mesh with a
  single "data" axis.  ShuffleSoftSort instances are embarrassingly
  parallel (N parameters each, zero cross-instance communication until
  the final best-restart argmin), so the right topology is the
  degenerate one: flatten the B problems x S restarts grid and shard it
  over every device.  See EXPERIMENTS.md §Scaling for measured
  devices x B x S sweeps.
"""
from __future__ import annotations

import jax

from repro.models.layers import AxisRules


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None):
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    ndev = 1
    for s in shape:
        ndev *= s
    avail = len(jax.devices())
    if avail < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, have {avail}; the dry-run "
            "entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before importing jax")
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev])


def make_sort_mesh(n_devices: int | None = None, *, devices=None):
    """1-D ("data",) mesh for sharded permutation workloads.

    ``n_devices=None`` uses every visible device.  The sharded engine
    (``shuffle_soft_sort_batched(..., mesh=...)``) splits the flattened
    B x S instance axis over "data", padding the tail shard; per-seed
    results are bit-identical to the single-device vmap engine, so the
    mesh size is purely a throughput knob (EXPERIMENTS.md §Scaling).

    ``devices=`` restricts the mesh to an explicit device list — the
    elastic re-shard path rebuilds the mesh over the SURVIVORS of a
    device eviction at a rung boundary (EXPERIMENTS.md §Robustness,
    "Elastic capacity"); because the rung carry is stored in logical
    layout, the rebuilt mesh is purely a throughput change and per-seed
    results stay bit-identical.
    """
    avail = jax.devices() if devices is None else list(devices)
    n = len(avail) if n_devices is None else int(n_devices)
    if n <= 0:
        raise RuntimeError(
            f"sort mesh wants {n} devices; n_devices must be >= 1 "
            "(None = every visible device)")
    if n > len(avail):
        raise RuntimeError(
            f"sort mesh wants {n} devices, have {len(avail)}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count before "
            "importing jax to fake more on CPU")
    return jax.make_mesh((n,), ("data",), devices=avail[:n])


def axis_rules_for(mesh) -> AxisRules:
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return AxisRules(fsdp="data", tp="model", dp=dp)


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
