"""Mesh factory + per-mesh axis rules.

``make_production_mesh`` is a FUNCTION (not a module constant) so
importing this module never touches jax device state — the dry-run
entrypoint sets XLA_FLAGS before any jax initialization.

Topology (DESIGN.md §7):
  single-pod: (16, 16)      axes ("data", "model")      — 256 chips
  multi-pod : (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Batch shards over ("pod","data"); params/optimizer FSDP over "data"
(ZeRO-3 inside a pod, pure DP across pods — gradient all-reduce over
"pod" is the only cross-DCN collective in the baseline); tensor/expert
parallelism over "model".  The factory generalizes to any (P, D, T) for
elastic restarts.
"""
from __future__ import annotations

import jax

from repro.models.layers import AxisRules


def make_production_mesh(*, multi_pod: bool = False,
                         shape: tuple[int, ...] | None = None):
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = (("pod", "data", "model") if len(shape) == 3
            else ("data", "model"))
    ndev = 1
    for s in shape:
        ndev *= s
    avail = len(jax.devices())
    if avail < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, have {avail}; the dry-run "
            "entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count before importing jax")
    return jax.make_mesh(shape, axes, devices=jax.devices()[:ndev])


def axis_rules_for(mesh) -> AxisRules:
    names = mesh.axis_names
    dp = ("pod", "data") if "pod" in names else ("data",)
    return AxisRules(fsdp="data", tp="model", dp=dp)


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
