"""Builds the sharded, jit-able step functions for every shape cell.

``build_cell`` returns (fn, example_specs, in_shardings, out_shardings)
for one (arch config, shape cell, mesh):

  train_*    -> train_step   : loss + grad + Adam update (donated state)
  prefill_*  -> prefill_step : full forward returning last logits + caches
  decode_*   -> serve_step   : one new token against a seq_len KV cache

Sharding policy (baseline — see EXPERIMENTS.md §Perf for iterations):
  batch          -> ("pod","data")          [dp]
  params/moments -> FSDP over "data", TP over "model"
  KV cache       -> batch over dp when divisible; sequence over "model"
                    (decode_32k) or all axes (long_500k, batch=1)
  SSM state      -> batch over dp, heads over "model"
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.synthetic import batch_specs, context_spec, token_spec
from repro.launch.mesh import axis_rules_for, dp_axes
from repro.models import model as model_lib
from repro.models.config import ModelConfig, ShapeCell
from repro.optim.adam import AdamState, adam_update, clip_by_global_norm

PyTree = Any


# ----------------------------------------------------------- small utils

def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _maybe(mesh, dim_size: int, axes):
    """Use axes only if dim divides evenly; else replicate that dim."""
    return axes if dim_size % _axis_size(mesh, axes) == 0 else None


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def _sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop axes from a PartitionSpec wherever the dim is not divisible by
    the axis-product (e.g. odd vocab/width on a 16-way axis)."""
    out = []
    for i, axes in enumerate(spec):
        if axes is None or i >= len(shape):
            out.append(None if i >= len(shape) else axes)
            continue
        if shape[i] % _axis_size(mesh, axes) == 0:
            out.append(axes)
        else:
            out.append(None)
    return P(*out)


def _sharding_tree_for(mesh, spec_tree, shape_tree):
    """NamedShardings with divisibility-sanitized specs."""
    flat_specs, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda s: isinstance(s, P))
    flat_shapes = treedef.flatten_up_to(shape_tree)
    out = [NamedSharding(mesh, _sanitize_spec(mesh, s, sh.shape))
           for s, sh in zip(flat_specs, flat_shapes)]
    return jax.tree.unflatten(treedef, out)


# -------------------------------------------------------- state skeleton

def model_state_specs(cfg: ModelConfig, mesh):
    """(shape_tree, pspec_tree) for {params, opt, step} without allocating."""
    rules = axis_rules_for(mesh)

    def init():
        params, _ = model_lib.init_model(jax.random.PRNGKey(0), cfg, rules)
        return params

    param_shapes = jax.eval_shape(init)
    param_specs = _param_specs(cfg, rules)

    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    opt_shapes = AdamState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt),
                        param_shapes),
        nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, mdt),
                        param_shapes),
    )
    opt_specs = AdamState(step=P(), mu=param_specs, nu=param_specs)
    shapes = {"params": param_shapes, "opt": opt_shapes}
    specs = {"params": param_specs, "opt": opt_specs}
    return shapes, specs


def _param_specs(cfg, rules):
    """Spec tree without allocating params: trace init abstractly and
    capture the (non-array) spec structure via closure."""
    box = {}

    def f(k):
        params, specs = model_lib.init_model(k, cfg, rules)
        box["specs"] = specs
        return jnp.zeros(())

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["specs"]


# ------------------------------------------------------------ train step

def make_train_step(cfg: ModelConfig, lr: float = 3e-4,
                    grad_clip: float = 1.0):
    def train_step(state, batch):
        def lf(p):
            return model_lib.loss_fn(p, cfg, batch)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state["params"])
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = adam_update(
            grads, state["opt"], state["params"], lr=lr, b1=0.9, b2=0.95)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def build_train(cfg: ModelConfig, cell: ShapeCell, mesh):
    rules = axis_rules_for(mesh)
    dp = dp_axes(mesh)
    state_shapes, state_specs = model_state_specs(cfg, mesh)
    bspecs = batch_specs(cfg, cell)
    bshard = {
        k: P(_maybe(mesh, v.shape[0], dp), *([None] * (len(v.shape) - 1)))
        for k, v in bspecs.items()
    }
    fn = make_train_step(cfg)
    state_sh = _sharding_tree_for(mesh, state_specs, state_shapes)
    in_shardings = (state_sh, _sharding_tree(mesh, bshard))
    out_shardings = (state_sh, None)
    args = (state_shapes, bspecs)
    return fn, args, in_shardings, out_shardings, (0,)   # donate state


# --------------------------------------------------------- serve: prefill

def _cache_pspecs(cfg: ModelConfig, cell: ShapeCell, mesh):
    """PartitionSpec tree matching make_caches structure."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMCache
    unit, _ = cfg.block_program()
    dp = dp_axes(mesh)
    b = cell.global_batch
    s = cell.seq_len
    b_ax = _maybe(mesh, b, dp)
    if b_ax is None and b == 1:
        seq_axes = ("data", "model") if "pod" not in mesh.axis_names \
            else ("pod", "data", "model")
    else:
        seq_axes = ("model",)
    s_ax = _maybe(mesh, s, seq_axes)

    specs = []
    for kind in unit:
        if kind.startswith("attn") or kind == "cross_attn":
            spec = KVCache(
                k=P(None, b_ax, s_ax, None, None),
                v=P(None, b_ax, s_ax, None, None))
        elif kind.startswith("mamba"):
            tp = "model"
            spec = SSMCache(
                conv_x=P(None, b_ax, None,
                         _maybe(mesh, cfg.ssm_d_inner, tp)),
                conv_b=P(None, b_ax, None,
                         _maybe(mesh, cfg.ssm_state, tp)),
                conv_c=P(None, b_ax, None,
                         _maybe(mesh, cfg.ssm_state, tp)),
                state=P(None, b_ax, _maybe(mesh, cfg.ssm_heads, tp),
                        None, None))
        else:
            spec = None
        specs.append(spec)
    return tuple(specs)


def _ctx_kv_pspecs(cfg: ModelConfig, cell: ShapeCell, mesh):
    from repro.models.attention import KVCache
    unit, _ = cfg.block_program()
    if not any(k == "cross_attn" for k in unit):
        return None
    dp = dp_axes(mesh)
    b_ax = _maybe(mesh, cell.global_batch, dp)
    specs = []
    for kind in unit:
        if kind == "cross_attn":
            specs.append(KVCache(k=P(None, b_ax, None, None, None),
                                 v=P(None, b_ax, None, None, None)))
        else:
            specs.append(None)
    return tuple(specs)


def build_prefill(cfg: ModelConfig, cell: ShapeCell, mesh):
    rules = axis_rules_for(mesh)
    dp = dp_axes(mesh)
    param_specs = _param_specs(cfg, rules)
    param_shapes = jax.eval_shape(
        lambda k: model_lib.init_model(k, cfg, rules)[0],
        jax.random.PRNGKey(0))

    b, s = cell.global_batch, cell.seq_len
    toks = token_spec(b, s)
    ctx = context_spec(cfg, b)
    b_ax = _maybe(mesh, b, dp)
    cache_specs = _cache_pspecs(cfg, cell, mesh)

    def prefill_step(params, tokens, context=None):
        return model_lib.prefill(params, cfg, tokens, context)

    args = [param_shapes, toks] + ([ctx] if ctx is not None else [])
    in_sh = [_sharding_tree_for(mesh, param_specs, param_shapes),
             NamedSharding(mesh, P(b_ax, None))]
    if ctx is not None:
        in_sh.append(NamedSharding(mesh, P(b_ax, None, None)))
    out_sh = (NamedSharding(mesh, P(b_ax, None, "model")),
              _sharding_tree(mesh, cache_specs))
    return prefill_step, tuple(args), tuple(in_sh), out_sh, ()


# ---------------------------------------------------------- serve: decode

def build_decode(cfg: ModelConfig, cell: ShapeCell, mesh):
    rules = axis_rules_for(mesh)
    dp = dp_axes(mesh)
    param_specs = _param_specs(cfg, rules)
    param_shapes = jax.eval_shape(
        lambda k: model_lib.init_model(k, cfg, rules)[0],
        jax.random.PRNGKey(0))

    b, s = cell.global_batch, cell.seq_len
    b_ax = _maybe(mesh, b, dp)
    tok = token_spec(b, 1)
    cache_shapes = jax.eval_shape(
        lambda: model_lib.make_caches(cfg, b, s, jnp.bfloat16))
    cache_specs = _cache_pspecs(cfg, cell, mesh)
    ctx = context_spec(cfg, b)
    ctx_kv_shapes = None
    if ctx is not None:
        ctx_kv_shapes = jax.eval_shape(
            lambda p, c: model_lib.precompute_ctx_kvs(p, cfg, c),
            param_shapes, ctx)
    ctx_kv_specs = _ctx_kv_pspecs(cfg, cell, mesh)

    def serve_step(params, token, caches, pos, ctx_kvs=None):
        logits, new_caches = model_lib.decode_step(
            params, cfg, token, caches, pos, context=None, ctx_kvs=ctx_kvs)
        return logits, new_caches

    args = [param_shapes, tok, cache_shapes,
            jax.ShapeDtypeStruct((), jnp.int32)]
    in_sh = [_sharding_tree_for(mesh, param_specs, param_shapes),
             NamedSharding(mesh, P(b_ax, None)),
             _sharding_tree(mesh, cache_specs),
             NamedSharding(mesh, P())]
    if ctx_kv_shapes is not None:
        args.append(ctx_kv_shapes)
        in_sh.append(_sharding_tree(mesh, ctx_kv_specs))
    out_sh = (NamedSharding(mesh, P(b_ax, None, "model")),
              _sharding_tree(mesh, cache_specs))
    donate = (2,)    # donate caches
    return serve_step, tuple(args), tuple(in_sh), out_sh, donate


# -------------------------------------------------------------- dispatch

def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh):
    if cell.kind == "train":
        return build_train(cfg, cell, mesh)
    if cell.kind == "prefill":
        return build_prefill(cfg, cell, mesh)
    if cell.kind == "decode":
        return build_decode(cfg, cell, mesh)
    raise ValueError(cell.kind)


def lower_cell(cfg: ModelConfig, cell: ShapeCell, mesh, opts: dict | None = None):
    """jit().lower() for one cell — the dry-run workhorse.

    ``opts`` carries §Perf hillclimb variants:
      moe_group_size: int   — dispatch group size override
      remat: bool           — activation checkpointing on/off
      moe_shard: bool       — constrain MoE dispatch intermediates (EP)
      decode_dshard: bool   — 2-D weight-stationary serving (activations
                              reshard over 'data' instead of FSDP weight
                              all-gathers)
    """
    import dataclasses
    from repro.models.layers import activation_sharding_ctx
    opts = opts or {}
    cfg_overrides = {k: v for k, v in opts.items()
                     if k in ("moe_group_size", "moe_impl", "remat", "param_dtype",
                              "embed_shard", "attn_seq_shard", "remat_policy",
                              "scan_unroll", "capacity_factor")}
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    fn, args, in_sh, out_sh, donate = build_cell(cfg, cell, mesh)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    sp = None
    if cell.kind in ("prefill",) and (
            cell.global_batch < _axis_size(mesh, dp_axes(mesh))
            or opts.get("force_sp")):
        sp = "model"    # batch too small to fill dp (or forced variant):
                        # seq-parallel prefill
    dshard = "data" if (opts.get("decode_dshard")
                        and cell.kind == "decode") else None
    with jax.set_mesh(mesh), activation_sharding_ctx(
            mesh, dp_axes(mesh), tp_axis="model", sp_axis=sp,
            dshard_axis=dshard, moe_shard=bool(opts.get("moe_shard"))):
        return jfn.lower(*args)
