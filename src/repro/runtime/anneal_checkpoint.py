"""Rung-boundary checkpointing for the annealing engines.

``AnnealCheckpointer`` is the thin persistence layer behind the
``checkpoint_dir=`` / ``resume=`` knobs on ``shuffle_soft_sort``,
``shuffle_soft_sort_batched``, and ``restart_tournament``: at each rung
boundary the engine hands it a flat ``{name: ndarray}`` snapshot of the
full per-instance carry (shuffle orders, chained PRNG keys, executed
loss traces, tournament alive sets, adaptive-controller state) plus a
small JSON ``meta`` record (engine kind, round/rung position, structural
fingerprint), and it writes them through ``CheckpointManager`` — so the
anneal inherits the same atomic tmp-then-rename publish, manifest,
keep-k GC, and resume-latest semantics the LM trainer already has.

Why a flat dict and not the engines' pytrees: the state a resumed run
needs is exactly what crosses the rung boundary, which the PR 6 segment
seam made small and explicit — N int32 orders and a (2,) uint32 key per
instance, NOT the inner-loop ``w``/Adam moments (the trainer
re-initializes ``w = arange(N)`` every round, so the carry between
rounds is only ``order``/``key``; snapshotting at rung boundaries
captures the complete state by construction).  Flat string keys also
survive the manifest round-trip without a treedef parser: ``restore``
rebuilds ``like`` from the manifest's recorded key list, and dict
flattening is key-sorted on both sides.

Structural fingerprint: ``meta`` fields listed in ``expect`` at restore
time must match exactly (engine kind, rounds, N, instance count,
schedule, grid) — resuming a checkpoint against a different problem is
a hard error, not silent corruption.  Deliberately NOT fingerprinted:
``compute_dtype`` / ``tau_end`` / ``band``, because the divergence
graceful-degradation ladder (``runtime.fault_tolerance
.DivergencePolicy``) resumes the same run under an adjusted config.
The full config repr is stored for audit.

Device-layout freedom: the snapshots are host numpy in LOGICAL
(instance-major) layout — no mesh shape, shard order, or device ids
anywhere in the carry.  That is what makes cross-mesh resume work (kill
on 8 devices, resume on 3 — proven in the chaos matrix), and it is the
same property the elastic re-shard path (``mesh_hook`` in
``core.shufflesoftsort``, EXPERIMENTS.md §Robustness "Elastic
capacity") exploits IN MEMORY: evicting a device at a rung boundary
just rebuilds the mesh and re-pads the very same layout-free carry,
no disk round-trip — an in-memory special case of the resume path this
module already guarantees.
"""
from __future__ import annotations

import json
from typing import Any, Optional

import numpy as np

from repro.runtime.checkpoint import CheckpointManager


def _jsonable(v: Any) -> Any:
    """Normalize a meta value to what a JSON round-trip returns, so
    fingerprint comparison is layout-stable (tuples become lists, numpy
    scalars become Python scalars)."""
    return json.loads(json.dumps(v, default=lambda o: (
        o.item() if isinstance(o, np.generic) else list(o))))


class AnnealCheckpointer:
    """Flat-dict checkpoint store for annealing engine state.

    Synchronous by default: the per-rung state is a few N-sized integer
    arrays, the write is microseconds next to a rung of device compute,
    and a synchronous publish means a crash at ANY point leaves either
    the previous rung's checkpoint or the new one — never a half-written
    latest.  (``CheckpointManager``'s async path remains available for
    callers that want it.)
    """

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.mgr = CheckpointManager(directory, keep=keep,
                                     async_save=async_save)

    # ------------------------------------------------------------- save

    def save(self, round_idx: int, state: dict[str, np.ndarray],
             meta: dict) -> None:
        """Publish ``state`` as the checkpoint for rung/round
        ``round_idx``.  ``state`` must be a flat ``{str: array-like}``
        dict; ``meta`` must be JSON-serializable."""
        assert all(isinstance(k, str) for k in state), state.keys()
        self.mgr.save(int(round_idx),
                      {k: np.asarray(v) for k, v in state.items()},
                      extra={"anneal": meta,
                             "state_keys": sorted(state)})

    def wait(self) -> None:
        self.mgr.wait()

    # ---------------------------------------------------------- restore

    def latest_round(self) -> Optional[int]:
        return self.mgr.latest_step()

    def restore_latest(self, expect: dict | None = None):
        """Load the newest checkpoint, or ``None`` if the directory has
        none (a fresh ``resume=True`` run starts from scratch — which is
        what lets a supervisor pass ``resume=True`` unconditionally).

        ``expect`` maps meta field -> required value; a mismatch on any
        listed field raises ``ValueError`` (wrong problem / engine for
        this checkpoint directory).

        Returns ``(state, round_idx, meta)``.
        """
        self.mgr.wait()
        step = self.mgr.latest_step()
        if step is None:
            return None
        man = self.mgr.manifest(step)
        meta = man["extra"]["anneal"]
        if expect:
            for k, v in expect.items():
                if meta.get(k) != _jsonable(v):
                    raise ValueError(
                        f"checkpoint at {self.mgr.directory} (round "
                        f"{step}) does not match this run: meta[{k!r}] "
                        f"= {meta.get(k)!r}, expected {_jsonable(v)!r}")
        keys = man["extra"]["state_keys"]
        # Plain-int like-leaves carry no dtype, so restore returns the
        # stored arrays uncast — dtypes round-trip exactly, which the
        # bit-identical-resume contract needs (a uint32 PRNG key cast
        # through float would be corruption, not restoration).
        like = {k: 0 for k in keys}
        state, _ = self.mgr.restore(like, step)
        return state, int(step), meta
