from repro.runtime.checkpoint import CheckpointManager  # noqa: F401
from repro.runtime.anneal_checkpoint import AnnealCheckpointer  # noqa: F401
from repro.runtime.fault_tolerance import (  # noqa: F401
    AnnealSupervisor,
    CorruptionSpec,
    DivergencePolicy,
    FaultInjector,
    RetryPolicy,
    TrainSupervisor,
    WorkerFailure,
)
from repro.runtime.guardrails import (  # noqa: F401
    GuardrailMonitor,
    GuardrailPolicy,
    IntegrityViolation,
)
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
from repro.runtime.compression import (  # noqa: F401
    CompressionState,
    compress_gradients,
    init_compression,
)
