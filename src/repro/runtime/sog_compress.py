"""Self-organizing checkpoint compression — the paper's SOG story applied
to LM checkpoints.

Each 2-D weight (D, F) is treated as F column vectors; ShuffleSoftSort
arranges them on a grid maximizing neighbour correlation (storing only
the F permutation indices — the paper's N-parameter claim), then the
permuted tensor is int8-quantized, delta-encoded along the sorted order
and deflated.  Correlated columns (the common case in trained nets:
duplicated/co-adapted features) compress measurably better after
sorting; the permutation costs 4F bytes.

This is an opt-in codec for CheckpointManager-style storage; round-trip
is exact at the int8 quantization level.
"""
from __future__ import annotations

import zlib
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.shufflesoftsort import ShuffleSoftSortConfig, shuffle_soft_sort


def _grid_hw(n: int) -> tuple[int, int]:
    """Near-square sorting grid for n columns; h * w >= n.

    Prefers an exact factorization when a near-square one exists
    (aspect ratio <= 2, no padding).  Otherwise — prime n, or n whose
    largest divisor <= sqrt(n) is tiny — walking h down degenerates
    toward a 1 x n grid whose "neighborhood" is a line, which defeats
    the 2-D neighbor loss entirely.  For those n we return a padded
    ceil(sqrt) x ceil grid instead (h * w - n < h extra cells); callers
    pad the feature rows and drop pad indices from the returned
    permutation (see ``sog_compress_tensor``).
    """
    h = int(np.sqrt(n))
    while n % h:
        h -= 1
    if n // h <= 2 * h:
        return h, n // h
    h = int(np.ceil(np.sqrt(n)))
    return h, int(np.ceil(n / h))


def _quantize(w: np.ndarray) -> tuple[np.ndarray, float]:
    scale = float(np.max(np.abs(w))) / 127.0 + 1e-12
    return np.clip(np.round(w / scale), -127, 127).astype(np.int8), scale


def _encode(q: np.ndarray) -> bytes:
    # delta along the sorted (column) axis (first row kept verbatim via a
    # zero prepend), wrapped mod 256 — lossless for int8 payloads — then
    # deflate.
    delta = np.diff(q.astype(np.int16), axis=0,
                    prepend=np.zeros((1, q.shape[1]), np.int16))
    return zlib.compress(delta.astype(np.int8).tobytes(), level=6)


def sog_compress_tensor(
    w,
    *,
    sort_rounds: int = 120,
    feature_rows: int = 32,
    key=None,
) -> dict:
    """Compress one 2-D tensor (D, F) -> blob dict.  Returns the payload
    plus baseline (unsorted) size so callers can report the SOG gain."""
    w = np.asarray(jax.device_get(w), np.float32)
    assert w.ndim == 2, w.shape
    d, f = w.shape
    key = key if key is not None else jax.random.PRNGKey(0)

    # features for sorting: subsample rows (cheap proxy for the column)
    rows = np.linspace(0, d - 1, min(feature_rows, d)).astype(int)
    feats = w[rows].T                                    # (F, <=32)

    hw = _grid_hw(f)
    m = hw[0] * hw[1]
    if m > f:
        # Padded grid (f prime or near-prime): replicate trailing columns
        # as pad features — maximally correlated with real columns, so
        # they cluster beside their twins without distorting the layout —
        # then drop the pad indices, leaving a permutation of the real f
        # columns in grid-scan order.
        feats = np.concatenate([feats, feats[f - (m - f):]], axis=0)
    # chunk must divide the (possibly padded) grid size; largest such
    # divisor <= 256 keeps the streamed apply's O(chunk * m) footprint.
    chunk = m if m <= 256 else max(c for c in range(1, 257) if m % c == 0)
    cfg = ShuffleSoftSortConfig(rounds=sort_rounds, inner_steps=4,
                                chunk=chunk)
    order, _, _ = shuffle_soft_sort(jnp.asarray(feats), hw, cfg, key=key)
    if m > f:
        order = order[order < f]

    q_sorted, scale = _quantize(w.T[order])              # (F, D) sorted
    q_plain, _ = _quantize(w.T)
    payload = _encode(q_sorted)
    baseline = _encode(q_plain)

    return {
        "payload": payload,
        "perm": order.astype(np.int32),
        "scale": scale,
        "shape": (d, f),
        "bytes": len(payload) + 4 * f,                  # + stored permutation
        "baseline_bytes": len(baseline),
        "raw_bytes": w.nbytes,
    }


def sog_decompress_tensor(blob: dict) -> np.ndarray:
    d, f = blob["shape"]
    raw = zlib.decompress(blob["payload"])
    delta = np.frombuffer(raw, np.int8).reshape(f, d).astype(np.int32)
    q = np.cumsum(delta, axis=0).astype(np.int8)   # mod-256 wrap == exact
    wt = q.astype(np.float32) * blob["scale"]            # (F, D) sorted
    out = np.empty_like(wt)
    out[blob["perm"]] = wt                               # invert permutation
    return out.T                                         # (D, F)


def compress_checkpoint(params: Any, *, min_cols: int = 64,
                        sort_rounds: int = 80) -> dict:
    """Compress every >=2-D weight in a param pytree; returns stats and
    the blobs.  Tensors are flattened to 2-D (leading dims merged)."""
    flat, treedef = jax.tree.flatten(params)
    blobs, stats = [], {"sog_bytes": 0, "baseline_bytes": 0, "raw_bytes": 0}
    key = jax.random.PRNGKey(7)
    for i, leaf in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf), np.float32)
        if arr.ndim >= 2 and arr.shape[-1] >= min_cols:
            arr2 = arr.reshape(-1, arr.shape[-1])
            key, sub = jax.random.split(key)
            blob = sog_compress_tensor(arr2, sort_rounds=sort_rounds,
                                       key=sub)
            blobs.append(blob)
            stats["sog_bytes"] += blob["bytes"]
            stats["baseline_bytes"] += blob["baseline_bytes"]
            stats["raw_bytes"] += blob["raw_bytes"]
        else:
            blobs.append(None)
    stats["gain_vs_baseline"] = (
        stats["baseline_bytes"] / max(stats["sog_bytes"], 1))
    stats["ratio_vs_raw"] = stats["raw_bytes"] / max(stats["sog_bytes"], 1)
    return {"blobs": blobs, "treedef": treedef, "stats": stats}
