"""Gradient compression for data-parallel sync: int8 quantization with
error feedback (EF-SGD / 1-bit-Adam style residual correction).

The quantize -> (all-reduce) -> dequantize pipeline reduces DP gradient
traffic 4x (f32) / 2x (bf16).  The residual (quantization error) is kept
per leaf and added back before the next quantization, which restores
convergence to the uncompressed trajectory asymptotically — the property
``test_runtime.py::test_compressed_training_converges`` asserts.

Inside a pjit'd train step the dequantized gradient is what the
all-reduce consumes; XLA moves int8 over the wire when the reduce is
expressed over the quantized values (wire format exercised in the
hillclimb, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    residual: PyTree           # error-feedback residuals, same shapes as grads


def init_compression(params: PyTree) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quant_leaf(g, r):
    """int8 symmetric quantization with error feedback residual."""
    gf = g.astype(jnp.float32) + r
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_r = gf - deq
    return deq.astype(g.dtype), new_r, q, scale


def compress_gradients(grads: PyTree, state: CompressionState
                       ) -> tuple[PyTree, CompressionState, dict]:
    """Returns (dequantized grads, new state, stats).  The dequantized
    grads replace the raw ones in the optimizer step; stats report the
    achieved compression ratio and quantization SNR."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    deqs, news, errs, raws = [], [], [], []
    for g, r in zip(flat_g, flat_r):
        deq, new_r, q, scale = _quant_leaf(g, r)
        deqs.append(deq)
        news.append(new_r)
        errs.append(jnp.sum(jnp.square(new_r)))
        raws.append(jnp.sum(jnp.square(g.astype(jnp.float32))))
    stats = {
        "quant_mse": sum(errs) / max(len(errs), 1),
        "grad_sq": sum(raws),
        "wire_bytes_ratio": 0.25,     # int8 vs f32
    }
    return (jax.tree.unflatten(treedef, deqs),
            CompressionState(residual=jax.tree.unflatten(treedef, news)),
            stats)
