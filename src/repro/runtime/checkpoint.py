"""Fault-tolerant checkpointing.

Design (scaled-down faithfully from the multi-host version):

  * **Atomic**: write to ``<dir>/tmp-<step>``, fsync, then rename to
    ``<dir>/step-<step>`` — a crash mid-save never corrupts the latest
    checkpoint.
  * **Manifest**: ``manifest.json`` records step, mesh shape, axis names
    and logical (unsharded) shapes, so a restart on a *different* mesh
    (elastic scaling) resharding is a pure load-time concern: arrays are
    stored in logical layout and re-device_put with the new mesh's
    NamedShardings.
  * **Keep-k GC** + resume-latest.
  * **Async save**: a background thread serializes a host snapshot so the
    step loop is not blocked (the snapshot is taken synchronously —
    correct w.r.t. donation — but serialization/IO overlaps compute).

On a real pod each host writes only its addressable shards (the manifest
carries the global shape + spec); this process-local implementation
writes full arrays, which is the correct degenerate case for 1 host.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np

import jax

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree.flatten(tree)
    paths = [f"leaf_{i:05d}" for i in range(len(flat))]
    return flat, paths, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._save_thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        # A crash mid-save strands its tmp-<step> staging dir (the
        # atomic publish is the rename; anything still named tmp- never
        # published).  Sweep them at open so a resumed run doesn't
        # accumulate garbage or trip over a half-written staging dir of
        # its own step number.  One manager owns a directory at a time.
        for name in os.listdir(directory):
            if name.startswith("tmp-"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # ----------------------------------------------------------- saving

    def save(self, step: int, state: PyTree, *, mesh=None,
             extra: dict | None = None, block: bool = False):
        """Snapshot now; serialize (a)synchronously."""
        self.wait()                                # one in-flight save max
        host_state = jax.tree.map(np.asarray, state)   # sync snapshot
        meta = {
            "step": int(step),
            "time": time.time(),
            "mesh_shape": list(dict(mesh.shape).values()) if mesh is not None
                          else None,
            "mesh_axes": list(mesh.axis_names) if mesh is not None else None,
            "extra": extra or {},
        }

        def _write():
            tmp = os.path.join(self.directory, f"tmp-{step}")
            final = os.path.join(self.directory, f"step-{step:09d}")
            os.makedirs(tmp, exist_ok=True)
            flat, paths, treedef = _flatten_with_paths(host_state)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{p: a for p, a in zip(paths, flat)})
            meta["treedef"] = str(treedef)
            meta["num_leaves"] = len(flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)                 # atomic publish
            self._gc()

        if self.async_save and not block:
            self._save_thread = threading.Thread(target=_write, daemon=True)
            self._save_thread.start()
        else:
            _write()

    def wait(self):
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step-{s:09d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- loading

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: PyTree, step: int | None = None, *,
                shardings: PyTree = None) -> tuple[PyTree, int]:
        """Load into the structure of ``like``.  ``shardings`` (optional
        pytree of NamedSharding) triggers elastic resharding: arrays are
        device_put with the *new* mesh layout regardless of the mesh they
        were saved under."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        final = os.path.join(self.directory, f"step-{step:09d}")
        data = np.load(os.path.join(final, "arrays.npz"))
        flat_like, treedef = jax.tree.flatten(like)
        saved = self.manifest(step).get("num_leaves")
        if saved is not None and saved != len(flat_like):
            raise ValueError(
                f"checkpoint step {step} in {self.directory} holds "
                f"{saved} leaves but `like` has {len(flat_like)} — the "
                f"state layout changed since this checkpoint was "
                f"written; restore with the layout it was saved under")
        flat = [data[f"leaf_{i:05d}"] for i in range(len(flat_like))]
        flat = [np.asarray(a, dtype=l.dtype) if hasattr(l, "dtype") else a
                for a, l in zip(flat, flat_like)]
        state = jax.tree.unflatten(treedef, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, sh: jax.device_put(a, sh), state, shardings)
        return state, step

    def manifest(self, step: int | None = None) -> dict:
        step = step if step is not None else self.latest_step()
        final = os.path.join(self.directory, f"step-{step:09d}")
        with open(os.path.join(final, "manifest.json")) as f:
            return json.load(f)
