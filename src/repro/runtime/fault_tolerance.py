"""Fault tolerance: checkpoint/restart supervision, retry policies, and
deterministic chaos injection.

On a real fleet the failure signal is a missing heartbeat or an XLA
collective timeout; here failures surface as exceptions raised by a
step/dispatch function (tests inject them deterministically via
``FaultInjector``).  Two consumers share this module:

* ``TrainSupervisor`` — the LM trainer's driver: catches step failures
  and restores from the newest checkpoint.  The restore path accepts a
  different mesh than the one the checkpoint was written under —
  `CheckpointManager.restore` re-device_puts logical arrays with the
  new shardings, which is the whole elastic-scaling story at this layer.
* The sort-serving scheduler (``repro.launch.serve.SortServer``) — a
  failed segment dispatch re-queues its requests from their last
  committed round boundary (the request state IS the checkpoint) under
  a ``RetryPolicy`` budget with exponential backoff, instead of failing
  every coalesced future (EXPERIMENTS.md §Serving).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

import jax

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.runtime")


class WorkerFailure(RuntimeError):
    """Simulated node failure (tests / chaos injection)."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry budget + exponential backoff for failed dispatches.

    ``max_retries`` is per unit of work (a training run's restarts, a
    sort request's re-queues), not per process; exhausting it converts
    the transient-failure path into a typed terminal error at the
    caller.  ``backoff(attempt)`` is the delay before re-queueing after
    the ``attempt``-th consecutive failure (1-based): base * mult^(a-1),
    capped — the standard exponential schedule, deterministic so tests
    can assert exact eligibility times.
    """
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_mult: float = 2.0
    backoff_max_s: float = 2.0

    def backoff(self, attempt: int) -> float:
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        return min(self.backoff_base_s * self.backoff_mult ** (attempt - 1),
                   self.backoff_max_s)


class FaultInjector:
    """Deterministic chaos harness around a dispatch callable.

    Wraps ``engine_fn``; the i-th call (0-based) first sleeps
    ``delay_calls[i]`` seconds if present (straggler injection), then
    raises ``exc_type`` if ``i`` is in ``fail_calls`` (worker-failure
    injection), else forwards to the engine.  Everything is counted
    (``calls`` / ``faults`` / ``delays``) so tests and the serving
    benchmark can assert exactly which dispatches were perturbed — the
    sort-path analogue of the flaky step functions
    ``tests/test_runtime.py`` feeds the TrainSupervisor.
    """

    def __init__(self, engine_fn: Callable, fail_calls=(),
                 delay_calls: Optional[dict[int, float]] = None,
                 exc_type: type = WorkerFailure,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.engine_fn = engine_fn
        self.fail_calls = set(fail_calls)
        self.delay_calls = dict(delay_calls or {})
        self.exc_type = exc_type
        self.sleep_fn = sleep_fn
        self.calls = 0
        self.faults = 0
        self.delays = 0
        # SortServer dispatches from worker threads; unguarded += on the
        # counters races (two dispatches can draw the same index and the
        # chaos schedule double-fires or skips).  The lock covers only
        # index assignment + counting — the injected sleep and the
        # wrapped engine run outside it, so injection never serializes
        # the dispatches it is perturbing.
        self._lock = threading.Lock()

    def __call__(self, *args, **kwargs):
        with self._lock:
            i = self.calls
            self.calls += 1
            delay = self.delay_calls.get(i)
            fail = i in self.fail_calls
            if delay is not None:
                self.delays += 1
            if fail:
                self.faults += 1
        if delay is not None:
            self.sleep_fn(delay)
        if fail:
            raise self.exc_type(f"injected fault at dispatch {i}")
        return self.engine_fn(*args, **kwargs)


class TrainSupervisor:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        data_fn: Callable[[int], Any],
        ckpt: CheckpointManager,
        *,
        checkpoint_every: int = 50,
        max_restarts: int = 10,
        mesh=None,
        shardings=None,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.mesh = mesh
        self.shardings = shardings
        self.straggler = straggler or StragglerMonitor()
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, state, start_step: int, num_steps: int):
        """Run to ``start_step + num_steps``, surviving step failures."""
        step = start_step
        target = start_step + num_steps
        # Host snapshot of the initial state: a failure BEFORE the first
        # checkpoint restarts from here.  Without it the retry loop kept
        # the partially-advanced state while resetting only the step
        # counter — a silent divergence from a clean run.
        init_state = jax.tree.map(np.asarray, state)
        # resume from a newer checkpoint if one exists
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state, step = self.ckpt.restore(state, shardings=self.shardings)
            log.info("resumed at step %d", step)

        while step < target:
            try:
                t0 = time.perf_counter()
                batch = self.data_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                flagged = self.straggler.record(step, dt)
                if flagged:
                    self.history.append({"step": step,
                                         "event": "straggler",
                                         "dt": dt})
                step += 1
                self.history.append({"step": step, "metrics": {
                    k: float(v) for k, v in metrics.items()}})
                if step % self.checkpoint_every == 0 or step == target:
                    self.ckpt.save(step, state, mesh=self.mesh)
            except WorkerFailure as e:
                self.restarts += 1
                self.history.append({"step": step, "event": "failure",
                                     "error": str(e)})
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                latest = self.ckpt.latest_step()
                if latest is None:
                    log.warning("failure before first checkpoint; "
                                "restarting from initial state")
                    state = jax.tree.map(np.array, init_state)
                    step = start_step
                    continue
                self.ckpt.wait()
                state, step = self.ckpt.restore(state,
                                                shardings=self.shardings)
                log.info("restored step %d after failure (%d restarts)",
                         step, self.restarts)
        self.ckpt.wait()
        return state, step


@dataclasses.dataclass(frozen=True)
class DivergencePolicy:
    """Graceful-degradation ladder for ``NumericalDivergence`` failures.

    Each divergence event consumes ONE rung of the ladder, in order:

      1. ``promote_f32`` — if the run was computing in bfloat16, retry
         the remaining rounds in float32 (the usual cure: bf16's 8-bit
         mantissa under-resolves small loss deltas at cold tau).
      2. ``tau_floor`` — clamp ``tau_end`` up to the floor; an
         over-aggressive anneal drives the softmax logits ``w / tau``
         to overflow before the permutation has locked in.
      3. ``widen_band`` — double an explicit band half-width (or drop
         an ``"auto"`` band back to dense): a too-narrow band can strand
         mass outside the window and zero out rows.

    ``apply`` returns the degraded config plus a human-readable
    description, or ``None`` when no rung is applicable — the caller
    (``AnnealSupervisor``) re-raises the original divergence then.
    Retries restart from the last committed rung checkpoint, so the
    ladder never repeats completed work (EXPERIMENTS.md §Robustness).
    """
    promote_f32: bool = True
    tau_floor: float = 0.05
    widen_band: bool = True
    max_fallbacks: int = 3

    def apply(self, cfg, failure) -> Optional[tuple[Any, str]]:
        if self.promote_f32 and cfg.compute_dtype == "bfloat16":
            return (dataclasses.replace(cfg, compute_dtype="float32"),
                    "promoted compute_dtype bfloat16 -> float32")
        if self.tau_floor and cfg.tau_end < self.tau_floor:
            return (dataclasses.replace(cfg, tau_end=float(self.tau_floor)),
                    f"clamped tau_end {cfg.tau_end:g} -> {self.tau_floor:g}")
        if self.widen_band and cfg.band is not None:
            if cfg.band == "auto":
                return (dataclasses.replace(cfg, band=None),
                        "dropped band 'auto' -> dense")
            return (dataclasses.replace(cfg, band=int(cfg.band) * 2),
                    f"widened band {cfg.band} -> {int(cfg.band) * 2}")
        return None


class AnnealSupervisor:
    """Checkpoint/restart driver for the annealing engines — the sort
    path's sibling of ``TrainSupervisor``.

    Wraps one of the resumable entry points
    (``shuffle_soft_sort_batched`` by default; ``restart_tournament``
    and ``shuffle_soft_sort`` share the knob contract) and supervises a
    run to completion:

    * **Worker failures** (``failure_types``) restart the engine with
      ``resume=True`` under a ``RetryPolicy`` budget — the engine
      replays from its last committed rung-boundary checkpoint, and
      because rung carries are complete (orders + PRNG keys + losses +
      controller state), the finished run is bit-identical per seed to
      an uninterrupted one (tests/test_checkpointing.py kill-at-any-rung
      sweep).
    * **Numerical divergences** consume rungs of an optional
      ``DivergencePolicy`` ladder instead of the retry budget; each
      fallback re-runs only the rounds after the last finite rung,
      with the degraded config recorded in ``stats["fallbacks"]``.

    The supervisor owns no engine state — the checkpoint directory IS
    the state, which is what makes the restart path preemption-safe:
    kill the process anywhere and a new supervisor over the same
    directory continues the run.
    """

    def __init__(self, run_fn: Optional[Callable] = None, *,
                 checkpoint_dir: str,
                 retry: Optional[RetryPolicy] = None,
                 degrade: Optional[DivergencePolicy] = None,
                 failure_types: tuple = (WorkerFailure,),
                 sleep_fn: Callable[[float], None] = time.sleep):
        if run_fn is None:
            from repro.core.shufflesoftsort import shuffle_soft_sort_batched
            run_fn = shuffle_soft_sort_batched
        self.run_fn = run_fn
        self.checkpoint_dir = checkpoint_dir
        self.retry = retry or RetryPolicy()
        self.degrade = degrade
        self.failure_types = tuple(failure_types)
        self.sleep_fn = sleep_fn
        self.stats: dict[str, Any] = {
            "attempts": 0, "restarts": 0, "fallbacks": []}
        self.history: list[dict] = []

    def run(self, xs, hw, cfg, **kwargs):
        """Run ``run_fn(xs, hw, cfg, ...)`` to completion, restarting
        from the latest rung checkpoint after each supervised failure.
        Extra ``kwargs`` are forwarded verbatim (engine selection knobs,
        ``rung_hook`` for chaos tests, ...)."""
        from repro.core.shufflesoftsort import NumericalDivergence
        cfg_cur = cfg
        restarts = 0
        while True:
            self.stats["attempts"] += 1
            try:
                return self.run_fn(xs, hw, cfg_cur,
                                   checkpoint_dir=self.checkpoint_dir,
                                   resume=True, **kwargs)
            except NumericalDivergence as e:
                n_fb = len(self.stats["fallbacks"])
                fallback = None
                if (self.degrade is not None
                        and n_fb < self.degrade.max_fallbacks):
                    fallback = self.degrade.apply(cfg_cur, e)
                if fallback is None:
                    raise
                cfg_cur, desc = fallback
                self.stats["fallbacks"].append(desc)
                self.history.append({
                    "event": "divergence", "round": e.round, "tau": e.tau,
                    "dtype": e.dtype, "fallback": desc})
                log.warning("divergence at round %s (tau=%s, %s): %s",
                            e.round, e.tau, e.dtype, desc)
            except self.failure_types as e:
                restarts += 1
                self.stats["restarts"] = restarts
                self.history.append({"event": "failure", "error": str(e)})
                if restarts > self.retry.max_retries:
                    raise RuntimeError(
                        f"exceeded {self.retry.max_retries} restarts"
                    ) from e
                delay = self.retry.backoff(restarts)
                if delay:
                    self.sleep_fn(delay)
                log.info("restarting after failure (%d restarts): %s",
                         restarts, e)
