"""Fault tolerance: checkpoint/restart supervision, retry policies, and
deterministic chaos injection.

On a real fleet the failure signal is a missing heartbeat or an XLA
collective timeout; here failures surface as exceptions raised by a
step/dispatch function (tests inject them deterministically via
``FaultInjector``).  Two consumers share this module:

* ``TrainSupervisor`` — the LM trainer's driver: catches step failures
  and restores from the newest checkpoint.  The restore path accepts a
  different mesh than the one the checkpoint was written under —
  `CheckpointManager.restore` re-device_puts logical arrays with the
  new shardings, which is the whole elastic-scaling story at this layer.
* The sort-serving scheduler (``repro.launch.serve.SortServer``) — a
  failed segment dispatch re-queues its requests from their last
  committed round boundary (the request state IS the checkpoint) under
  a ``RetryPolicy`` budget with exponential backoff, instead of failing
  every coalesced future (EXPERIMENTS.md §Serving).
"""
from __future__ import annotations

import dataclasses
import logging
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

import jax

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.runtime")


class WorkerFailure(RuntimeError):
    """Simulated node failure (tests / chaos injection)."""


class DeviceLost(WorkerFailure):
    """A mesh device stopped serving mid-dispatch.

    Unlike a plain ``WorkerFailure`` (anonymous, transient — retry the
    dispatch as-is), a ``DeviceLost`` names the device that died via
    ``device_id``, so a device-health layer
    (``runtime.straggler.DeviceHealthMonitor``) can EVICT it: gather
    the layout-free rung carry, rebuild the mesh over the survivors,
    and replay the failed rung there — the elastic-capacity path
    (EXPERIMENTS.md §Robustness, "Elastic capacity")."""

    def __init__(self, message: str, *, device_id: int | None = None):
        super().__init__(message)
        self.device_id = device_id


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry budget + exponential backoff for failed dispatches.

    ``max_retries`` is per unit of work (a training run's restarts, a
    sort request's re-queues), not per process; exhausting it converts
    the transient-failure path into a typed terminal error at the
    caller.  ``backoff(attempt)`` is the delay before re-queueing after
    the ``attempt``-th consecutive failure (1-based): base * mult^(a-1),
    capped — the standard exponential schedule, deterministic so tests
    can assert exact eligibility times.
    """
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_mult: float = 2.0
    backoff_max_s: float = 2.0

    def backoff(self, attempt: int) -> float:
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        return min(self.backoff_base_s * self.backoff_mult ** (attempt - 1),
                   self.backoff_max_s)


@dataclasses.dataclass(frozen=True)
class CorruptionSpec:
    """One injected value corruption at an exact dispatch index.

    ``target`` names the element of the engine's result tuple to
    corrupt (``run_round_segment`` layout: ``orders`` / ``keys`` /
    ``losses`` / ``ws``); ``index`` is the flat element index within
    that array.  Modes model the classic silent-data-corruption
    taxonomy:

    * ``"bitflip"`` — XOR one bit at the element: the high exponent
      bit for floats (a value orders of magnitude off), the low bit
      for int32 orders (a duplicate entry — bijectivity breaks), bit 7
      for uint32 PRNG keys (the key chain breaks).
    * ``"signflip"`` — negate the element (floats / int32); flip the
      top bit for uint32.
    * ``"stale"`` — replace the WHOLE target array with the previous
      call's value for that target (a repeated DMA buffer); zeros when
      there is no previous call.
    * ``"nan"`` — splat NaN at the element (float targets only).
    """
    mode: str
    target: str = "losses"
    index: int = 0

    _MODES = ("bitflip", "signflip", "stale", "nan")
    _TARGETS = {"orders": 0, "keys": 1, "losses": 2, "ws": 3}

    def __post_init__(self):
        if self.mode not in self._MODES:
            raise ValueError(f"mode must be one of {self._MODES}, "
                             f"got {self.mode!r}")
        if self.target not in self._TARGETS:
            raise ValueError(
                f"target must be one of {sorted(self._TARGETS)}, "
                f"got {self.target!r}")
        if not isinstance(self.index, int):
            # A None/str index would raise mid-dispatch instead, where
            # the retry path swallows it and the corruption silently
            # never fires — fail at construction.
            raise ValueError(f"index must be an int, got {self.index!r}")

    def apply(self, arr: np.ndarray,
              prev: Optional[np.ndarray]) -> np.ndarray:
        out = np.array(arr)  # host copy — never mutate engine buffers
        flat = out.reshape(-1)
        idx = int(self.index) % flat.size
        if self.mode == "stale":
            if prev is not None and prev.shape == out.shape:
                return np.array(prev)
            return np.zeros_like(out)
        if self.mode == "nan":
            if not np.issubdtype(out.dtype, np.floating):
                raise ValueError(
                    f"nan corruption needs a float target, "
                    f"{self.target} is {out.dtype}")
            flat[idx] = np.nan
        elif self.mode == "bitflip":
            if np.issubdtype(out.dtype, np.floating):
                bits = flat.view(np.uint32) if out.dtype == np.float32 \
                    else flat.view(np.uint16)
                bits[idx] ^= np.array(
                    1 << (30 if out.dtype == np.float32 else 14),
                    bits.dtype)
            elif out.dtype == np.uint32:
                flat[idx] ^= np.uint32(1 << 7)
            else:
                flat[idx] ^= np.array(1, out.dtype)
        elif self.mode == "signflip":
            if out.dtype == np.uint32:
                flat[idx] ^= np.uint32(1 << 31)
            else:
                flat[idx] = -flat[idx]
        return out


class FaultInjector:
    """Deterministic chaos harness around a dispatch callable.

    Wraps ``engine_fn``; the i-th call (0-based) first sleeps
    ``delay_calls[i]`` seconds if present (straggler injection), then
    raises ``exc_type`` if ``i`` is in ``fail_calls`` (worker-failure
    injection), else forwards to the engine — and, when ``i`` is in
    ``corrupt_calls``, silently corrupts the engine's RESULT per the
    ``CorruptionSpec`` (value-corruption injection: the SDC the
    guardrail probes must catch).  Everything is counted (``calls`` /
    ``faults`` / ``delays`` / ``corruptions``) so tests and the serving
    benchmark can assert exactly which dispatches were perturbed — the
    sort-path analogue of the flaky step functions
    ``tests/test_runtime.py`` feeds the TrainSupervisor.

    **Device-loss / device-return injection** (the elastic-capacity
    chaos mode): ``device_loss`` maps a dispatch index to a device id
    taken DOWN from that index on; ``device_return`` maps a dispatch
    index to a device id brought BACK.  The down-set is persistent
    state, not a one-shot schedule — every dispatch whose ``mesh=``
    kwarg contains a downed device raises ``DeviceLost`` (naming the
    device), exactly what a real fleet looks like between the failure
    and the re-shard: the run keeps crashing on the dead slot until
    the scheduler rebuilds the mesh without it.  Dispatches with
    ``mesh=None`` (the vmap engine) have no device slots and are never
    affected.  ``healthy(device_id)`` is the probe a
    ``DeviceHealthMonitor`` polls to detect returns.

    The injection cursor and schedules are serializable
    (``state_dict`` / ``load_state_dict``) so a chaos scenario can
    round-trip through a ``WarmHandoff`` — a preempted injected run
    resumes with its cursor intact and the accounting stays exact.
    """

    def __init__(self, engine_fn: Callable, fail_calls=(),
                 delay_calls: Optional[dict[int, float]] = None,
                 exc_type: type = WorkerFailure,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 corrupt_calls: Optional[dict] = None,
                 device_loss: Optional[dict[int, int]] = None,
                 device_return: Optional[dict[int, int]] = None):
        self.engine_fn = engine_fn
        self.fail_calls = set(fail_calls)
        self.delay_calls = dict(delay_calls or {})
        self.corrupt_calls = {
            int(k): (v if isinstance(v, CorruptionSpec)
                     else CorruptionSpec(**v))
            for k, v in (corrupt_calls or {}).items()}
        self.device_loss = {int(k): int(v)
                            for k, v in (device_loss or {}).items()}
        self.device_return = {int(k): int(v)
                              for k, v in (device_return or {}).items()}
        self.down: set[int] = set()
        self.exc_type = exc_type
        self.sleep_fn = sleep_fn
        self.calls = 0
        self.faults = 0
        self.delays = 0
        self.corruptions = 0
        self.device_faults = 0
        # Previous call's result per target name — the stale-buffer
        # corruption source (host np copies, chaos-scale arrays only).
        self._prev: dict[str, np.ndarray] = {}
        # SortServer dispatches from worker threads; unguarded += on the
        # counters races (two dispatches can draw the same index and the
        # chaos schedule double-fires or skips).  The lock covers only
        # index assignment + counting — the injected sleep and the
        # wrapped engine run outside it, so injection never serializes
        # the dispatches it is perturbing.
        self._lock = threading.Lock()

    def healthy(self, device_id: int) -> bool:
        """Health probe for a device id — ``DeviceHealthMonitor``'s
        ``poll_returns`` asks this to detect grown-back devices."""
        with self._lock:
            return int(device_id) not in self.down

    def __call__(self, *args, **kwargs):
        with self._lock:
            i = self.calls
            self.calls += 1
            delay = self.delay_calls.get(i)
            fail = i in self.fail_calls
            spec = self.corrupt_calls.get(i)
            # Device transitions fire at exact dispatch indices, then
            # persist: the down-set outlives the index that set it.
            if i in self.device_loss:
                self.down.add(self.device_loss[i])
            if i in self.device_return:
                self.down.discard(self.device_return[i])
            lost = None
            mesh = kwargs.get("mesh")
            if mesh is not None and self.down:
                hit = [d.id for d in mesh.devices.flat
                       if d.id in self.down]
                if hit:
                    lost = hit[0]
                    self.device_faults += 1
            if delay is not None:
                self.delays += 1
            if fail:
                self.faults += 1
        if delay is not None:
            self.sleep_fn(delay)
        if fail:
            raise self.exc_type(f"injected fault at dispatch {i}")
        if lost is not None:
            raise DeviceLost(
                f"device {lost} lost at dispatch {i} (down set "
                f"{sorted(self.down)})", device_id=lost)
        result = self.engine_fn(*args, **kwargs)
        if spec is None and not self.corrupt_calls:
            return result
        out = list(result) if isinstance(result, tuple) else [result]
        if spec is not None:
            pos = CorruptionSpec._TARGETS[spec.target]
            if pos >= len(out):
                raise ValueError(
                    f"corruption target {spec.target!r} needs a "
                    f"{pos + 1}-tuple result, engine returned "
                    f"{len(out)} elements")
            with self._lock:
                prev = self._prev.get(spec.target)
            out[pos] = spec.apply(np.asarray(out[pos]), prev)
            with self._lock:
                self.corruptions += 1
        # Record this call's CLEAN targets as the next stale source
        # (post-corruption values for the corrupted target would make
        # consecutive stale injections self-consistent — record what
        # the engine actually produced).
        with self._lock:
            for name, pos in CorruptionSpec._TARGETS.items():
                if pos < len(out):
                    src = result[pos] if isinstance(result, tuple) \
                        else result
                    self._prev[name] = np.asarray(src)
        return tuple(out) if isinstance(result, tuple) else out[0]

    def state_dict(self) -> dict:
        """JSON-able injection cursor + schedules (not the stale-source
        arrays — a resumed injector re-primes them on its next call)."""
        with self._lock:
            return {
                "calls": self.calls, "faults": self.faults,
                "delays": self.delays, "corruptions": self.corruptions,
                "fail_calls": sorted(int(i) for i in self.fail_calls),
                "delay_calls": {str(k): float(v)
                                for k, v in self.delay_calls.items()},
                "corrupt_calls": {
                    str(k): dataclasses.asdict(v)
                    for k, v in self.corrupt_calls.items()},
                "device_loss": {str(k): int(v)
                                for k, v in self.device_loss.items()},
                "device_return": {str(k): int(v)
                                  for k, v in self.device_return.items()},
                "down": sorted(int(d) for d in self.down),
                "device_faults": self.device_faults,
                "exc_type": self.exc_type.__name__,
            }

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            self.calls = int(state["calls"])
            self.faults = int(state["faults"])
            self.delays = int(state["delays"])
            self.corruptions = int(state.get("corruptions", 0))
            self.fail_calls = set(int(i) for i in state["fail_calls"])
            self.delay_calls = {int(k): float(v)
                                for k, v in state["delay_calls"].items()}
            self.corrupt_calls = {
                int(k): CorruptionSpec(**v)
                for k, v in state.get("corrupt_calls", {}).items()}
            self.device_loss = {
                int(k): int(v)
                for k, v in state.get("device_loss", {}).items()}
            self.device_return = {
                int(k): int(v)
                for k, v in state.get("device_return", {}).items()}
            self.down = set(int(d) for d in state.get("down", []))
            self.device_faults = int(state.get("device_faults", 0))


class TrainSupervisor:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        data_fn: Callable[[int], Any],
        ckpt: CheckpointManager,
        *,
        checkpoint_every: int = 50,
        max_restarts: int = 10,
        mesh=None,
        shardings=None,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.mesh = mesh
        self.shardings = shardings
        self.straggler = straggler or StragglerMonitor()
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, state, start_step: int, num_steps: int):
        """Run to ``start_step + num_steps``, surviving step failures."""
        step = start_step
        target = start_step + num_steps
        # Host snapshot of the initial state: a failure BEFORE the first
        # checkpoint restarts from here.  Without it the retry loop kept
        # the partially-advanced state while resetting only the step
        # counter — a silent divergence from a clean run.
        init_state = jax.tree.map(np.asarray, state)
        # resume from a newer checkpoint if one exists
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state, step = self.ckpt.restore(state, shardings=self.shardings)
            log.info("resumed at step %d", step)

        while step < target:
            try:
                t0 = time.perf_counter()
                batch = self.data_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                flagged = self.straggler.record(step, dt)
                if flagged:
                    self.history.append({"step": step,
                                         "event": "straggler",
                                         "dt": dt})
                step += 1
                self.history.append({"step": step, "metrics": {
                    k: float(v) for k, v in metrics.items()}})
                if step % self.checkpoint_every == 0 or step == target:
                    self.ckpt.save(step, state, mesh=self.mesh)
            except WorkerFailure as e:
                self.restarts += 1
                self.history.append({"step": step, "event": "failure",
                                     "error": str(e)})
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                latest = self.ckpt.latest_step()
                if latest is None:
                    log.warning("failure before first checkpoint; "
                                "restarting from initial state")
                    state = jax.tree.map(np.array, init_state)
                    step = start_step
                    continue
                self.ckpt.wait()
                state, step = self.ckpt.restore(state,
                                                shardings=self.shardings)
                log.info("restored step %d after failure (%d restarts)",
                         step, self.restarts)
        self.ckpt.wait()
        return state, step


@dataclasses.dataclass(frozen=True)
class DivergencePolicy:
    """Graceful-degradation ladder for ``NumericalDivergence`` failures.

    Each divergence event consumes ONE rung of the ladder, in order:

      1. ``promote_f32`` — if the run was computing in bfloat16, retry
         the remaining rounds in float32 (the usual cure: bf16's 8-bit
         mantissa under-resolves small loss deltas at cold tau).
      2. ``tau_floor`` — clamp ``tau_end`` up to the floor; an
         over-aggressive anneal drives the softmax logits ``w / tau``
         to overflow before the permutation has locked in.
      3. ``widen_band`` — double an explicit band half-width (or drop
         an ``"auto"`` band back to dense): a too-narrow band can strand
         mass outside the window and zero out rows.

    **Integrity violations** (``runtime.guardrails.IntegrityViolation``
    — a guardrail probe caught silent corruption) reorder the ladder:
    ``integrity_retries`` plain replays from the last *verified*
    checkpoint come first (transient SDC needs no config change — the
    supervisor tracks these separately from config fallbacks), then
    ``oracle_fallback`` retires the Pallas kernel tier
    (``use_kernel=False`` — the pure-jnp oracle is the reference
    implementation), then band widening for band-tail violations, then
    the generic rungs above.

    ``apply`` returns the degraded config plus a human-readable
    description, or ``None`` when no rung is applicable — the caller
    (``AnnealSupervisor``) re-raises the original divergence then.
    Retries restart from the last committed rung checkpoint, so the
    ladder never repeats completed work (EXPERIMENTS.md §Robustness).
    """
    promote_f32: bool = True
    tau_floor: float = 0.05
    widen_band: bool = True
    max_fallbacks: int = 3
    oracle_fallback: bool = True
    integrity_retries: int = 1

    def apply(self, cfg, failure) -> Optional[tuple[Any, str]]:
        # Guardrail violations first try dropping the kernel tier (SDC
        # lives in the accelerated path; the jnp oracle IS the spec),
        # and band-tail violations widen the band before anything else.
        integrity = getattr(failure, "probe", None) is not None
        if integrity:
            probe = failure.probe
            if (probe == "band_tail" and self.widen_band
                    and cfg.band is not None):
                if cfg.band == "auto":
                    return (dataclasses.replace(cfg, band=None),
                            "dropped band 'auto' -> dense")
                return (dataclasses.replace(cfg, band=int(cfg.band) * 2),
                        f"widened band {cfg.band} -> {int(cfg.band) * 2}")
            if self.oracle_fallback and cfg.use_kernel:
                return (dataclasses.replace(cfg, use_kernel=False),
                        "retired kernel tier -> pure-jnp oracle apply")
        if self.promote_f32 and cfg.compute_dtype == "bfloat16":
            return (dataclasses.replace(cfg, compute_dtype="float32"),
                    "promoted compute_dtype bfloat16 -> float32")
        if self.tau_floor and cfg.tau_end < self.tau_floor:
            return (dataclasses.replace(cfg, tau_end=float(self.tau_floor)),
                    f"clamped tau_end {cfg.tau_end:g} -> {self.tau_floor:g}")
        if self.widen_band and cfg.band is not None:
            if cfg.band == "auto":
                return (dataclasses.replace(cfg, band=None),
                        "dropped band 'auto' -> dense")
            return (dataclasses.replace(cfg, band=int(cfg.band) * 2),
                    f"widened band {cfg.band} -> {int(cfg.band) * 2}")
        return None


class AnnealSupervisor:
    """Checkpoint/restart driver for the annealing engines — the sort
    path's sibling of ``TrainSupervisor``.

    Wraps one of the resumable entry points
    (``shuffle_soft_sort_batched`` by default; ``restart_tournament``
    and ``shuffle_soft_sort`` share the knob contract) and supervises a
    run to completion:

    * **Worker failures** (``failure_types``) restart the engine with
      ``resume=True`` under a ``RetryPolicy`` budget — the engine
      replays from its last committed rung-boundary checkpoint, and
      because rung carries are complete (orders + PRNG keys + losses +
      controller state), the finished run is bit-identical per seed to
      an uninterrupted one (tests/test_checkpointing.py kill-at-any-rung
      sweep).
    * **Numerical divergences** consume rungs of an optional
      ``DivergencePolicy`` ladder instead of the retry budget; each
      fallback re-runs only the rounds after the last finite rung,
      with the degraded config recorded in ``stats["fallbacks"]``.

    The supervisor owns no engine state — the checkpoint directory IS
    the state, which is what makes the restart path preemption-safe:
    kill the process anywhere and a new supervisor over the same
    directory continues the run.
    """

    def __init__(self, run_fn: Optional[Callable] = None, *,
                 checkpoint_dir: str,
                 retry: Optional[RetryPolicy] = None,
                 degrade: Optional[DivergencePolicy] = None,
                 failure_types: tuple = (WorkerFailure,),
                 sleep_fn: Callable[[float], None] = time.sleep):
        if run_fn is None:
            from repro.core.shufflesoftsort import shuffle_soft_sort_batched
            run_fn = shuffle_soft_sort_batched
        self.run_fn = run_fn
        self.checkpoint_dir = checkpoint_dir
        self.retry = retry or RetryPolicy()
        self.degrade = degrade
        self.failure_types = tuple(failure_types)
        self.sleep_fn = sleep_fn
        self.stats: dict[str, Any] = {
            "attempts": 0, "restarts": 0, "fallbacks": [],
            "verified_replays": 0, "integrity_incidents": []}
        self.history: list[dict] = []

    def run(self, xs, hw, cfg, **kwargs):
        """Run ``run_fn(xs, hw, cfg, ...)`` to completion, restarting
        from the latest rung checkpoint after each supervised failure.
        Extra ``kwargs`` are forwarded verbatim (engine selection knobs,
        ``rung_hook`` for chaos tests, ``guardrail=`` policies, ...).

        ``IntegrityViolation`` (a guardrail probe caught silent
        corruption) is repaired like a divergence, with one extra rung
        ahead of the config ladder: up to ``degrade.integrity_retries``
        plain replays from the last VERIFIED checkpoint (probes run
        before every ``ckpt.save``, so the newest checkpoint passed
        them) — transient SDC heals with no config change, and the
        replayed run is bit-identical per seed to a clean one.  Every
        incident lands in ``stats["integrity_incidents"]``."""
        from repro.core.shufflesoftsort import NumericalDivergence
        from repro.runtime.guardrails import IntegrityViolation
        cfg_cur = cfg
        restarts = 0
        replays = 0
        while True:
            self.stats["attempts"] += 1
            try:
                return self.run_fn(xs, hw, cfg_cur,
                                   checkpoint_dir=self.checkpoint_dir,
                                   resume=True, **kwargs)
            except (NumericalDivergence, IntegrityViolation) as e:
                integrity = isinstance(e, IntegrityViolation)
                if integrity:
                    self.stats["integrity_incidents"].append(e.incident())
                    budget = (self.degrade.integrity_retries
                              if self.degrade is not None else 0)
                    if replays < budget:
                        replays += 1
                        self.stats["verified_replays"] += 1
                        self.history.append({
                            "event": "integrity", "probe": e.probe,
                            "round": e.round,
                            "fallback": "replayed from last verified "
                                        "checkpoint"})
                        log.warning(
                            "integrity violation (%s) at round %s: "
                            "replaying from last verified checkpoint",
                            e.probe, e.round)
                        continue
                n_fb = len(self.stats["fallbacks"])
                fallback = None
                if (self.degrade is not None
                        and n_fb < self.degrade.max_fallbacks):
                    fallback = self.degrade.apply(cfg_cur, e)
                if fallback is None:
                    raise
                cfg_cur, desc = fallback
                self.stats["fallbacks"].append(desc)
                self.history.append({
                    "event": "integrity" if integrity else "divergence",
                    "round": e.round, "tau": e.tau,
                    "dtype": e.dtype, "fallback": desc})
                log.warning("%s at round %s (tau=%s, %s): %s",
                            "integrity violation" if integrity
                            else "divergence",
                            e.round, e.tau, e.dtype, desc)
            except self.failure_types as e:
                restarts += 1
                self.stats["restarts"] = restarts
                self.history.append({"event": "failure", "error": str(e)})
                if restarts > self.retry.max_retries:
                    raise RuntimeError(
                        f"exceeded {self.retry.max_retries} restarts"
                    ) from e
                # Elastic restart: a DeviceLost names the dead device,
                # so the retry re-shards over the survivors instead of
                # replaying onto the slot that just failed (the rung
                # carry is layout-free, so the resumed run is still
                # bit-identical per seed — EXPERIMENTS.md §Robustness).
                dev = getattr(e, "device_id", None)
                mesh = kwargs.get("mesh")
                if dev is not None and mesh is not None:
                    survivors = [d for d in mesh.devices.flat
                                 if d.id != dev]
                    if survivors:
                        from repro.launch.mesh import make_sort_mesh
                        kwargs["mesh"] = make_sort_mesh(
                            len(survivors), devices=survivors)
                        self.stats.setdefault("evictions", 0)
                        self.stats["evictions"] += 1
                        self.history.append(
                            {"event": "evict", "device": int(dev),
                             "survivors": len(survivors)})
                        log.warning(
                            "evicted device %d; re-sharded over %d "
                            "survivors", dev, len(survivors))
                delay = self.retry.backoff(restarts)
                if delay:
                    self.sleep_fn(delay)
                log.info("restarting after failure (%d restarts): %s",
                         restarts, e)
