"""Training supervisor: checkpoint/restart fault tolerance + elastic
re-meshing.

On a real fleet the failure signal is a missing heartbeat or an XLA
collective timeout; here the supervisor catches exceptions raised by the
step function (tests inject them deterministically) and restores from
the newest checkpoint.  The restore path accepts a different mesh than
the one the checkpoint was written under — `CheckpointManager.restore`
re-device_puts logical arrays with the new shardings, which is the whole
elastic-scaling story at this layer.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Optional

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.runtime")


class WorkerFailure(RuntimeError):
    """Simulated node failure (tests / chaos injection)."""


class TrainSupervisor:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        data_fn: Callable[[int], Any],
        ckpt: CheckpointManager,
        *,
        checkpoint_every: int = 50,
        max_restarts: int = 10,
        mesh=None,
        shardings=None,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.mesh = mesh
        self.shardings = shardings
        self.straggler = straggler or StragglerMonitor()
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, state, start_step: int, num_steps: int):
        """Run to ``start_step + num_steps``, surviving step failures."""
        step = start_step
        target = start_step + num_steps
        # resume from a newer checkpoint if one exists
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state, step = self.ckpt.restore(state, shardings=self.shardings)
            log.info("resumed at step %d", step)

        while step < target:
            try:
                t0 = time.perf_counter()
                batch = self.data_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                flagged = self.straggler.record(step, dt)
                if flagged:
                    self.history.append({"step": step,
                                         "event": "straggler",
                                         "dt": dt})
                step += 1
                self.history.append({"step": step, "metrics": {
                    k: float(v) for k, v in metrics.items()}})
                if step % self.checkpoint_every == 0 or step == target:
                    self.ckpt.save(step, state, mesh=self.mesh)
            except WorkerFailure as e:
                self.restarts += 1
                self.history.append({"step": step, "event": "failure",
                                     "error": str(e)})
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                latest = self.ckpt.latest_step()
                if latest is None:
                    log.warning("failure before first checkpoint; "
                                "restarting from initial state")
                    step = start_step
                    continue
                self.ckpt.wait()
                state, step = self.ckpt.restore(state,
                                                shardings=self.shardings)
                log.info("restored step %d after failure (%d restarts)",
                         step, self.restarts)
        self.ckpt.wait()
        return state, step
