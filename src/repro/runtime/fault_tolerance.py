"""Fault tolerance: checkpoint/restart supervision, retry policies, and
deterministic chaos injection.

On a real fleet the failure signal is a missing heartbeat or an XLA
collective timeout; here failures surface as exceptions raised by a
step/dispatch function (tests inject them deterministically via
``FaultInjector``).  Two consumers share this module:

* ``TrainSupervisor`` — the LM trainer's driver: catches step failures
  and restores from the newest checkpoint.  The restore path accepts a
  different mesh than the one the checkpoint was written under —
  `CheckpointManager.restore` re-device_puts logical arrays with the
  new shardings, which is the whole elastic-scaling story at this layer.
* The sort-serving scheduler (``repro.launch.serve.SortServer``) — a
  failed segment dispatch re-queues its requests from their last
  committed round boundary (the request state IS the checkpoint) under
  a ``RetryPolicy`` budget with exponential backoff, instead of failing
  every coalesced future (EXPERIMENTS.md §Serving).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.runtime")


class WorkerFailure(RuntimeError):
    """Simulated node failure (tests / chaos injection)."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry budget + exponential backoff for failed dispatches.

    ``max_retries`` is per unit of work (a training run's restarts, a
    sort request's re-queues), not per process; exhausting it converts
    the transient-failure path into a typed terminal error at the
    caller.  ``backoff(attempt)`` is the delay before re-queueing after
    the ``attempt``-th consecutive failure (1-based): base * mult^(a-1),
    capped — the standard exponential schedule, deterministic so tests
    can assert exact eligibility times.
    """
    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_mult: float = 2.0
    backoff_max_s: float = 2.0

    def backoff(self, attempt: int) -> float:
        if attempt < 1:
            raise ValueError(f"attempt is 1-based, got {attempt}")
        return min(self.backoff_base_s * self.backoff_mult ** (attempt - 1),
                   self.backoff_max_s)


class FaultInjector:
    """Deterministic chaos harness around a dispatch callable.

    Wraps ``engine_fn``; the i-th call (0-based) first sleeps
    ``delay_calls[i]`` seconds if present (straggler injection), then
    raises ``exc_type`` if ``i`` is in ``fail_calls`` (worker-failure
    injection), else forwards to the engine.  Everything is counted
    (``calls`` / ``faults`` / ``delays``) so tests and the serving
    benchmark can assert exactly which dispatches were perturbed — the
    sort-path analogue of the flaky step functions
    ``tests/test_runtime.py`` feeds the TrainSupervisor.
    """

    def __init__(self, engine_fn: Callable, fail_calls=(),
                 delay_calls: Optional[dict[int, float]] = None,
                 exc_type: type = WorkerFailure,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.engine_fn = engine_fn
        self.fail_calls = set(fail_calls)
        self.delay_calls = dict(delay_calls or {})
        self.exc_type = exc_type
        self.sleep_fn = sleep_fn
        self.calls = 0
        self.faults = 0
        self.delays = 0

    def __call__(self, *args, **kwargs):
        i = self.calls
        self.calls += 1
        if i in self.delay_calls:
            self.delays += 1
            self.sleep_fn(self.delay_calls[i])
        if i in self.fail_calls:
            self.faults += 1
            raise self.exc_type(f"injected fault at dispatch {i}")
        return self.engine_fn(*args, **kwargs)


class TrainSupervisor:
    def __init__(
        self,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        data_fn: Callable[[int], Any],
        ckpt: CheckpointManager,
        *,
        checkpoint_every: int = 50,
        max_restarts: int = 10,
        mesh=None,
        shardings=None,
        straggler: Optional[StragglerMonitor] = None,
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.mesh = mesh
        self.shardings = shardings
        self.straggler = straggler or StragglerMonitor()
        self.restarts = 0
        self.history: list[dict] = []

    def run(self, state, start_step: int, num_steps: int):
        """Run to ``start_step + num_steps``, surviving step failures."""
        step = start_step
        target = start_step + num_steps
        # resume from a newer checkpoint if one exists
        latest = self.ckpt.latest_step()
        if latest is not None and latest > step:
            state, step = self.ckpt.restore(state, shardings=self.shardings)
            log.info("resumed at step %d", step)

        while step < target:
            try:
                t0 = time.perf_counter()
                batch = self.data_fn(step)
                state, metrics = self.step_fn(state, batch)
                dt = time.perf_counter() - t0
                flagged = self.straggler.record(step, dt)
                if flagged:
                    self.history.append({"step": step,
                                         "event": "straggler",
                                         "dt": dt})
                step += 1
                self.history.append({"step": step, "metrics": {
                    k: float(v) for k, v in metrics.items()}})
                if step % self.checkpoint_every == 0 or step == target:
                    self.ckpt.save(step, state, mesh=self.mesh)
            except WorkerFailure as e:
                self.restarts += 1
                self.history.append({"step": step, "event": "failure",
                                     "error": str(e)})
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                latest = self.ckpt.latest_step()
                if latest is None:
                    log.warning("failure before first checkpoint; "
                                "restarting from initial state")
                    step = start_step
                    continue
                self.ckpt.wait()
                state, step = self.ckpt.restore(state,
                                                shardings=self.shardings)
                log.info("restored step %d after failure (%d restarts)",
                         step, self.restarts)
        self.ckpt.wait()
        return state, step
