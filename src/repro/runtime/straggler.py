"""Straggler detection/mitigation.

Per-step wall time feeds an EWMA mean/variance; a step slower than
``mean + z * std`` (and at least ``min_ratio`` x mean) is flagged.
Flagged steps never update the baseline, so one straggler cannot poison
the statistics it is judged against.  On a real fleet the flag feeds
the scheduler (demote host to backup group, re-shard its data); the two
in-process consumers are the LM TrainSupervisor (history event +
optional ``on_straggler`` callback) and the sort-serving scheduler
(``repro.launch.serve.SortServer``), which feeds per-dispatch
wall-clock normalized per instance-round and halves its batch bucket
cap on a flag so one slow coalesced batch stops stalling the traffic
behind it.  Detection, the warmup-only stream, a straggler on the very
first post-warmup step, and the healthy-steps-only baseline update are
exercised with injected delays in tests/test_runtime.py; the serving
reroute in tests/test_serving.py.
"""
from __future__ import annotations

import math
from typing import Callable, Optional


class StragglerMonitor:
    def __init__(self, z: float = 4.0, min_ratio: float = 1.5,
                 alpha: float = 0.05, warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.z = z
        self.min_ratio = min_ratio
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            # prime the EWMA
            self.mean = dt if self.count == 1 else (
                self.mean + (dt - self.mean) / self.count)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        std = math.sqrt(self.var) + 1e-9
        is_straggler = (dt > self.mean + self.z * std
                        and dt > self.min_ratio * self.mean)
        if is_straggler:
            self.flagged.append((step, dt))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self.mean)
        else:
            # only update stats with healthy steps so one straggler does
            # not poison the baseline
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler
