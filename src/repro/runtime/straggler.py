"""Straggler + device-health detection/mitigation.

Per-step wall time feeds an EWMA mean/variance; a step slower than
``mean + z * std`` (and at least ``min_ratio`` x mean) is flagged.
Flagged steps never update the baseline, so one straggler cannot poison
the statistics it is judged against.  On a real fleet the flag feeds
the scheduler (demote host to backup group, re-shard its data); the two
in-process consumers are the LM TrainSupervisor (history event +
optional ``on_straggler`` callback) and the sort-serving scheduler
(``repro.launch.serve.SortServer``), which feeds per-dispatch
wall-clock normalized per instance-round and halves its batch bucket
cap on a flag so one slow coalesced batch stops stalling the traffic
behind it.  Detection, the warmup-only stream, a straggler on the very
first post-warmup step, and the healthy-steps-only baseline update are
exercised with injected delays in tests/test_runtime.py; the serving
reroute in tests/test_serving.py.

``DeviceHealthMonitor`` is the sibling layer for HARD failures: where
the straggler monitor watches wall-clock, the health monitor watches
dispatch exceptions and classifies them transient (anonymous — retry
the dispatch as-is under the caller's ``RetryPolicy``) vs lost (a
``DeviceLost`` naming the device, past the strike budget — evict it
and re-shard over the survivors at the next rung boundary).  Elastic
eviction/return proofs: tests/test_elastic.py.
"""
from __future__ import annotations

import math
from typing import Callable, Optional


class StragglerMonitor:
    def __init__(self, z: float = 4.0, min_ratio: float = 1.5,
                 alpha: float = 0.05, warmup: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.z = z
        self.min_ratio = min_ratio
        self.alpha = alpha
        self.warmup = warmup
        self.on_straggler = on_straggler
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self.flagged: list[tuple[int, float]] = []

    def record(self, step: int, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            # prime the EWMA
            self.mean = dt if self.count == 1 else (
                self.mean + (dt - self.mean) / self.count)
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        std = math.sqrt(self.var) + 1e-9
        is_straggler = (dt > self.mean + self.z * std
                        and dt > self.min_ratio * self.mean)
        if is_straggler:
            self.flagged.append((step, dt))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self.mean)
        else:
            # only update stats with healthy steps so one straggler does
            # not poison the baseline
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler


class DeviceHealthMonitor:
    """Classify per-shard dispatch failures: transient vs lost device.

    ``classify(exc)`` inspects one dispatch failure.  An exception that
    names a device (a ``device_id`` attribute, e.g.
    ``runtime.fault_tolerance.DeviceLost``) counts a strike against it;
    once the device has ``lost_after`` strikes it is declared LOST and
    its id is returned — the caller evicts it from the mesh and
    re-shards at the next rung boundary.  Anything else (anonymous
    failures, devices still under the strike budget) returns ``None``:
    transient, retry under the caller's ``RetryPolicy``.

    ``record_success(device_ids)`` clears strikes for devices that just
    served a clean dispatch, so intermittent flakes never accumulate
    into a false eviction.  ``poll_returns()`` re-probes the evicted
    set against ``probe(device_id) -> bool`` (e.g.
    ``FaultInjector.healthy``) and returns the devices that came back —
    the caller grows the mesh at the next boundary.

    State round-trips through ``state_dict``/``load_state_dict`` so a
    preempted server resumes with the same evicted-device set and
    strike counts (``WarmHandoff``; tests/test_serving.py).
    """

    def __init__(self, lost_after: int = 1,
                 probe: Optional[Callable[[int], bool]] = None):
        if lost_after < 1:
            raise ValueError(f"lost_after must be >= 1, got {lost_after}")
        self.lost_after = int(lost_after)
        self.probe = probe
        self.strikes: dict[int, int] = {}
        self.evicted: list[int] = []          # eviction order

    def classify(self, exc: BaseException) -> Optional[int]:
        dev = getattr(exc, "device_id", None)
        if dev is None:
            return None
        dev = int(dev)
        if dev in self.evicted:
            # already evicted; the dispatch raced the re-shard
            return None
        self.strikes[dev] = self.strikes.get(dev, 0) + 1
        if self.strikes[dev] >= self.lost_after:
            self.evicted.append(dev)
            return dev
        return None

    def record_success(self, device_ids) -> None:
        for d in device_ids:
            self.strikes.pop(int(d), None)

    def poll_returns(self, probe: Optional[Callable[[int], bool]] = None
                     ) -> list[int]:
        probe = self.probe if probe is None else probe
        if probe is None:
            return []
        back = [d for d in self.evicted if probe(d)]
        for d in back:
            self.evicted.remove(d)
            self.strikes.pop(d, None)
        return back

    def state_dict(self) -> dict:
        return {"lost_after": self.lost_after,
                "strikes": {str(k): int(v)
                            for k, v in self.strikes.items()},
                "evicted": [int(d) for d in self.evicted]}

    def load_state_dict(self, state: dict) -> None:
        self.lost_after = int(state.get("lost_after", self.lost_after))
        self.strikes = {int(k): int(v)
                        for k, v in state.get("strikes", {}).items()}
        self.evicted = [int(d) for d in state.get("evicted", [])]
