"""Permutation-integrity guardrails: silent-corruption detection at rung
boundaries.

PR 8's fault-tolerance tier catches runs that *crash* (worker failures)
or *explode* (non-finite losses).  Nothing there catches a run that
finishes **wrong**: a Pallas kernel returning a subtly corrupted buffer
(silent data corruption — the failure mode production training fleets
now screen for), a banded tier whose real dropped softmax mass exceeds
the analytic ``band_tail_bound``, or a bf16 sweep drifting past its
documented parity envelope.  ShuffleSoftSort's output contract is cheap
to audit — a valid permutation of ``arange(N)`` plus a scalar loss per
round — so this module does exactly that, at the rung-boundary host
syncs the engines already pay for.

Three probe families, in increasing cost:

* **Invariant probes** (mode ``"invariants"`` and up) — pure host-side
  checks on state the engine already synced: committed orders are
  bijective permutations, losses are finite / non-negative (the grid
  layout loss is a sum of squared distances), no explosion vs. the
  committed loss history, no bitwise-stale loss segment (a repeated
  DMA buffer), and PRNG keys advanced exactly ``seg_len`` chained
  ``jax.random.split`` steps from the rung's input keys.
* **Band-tail audit** — when live ``w`` rows are available (adaptive
  engines, ``run_round_segment(with_w=True)``), the analytic
  ``band_tail_bound`` is evaluated on the *live* keys, and at sampled
  rungs the measured dropped mass is recomputed densely and checked
  against the bound (the bound is a theorem; measured > bound means
  corrupted keys, not a soft anneal).
* **Shadow recompute** (mode ``"shadow"``) — a deterministic hash of
  ``(policy.seed, rung start)`` samples ``shadow_rate`` of rungs; a
  sampled rung is re-run through the pure-jnp oracle tier
  (``use_kernel=False``) from the rung's input snapshot and compared
  at the per-dtype documented tolerance (f32 ``2e-3``, bf16 ``2e-2``
  — the same envelopes ``tools/check_bench.py`` gates).  On oracle
  configs the recompute is bit-exact, so committed orders are compared
  too; on f32 kernel configs orders are compared exactly (the ~1e-7
  apply parity cannot flip a converged argsort), while bf16 compares
  losses only.

Probe failures raise a typed :class:`IntegrityViolation` — sibling of
``NumericalDivergence`` — carrying the probe name, round, and a
structured incident record.  ``AnnealSupervisor`` repairs it through
the ``DivergencePolicy`` ladder (verified-rung replay first, then
kernel→oracle fallback, band widening, dtype promotion), resuming from
the last *verified* checkpoint: every engine runs its probes before
``ckpt.save``, so a corrupted segment is never committed.  SortServer
runs the same probes per request slice and self-heals via per-request
config overrides (EXPERIMENTS.md §Robustness, "Silent corruption").

Determinism contract: probes are read-only — they never touch engine
PRNG keys, never mutate state, and sampling is a pure function of
``(seed, rung start)`` — so a guarded run commits bit-identical results
to an unguarded one, per seed.
"""
from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.softsort import band_tail_bound

_VALID_MODES = ("off", "invariants", "shadow")

# Matches tools/check_bench.py --tol / --tol-bf16: the committed parity
# envelopes for kernel-vs-oracle comparisons per compute dtype.
DEFAULT_TOL = {"float32": 2e-3, "bfloat16": 2e-2}


class IntegrityViolation(RuntimeError):
    """A guardrail probe failed: the run produced state that violates
    the output contract (invalid permutation, corrupted losses, stale
    buffers, broken key chain, band-tail excess, or shadow-recompute
    mismatch).  Sibling of ``NumericalDivergence`` — carries the same
    location attributes plus the probe name and a structured incident
    record, so ``AnnealSupervisor`` / ``SortServer`` can log exactly
    what fired and route repair through the ``DivergencePolicy``
    ladder."""

    def __init__(self, message: str, *, probe: str,
                 round: Optional[int] = None,
                 tau: Optional[float] = None,
                 dtype: Optional[str] = None,
                 context: Optional[str] = None,
                 detail: Optional[dict] = None):
        super().__init__(message)
        self.probe = probe
        self.round = round
        self.tau = tau
        self.dtype = dtype
        self.context = context
        self.detail = dict(detail or {})

    def incident(self) -> dict:
        """JSON-able structured record for stats / audit surfaces."""
        rec = {"probe": self.probe, "round": self.round,
               "context": self.context, "message": str(self)}
        if self.tau is not None:
            rec["tau"] = float(self.tau)
        if self.dtype is not None:
            rec["dtype"] = self.dtype
        rec.update(self.detail)
        return rec


@dataclasses.dataclass(frozen=True)
class GuardrailPolicy:
    """Per-run (or per-request) probe configuration.

    ``mode`` selects the probe tier: ``"off"`` disables everything,
    ``"invariants"`` runs the free host-side checks, ``"shadow"`` adds
    sampled oracle recompute at ``shadow_rate``.  Sampling is a pure
    hash of ``(seed, rung start)`` — deterministic, replayable, and
    independent of wall clock and engine PRNG.  ``heal_after`` is the
    number of integrity strikes on one unit of work before the serving
    tier consumes a ``DivergencePolicy`` rung (the first strike is a
    plain replay from the last verified boundary — the right repair for
    transient SDC).  Tolerances default to the documented per-dtype
    parity envelopes; ``tail_slack`` is the multiplicative grace on the
    band-tail audit (the measured mass is itself a float sum).
    """
    mode: str = "invariants"
    shadow_rate: float = 0.03125          # 1/32 of rungs; overhead ~ rate
    seed: int = 0
    tol_f32: float = DEFAULT_TOL["float32"]
    tol_bf16: float = DEFAULT_TOL["bfloat16"]
    # Rung-level bf16 envelope for the shadow compare.  The 2e-2
    # apply-level parity does NOT survive an outer round: bf16's 8-bit
    # mantissa flips Adam rounding decisions, and measured clean drift
    # of a bf16 rung vs. the f32 oracle reaches ~0.13 rel (even vs. a
    # bf16-jnp recompute — it is dtype noise, not kernel error).  The
    # 0.5 gate stays far above benign drift and far below every
    # corruption signature (exponent flips ~1e30 rel, sign flips 2.0,
    # NaN always trips).
    shadow_rel_bf16: float = 0.5
    explosion_factor: float = 1e3
    tail_slack: float = 1.05
    heal_after: int = 1

    def __post_init__(self):
        if self.mode not in _VALID_MODES:
            raise ValueError(
                f"guardrail mode must be one of {_VALID_MODES}, "
                f"got {self.mode!r}")
        if not (0.0 <= self.shadow_rate <= 1.0):
            raise ValueError(
                f"shadow_rate must be in [0, 1], got {self.shadow_rate}")

    def tol(self, dtype: str) -> float:
        return self.tol_bf16 if str(dtype) == "bfloat16" else self.tol_f32

    def shadow_tol(self, dtype: str) -> float:
        """Rung-level loss envelope for the shadow-recompute compare
        (see ``shadow_rel_bf16`` for why bf16 differs from the
        apply-level parity constant)."""
        return (self.shadow_rel_bf16 if str(dtype) == "bfloat16"
                else self.tol_f32)


def shadow_sampled(seed: int, start: int, rate: float) -> bool:
    """Deterministic rung sampler: hash ``(seed, start)`` to [0, 1).

    crc32 of the decimal rendering — stable across platforms and
    processes (unlike ``hash()``), cheap, and uniform enough for a
    sampling decision.  ``rate=1.0`` samples every rung (chaos tests),
    ``rate=0.0`` none.
    """
    if rate <= 0.0:
        return False
    if rate >= 1.0:
        return True
    h = zlib.crc32(f"{int(seed)}:{int(start)}".encode()) & 0xFFFFFFFF
    return (h / 2.0 ** 32) < rate


@functools.lru_cache(maxsize=None)
def _key_chain_program(seg_len: int):
    # One jitted program per segment length — an eager per-call
    # vmap(split) chain retraces every rung and costs ~10 ms, which
    # alone would blow the probe overhead budget (BENCH_guardrails.json
    # gates <= 5% at the default sample rate).
    def chain(k):
        def step(kk, _):
            return jax.vmap(lambda one: jax.random.split(one)[0])(kk), None
        return jax.lax.scan(step, k, None, length=seg_len)[0]
    return jax.jit(chain)


def expected_key_chain(keys_in: np.ndarray, seg_len: int) -> np.ndarray:
    """The PRNG keys a clean engine must return after ``seg_len``
    rounds: every round consumes ``key, sub = split(key)`` and carries
    ``key`` forward, so the output keys are a pure function of the
    input keys — corrupted key state is exactly detectable."""
    k = jnp.asarray(np.asarray(keys_in))
    return np.asarray(jax.device_get(_key_chain_program(int(seg_len))(k)))


def measured_dropped_mass(w, tau, band: int, descending: bool = False):
    """Densely measure the softmax mass each SoftSort row drops outside
    a ±``band`` rank window — the quantity ``band_tail_bound`` upper
    bounds.  Host-side O(N^2) per instance; guardrails only run it at
    sampled rungs.  Mirrors the banded-apply window convention: row i
    of the dense relaxation targets the i-th largest (ascending
    commit) or i-th smallest (descending) key, and the window is the
    ±band neighborhood of rank i in that same ordering.
    """
    w = np.asarray(w, np.float64)
    if w.ndim == 1:
        w = w[None]
    tau_a = np.broadcast_to(np.asarray(tau, np.float64).reshape(-1),
                            (w.shape[0],)) \
        if np.ndim(tau) else np.full((w.shape[0],), float(tau))
    n = w.shape[1]
    worst = 0.0
    for b in range(w.shape[0]):
        row = w[b]
        srt = np.sort(row)[::-1] if not descending else np.sort(row)
        logits = -np.abs(srt[:, None] - row[None, :]) / max(tau_a[b], 1e-30)
        logits -= logits.max(axis=1, keepdims=True)
        p = np.exp(logits)
        p /= p.sum(axis=1, keepdims=True)
        # rank of each source position in the same ordering rows target
        order = np.argsort(-row, kind="stable") if not descending \
            else np.argsort(row, kind="stable")
        rank = np.empty(n, np.int64)
        rank[order] = np.arange(n)
        out = np.abs(rank[None, :] - np.arange(n)[:, None]) > int(band)
        worst = max(worst, float((p * out).sum(axis=1).max()))
    return worst


class GuardrailMonitor:
    """Stateful probe runner for one engine run (or one serving
    request).  Engines call :meth:`check_rung` at every rung-boundary
    host sync, *after* the finite sentinel and *before* committing a
    checkpoint — so the newest checkpoint is always the last verified
    rung.  All inputs are host arrays the engine already synced; the
    monitor never touches device state or engine PRNG.

    History carried across rungs: the committed loss ceiling (for the
    explosion sentinel) and the previous segment's loss bytes (for the
    stale-buffer probe).  Both reset per monitor — a fresh monitor
    re-establishes them on its first rung, which keeps warm restarts
    simple (sampling stays deterministic regardless, being keyed on
    ``(seed, start)``).
    """

    def __init__(self, policy: GuardrailPolicy,
                 context: str = "engine",
                 dtype: str = "float32"):
        if not isinstance(policy, GuardrailPolicy):
            raise TypeError(f"expected GuardrailPolicy, got {policy!r}")
        self.policy = policy
        self.context = context
        self.dtype = str(dtype)
        self.incidents: list[dict] = []
        self.rungs_checked = 0
        self.rungs_shadowed = 0
        self._loss_ref: Optional[float] = None
        self._prev_loss_bytes: Optional[bytes] = None

    # -- sampling ----------------------------------------------------
    @property
    def active(self) -> bool:
        return self.policy.mode != "off"

    def wants_shadow(self, start: int) -> bool:
        """Should the rung starting at round ``start`` be shadow
        recomputed?  Callers must snapshot the rung's *input* orders /
        keys to host BEFORE dispatching the primary segment — the
        batched engines donate their input buffers."""
        return (self.policy.mode == "shadow"
                and shadow_sampled(self.policy.seed, start,
                                   self.policy.shadow_rate))

    # -- probe driver ------------------------------------------------
    def _fail(self, probe: str, message: str, *, round=None, tau=None,
              **detail):
        if tau is not None:
            # Per-instance tau vectors (mixed-progress serving batches)
            # label the incident with the hottest value in the rung.
            t = np.asarray(tau, np.float64).reshape(-1)
            tau = float(t.max()) if t.size else None
        exc = IntegrityViolation(
            f"[guardrail:{probe}] {message} (context={self.context})",
            probe=probe, round=round, tau=tau,
            dtype=self.dtype, context=self.context, detail=detail)
        self.incidents.append(exc.incident())
        raise exc

    def check_rung(self, *, start: int, losses=None, orders=None,
                   n: Optional[int] = None, keys_in=None, keys_out=None,
                   seg_len: Optional[int] = None, ws=None, tau=None,
                   band: Optional[int] = None, banded_mask=None,
                   descending: bool = False,
                   oracle_losses=None, oracle_orders=None) -> None:
        """Run every applicable probe on one rung's synced state.

        ``losses`` is round-major ``(T, B)`` (or ``(T,)``); ``orders``
        is ``(B, N)`` committed permutations; ``keys_in``/``keys_out``
        bracket the rung's PRNG chain; ``ws``/``tau``/``band`` feed the
        band-tail audit (``banded_mask`` restricts it to the banded
        instances); ``oracle_losses``/``oracle_orders`` are the shadow
        recompute to compare against.  Raises IntegrityViolation on the
        first failing probe; returns None when the rung verifies.
        """
        if not self.active:
            return
        self.rungs_checked += 1
        pol = self.policy

        if losses is not None:
            seg = np.asarray(losses, np.float32)
            if seg.ndim == 1:
                seg = seg[:, None]
            if not np.isfinite(seg).all():
                t_bad = int(np.argwhere(
                    ~np.isfinite(seg).all(axis=1)).min())
                self._fail("finite",
                           f"non-finite loss at round {start + t_bad}",
                           round=start + t_bad, tau=tau)
            # The grid layout loss is a sum of squared pairwise
            # distances — strictly non-negative by construction.
            if float(seg.min()) < -1e-6:
                t_bad, b_bad = np.unravel_index(int(seg.argmin()),
                                                seg.shape)
                self._fail("loss_sign",
                           f"negative loss {float(seg.min()):.4g} at "
                           f"round {start + int(t_bad)}",
                           round=start + int(t_bad), tau=tau,
                           value=float(seg.min()))
            # Explosion vs. committed history: the anneal only ever
            # shrinks the loss across rungs, so anything orders of
            # magnitude above the committed ceiling is corruption, not
            # optimization.  First rung bootstraps the ceiling from its
            # own median (within-rung dynamic range is small).
            # Ceiling comes from COMMITTED rungs only — folding the
            # current segment in would let a corrupt value raise its
            # own limit.  The first rung bootstraps from its median.
            med = float(np.median(seg))
            ref = med if self._loss_ref is None else self._loss_ref
            lim = pol.explosion_factor * max(ref, 1e-6)
            if float(seg.max()) > lim:
                t_bad, b_bad = np.unravel_index(int(seg.argmax()),
                                                seg.shape)
                self._fail("loss_explosion",
                           f"loss {float(seg.max()):.4g} exceeds "
                           f"{pol.explosion_factor:g}x committed ceiling "
                           f"{ref:.4g} at round {start + int(t_bad)}",
                           round=start + int(t_bad), tau=tau,
                           value=float(seg.max()), limit=float(lim))
            # Stale buffer: consecutive rung segments bitwise equal is
            # a repeated DMA buffer, never a legitimate anneal (each
            # round draws a fresh shuffle).  Only meaningful for
            # multi-element segments.
            cur = seg.tobytes()
            if (seg.size >= 2 and self._prev_loss_bytes is not None
                    and cur == self._prev_loss_bytes):
                self._fail("stale_losses",
                           f"rung at round {start} returned a loss "
                           "segment bitwise-identical to the previous "
                           "rung", round=start, tau=tau)
            # History commits only after EVERY probe passes (end of this
            # method): a failing rung is replayed from the last verified
            # boundary and legitimately reproduces the same bytes — the
            # stale probe must compare against the last VERIFIED rung.
            commit_losses = (cur, max(ref, float(seg.max())))
        else:
            commit_losses = None

        if orders is not None:
            o = np.asarray(orders)
            if o.ndim == 1:
                o = o[None]
            nn = int(n if n is not None else o.shape[1])
            ok = (np.sort(o, axis=1) == np.arange(nn)).all(axis=1)
            if not ok.all():
                b_bad = int(np.argwhere(~ok).min())
                self._fail("permutation",
                           f"instance {b_bad} committed an invalid "
                           f"permutation after round "
                           f"{start + (seg_len or 0)}",
                           round=start, tau=tau, instance=b_bad)

        if keys_in is not None and keys_out is not None \
                and seg_len is not None:
            exp = expected_key_chain(keys_in, seg_len)
            got = np.asarray(keys_out)
            if exp.shape != got.shape or not (exp == got).all():
                self._fail("key_chain",
                           f"PRNG keys after rung at round {start} do "
                           f"not match the deterministic split chain "
                           f"({seg_len} rounds)", round=start, tau=tau)

        if ws is not None and band is not None and tau is not None:
            w = np.asarray(ws, np.float32)
            if w.ndim == 1:
                w = w[None]
            mask = np.ones(w.shape[0], bool) if banded_mask is None \
                else np.asarray(banded_mask, bool)
            if mask.any():
                wv = w[mask]
                tv = np.broadcast_to(
                    np.asarray(tau, np.float32).reshape(-1),
                    (w.shape[0],))[mask] if np.ndim(tau) \
                    else np.full((int(mask.sum()),), float(tau),
                                 np.float32)
                if not np.isfinite(wv).all():
                    self._fail("band_tail", "non-finite live keys in "
                               f"banded rung at round {start}",
                               round=start, tau=None)
                bound = float(np.max(band_tail_bound(wv, tv, int(band))))
                if self.wants_shadow(start):
                    meas = measured_dropped_mass(
                        wv, tv, int(band), descending=descending)
                    lim = bound * pol.tail_slack + 1e-6
                    if meas > lim:
                        self._fail(
                            "band_tail",
                            f"measured dropped mass {meas:.4g} exceeds "
                            f"analytic band_tail_bound {bound:.4g} at "
                            f"round {start} (band={band})",
                            round=start, measured=meas, bound=bound)

        if oracle_losses is not None and losses is not None:
            self.rungs_shadowed += 1
            a = np.asarray(losses, np.float64).reshape(-1)
            b = np.asarray(oracle_losses, np.float64).reshape(-1)
            tol = pol.shadow_tol(self.dtype)
            if a.shape != b.shape:
                self._fail("shadow", "shadow recompute shape mismatch "
                           f"at round {start}", round=start, tau=tau)
            rel = np.abs(a - b) / np.maximum(np.abs(b), 1e-6)
            # `not (ok).all()` so NaN in either side trips the probe.
            if not bool((rel <= tol).all()):
                worst = float(np.nanmax(rel)) \
                    if np.isfinite(rel).any() else float("inf")
                t_bad = int(np.argmax(~(rel <= tol)))
                self._fail(
                    "shadow",
                    f"kernel-vs-oracle loss mismatch at rung round "
                    f"{start}: rel err {worst:.4g} > tol {tol:g} "
                    f"({self.dtype})", round=start, tau=tau,
                    rel_err=worst, tol=tol)
        if oracle_orders is not None and orders is not None:
            a = np.asarray(orders)
            b = np.asarray(oracle_orders)
            if a.shape != b.shape or not (a == b).all():
                self._fail("shadow",
                           f"committed orders diverge from oracle "
                           f"recompute at rung round {start}",
                           round=start, tau=tau)

        if commit_losses is not None:
            self._prev_loss_bytes, self._loss_ref = commit_losses

    def compare_orders(self) -> bool:
        """Whether shadow recompute may compare committed orders
        exactly: safe for f32 (the ~1e-7 kernel-vs-oracle apply parity
        cannot flip a converged argsort); bf16 trajectories may
        legitimately commit different ties, so bf16 compares losses
        only."""
        return self.dtype != "bfloat16"

    def summary(self) -> dict:
        return {"mode": self.policy.mode,
                "shadow_rate": self.policy.shadow_rate,
                "rungs_checked": self.rungs_checked,
                "rungs_shadowed": self.rungs_shadowed,
                "incidents": list(self.incidents)}
