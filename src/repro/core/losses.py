"""Loss terms for grid-based permutation learning (paper eq. 2-4).

    L(P) = L_nbr(P) + lambda_s * L_s(P) + lambda_sigma * L_sigma(P)

* ``neighbor_loss_grid``         — smoothness term: normalized average
  distance of horizontally / vertically adjacent grid vectors.
* ``stochastic_constraint_loss`` — eq. 3: squared deviation of column
  sums of P_soft from 1 (pushes P toward doubly stochastic).
* ``std_loss``                   — eq. 4: |sigma_X - sigma_Y| / sigma_X,
  preserves the per-dimension spread so P cannot collapse rows onto the
  mean (a soft proxy for "is a permutation, not an averaging").

All terms are separable / row-block computable — nothing here ever needs
the full N x N matrix (the column sums arrive pre-reduced from the
chunked/Pallas softsort apply).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def neighbor_loss_grid(grid: jnp.ndarray, norm: jnp.ndarray | float = 1.0) -> jnp.ndarray:
    """Mean L2 distance between 4-neighbourhood grid cells.

    Args:
      grid: (H, W, d) soft-sorted vectors arranged on the target grid.
      norm: normalization constant (e.g. mean pairwise distance of the
        dataset) making the loss scale-free, per the paper's
        "normalized average distance".
    """
    dh = jnp.sqrt(jnp.sum(jnp.square(grid[:, 1:] - grid[:, :-1]), axis=-1) + 1e-12)
    dv = jnp.sqrt(jnp.sum(jnp.square(grid[1:, :] - grid[:-1, :]), axis=-1) + 1e-12)
    return (dh.mean() + dv.mean()) / (2.0 * norm)


def stochastic_constraint_loss(colsum: jnp.ndarray) -> jnp.ndarray:
    """Eq. 3 — colsum is the (N,) vector of column sums of P_soft."""
    return jnp.mean(jnp.square(colsum - 1.0))


def std_loss(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Eq. 4 — relative std deviation mismatch between input rows x and
    soft-sorted rows y, averaged over feature dimensions."""
    sx = jnp.std(x, axis=0)
    sy = jnp.std(y, axis=0)
    return jnp.mean(jnp.abs(sx - sy) / (sx + 1e-12))


def grid_sorting_loss(
    y: jnp.ndarray,
    colsum: jnp.ndarray,
    x: jnp.ndarray,
    hw: tuple[int, int],
    norm: jnp.ndarray | float = 1.0,
    lambda_s: float = 1.0,
    lambda_sigma: float = 2.0,
) -> jnp.ndarray:
    """Paper eq. 2 with the published lambda_s=1, lambda_sigma=2."""
    h, w = hw
    grid = y.reshape(h, w, -1)
    return (
        neighbor_loss_grid(grid, norm)
        + lambda_s * stochastic_constraint_loss(colsum)
        + lambda_sigma * std_loss(x, y)
    )


def mean_pairwise_distance(x: jnp.ndarray, sample: int = 2048,
                           key: jax.Array | None = None,
                           chunk: int = 256) -> jnp.ndarray:
    """Normalization constant for L_nbr: mean distance of random pairs.
    Exact for small N, sampled for large N.

    The exact path streams row chunks (``jax.lax.map`` over blocks of
    ``chunk`` rows, the tail block padded and masked), so peak live
    memory is O(chunk * N * d) instead of the (N, N, d) broadcast the
    previous version materialized (~134 MB at N=2048, d=8; the
    distance SUM it computes is unchanged).  Chunking only
    reassociates the float32 reduction, so the value agrees with the
    old all-at-once formula to a few ULP (bit-exact matching is not
    achievable by any reassociated rewrite — XLA's own (N, N)->scalar
    reduction order is already tiling-dependent; gated at rtol 5e-7 by
    ``tests/test_precision.py``).  Plain, vmapped, and grad calls all
    stream the same blocks, so the batched engines' eager vmap over
    this function stays bit-identical to the per-instance call — the
    property the per-seed engine contracts actually need.
    """
    n = x.shape[0]
    if n * n <= 4_194_304:  # exact up to 2048^2 pairs
        nb = -(-n // chunk)
        pad = nb * chunk - n
        xp = jnp.pad(x, ((0, pad), (0, 0)))
        valid = (jnp.arange(nb * chunk) < n).astype(x.dtype)

        def row_block(blk):
            xi, v = blk                       # (chunk, d), (chunk,)
            d = jnp.sqrt(jnp.sum(jnp.square(xi[:, None] - x[None, :]),
                                 axis=-1) + 1e-12)
            return jnp.sum(d, axis=-1) * v    # pad rows contribute 0

        rows = jax.lax.map(row_block, (xp.reshape(nb, chunk, -1),
                                       valid.reshape(nb, chunk)))
        return rows.reshape(-1).sum() / (n * (n - 1))
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    i = jax.random.randint(k1, (sample,), 0, n)
    j = jax.random.randint(k2, (sample,), 0, n)
    return jnp.mean(jnp.sqrt(jnp.sum(jnp.square(x[i] - x[j]), axis=-1) + 1e-12))
