"""Layout-quality metrics.

``dpq`` reimplements Distance Preservation Quality (Barthel et al., CGF
2023 [3]) from its published description: for each neighbourhood size
k <= p, compare the mean feature-space distance of every item to its k
*grid*-nearest neighbours against (a) the same quantity for the k
*feature*-nearest neighbours (the unreachable optimum) and (b) the mean
distance of random pairs (the chance level).  DPQ_p averages the
resulting preservation ratios over k = 1..p.  The paper uses DPQ_16.

Exact-formula caveat (see also EXPERIMENTS.md §Paper-claims): the CGF
paper is not
available in this environment, so absolute values are comparable but not
bit-identical to the paper's table; the metric ordering of methods is
the reproduction target.  ``mean_neighbor_distance`` — which [3] states
DPQ strongly correlates with — is reported alongside.
"""
from __future__ import annotations

import numpy as np


def _grid_positions(h: int, w: int) -> np.ndarray:
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    return np.stack([yy.ravel(), xx.ravel()], axis=-1).astype(np.float64)


def dpq(grid_vectors: np.ndarray, hw: tuple[int, int], p: int = 16) -> float:
    """Distance Preservation Quality of an (N, d) array laid out row-major
    on an (h, w) grid.  Higher is better; ~1.0 means grid neighbourhoods
    preserve feature neighbourhoods as well as theoretically possible."""
    x = np.asarray(grid_vectors, dtype=np.float64)
    h, w = hw
    n = x.shape[0]
    assert n == h * w, (n, hw)

    pos = _grid_positions(h, w)
    dg = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
    df = np.linalg.norm(x[:, None] - x[None, :], axis=-1)
    np.fill_diagonal(dg, np.inf)
    np.fill_diagonal(df, np.inf)

    grid_order = np.argsort(dg, axis=1)   # (N, N-1) grid-nearest first
    feat_order = np.argsort(df, axis=1)

    d_rand = df[np.isfinite(df)].mean()

    # Cumulative mean feature distance of the k grid/feat-nearest items.
    take = np.arange(n)[:, None]
    df_by_grid = df[take, grid_order[:, :p]]     # (N, p)
    df_by_feat = df[take, feat_order[:, :p]]     # (N, p)
    cum_grid = np.cumsum(df_by_grid, axis=1) / np.arange(1, p + 1)
    cum_feat = np.cumsum(df_by_feat, axis=1) / np.arange(1, p + 1)

    mean_grid_k = cum_grid.mean(axis=0)          # (p,)
    mean_feat_k = cum_feat.mean(axis=0)          # (p,)

    ratio = (d_rand - mean_grid_k) / np.maximum(d_rand - mean_feat_k, 1e-12)
    return float(np.clip(ratio, 0.0, 1.0).mean())


def mean_neighbor_distance(grid_vectors: np.ndarray, hw: tuple[int, int]) -> float:
    """Mean feature distance of 4-neighbourhood grid cells, normalized by
    the mean random-pair distance (lower is better)."""
    x = np.asarray(grid_vectors, dtype=np.float64)
    h, w = hw
    g = x.reshape(h, w, -1)
    dh = np.linalg.norm(g[:, 1:] - g[:, :-1], axis=-1)
    dv = np.linalg.norm(g[1:, :] - g[:-1, :], axis=-1)
    d_nbr = (dh.sum() + dv.sum()) / (dh.size + dv.size)
    df = np.linalg.norm(x[:, None] - x[None, :], axis=-1)
    d_rand = df[~np.eye(h * w, dtype=bool)].mean()
    return float(d_nbr / d_rand)
