"""'Kissing to Find a Match' low-rank permutation baseline (Droge et al.,
NeurIPS 2023): P ~ row_softmax(scale * V W^T) with row-normalized factors
V, W of shape (N, M), 2NM parameters.  The paper's Table III reports this
method failing to produce a valid permutation on the color-sorting task;
we reproduce both the method and (empirically) its instability.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.losses import (
    mean_pairwise_distance,
    neighbor_loss_grid,
    std_loss,
)
from repro.core.softsort import is_valid_permutation


@dataclasses.dataclass(frozen=True)
class KissingConfig:
    rank: int = 13              # M: 2NM = 26624 for N = 1024, as in Table III
    steps: int = 600
    scale_start: float = 4.0    # softmax sharpness (annealed up)
    scale_end: float = 60.0
    lr: float = 0.02
    lambda_sigma: float = 2.0
    lambda_s: float = 1.0


def _normalize_rows(m):
    return m / (jnp.linalg.norm(m, axis=-1, keepdims=True) + 1e-9)


@functools.partial(jax.jit, static_argnames=("hw", "cfg"))
def _train(x, norm, key, *, hw, cfg: KissingConfig):
    n = x.shape[0]
    k1, k2 = jax.random.split(key)
    v0 = jax.random.normal(k1, (n, cfg.rank)) * 0.1
    w0 = jax.random.normal(k2, (n, cfg.rank)) * 0.1

    def loss_fn(params, scale):
        v, w = params
        p = jax.nn.softmax(scale * _normalize_rows(v) @ _normalize_rows(w).T,
                           axis=-1)
        y = p @ x
        colsum = p.sum(axis=0)
        return (neighbor_loss_grid(y.reshape(hw[0], hw[1], -1), norm)
                + cfg.lambda_s * jnp.mean(jnp.square(colsum - 1.0))
                + cfg.lambda_sigma * std_loss(x, y))

    grad_fn = jax.value_and_grad(loss_fn)

    def body(i, carry):
        params, mu, nu, _ = carry
        frac = i.astype(jnp.float32) / cfg.steps
        scale = cfg.scale_start * (cfg.scale_end / cfg.scale_start) ** frac
        loss, g = grad_fn(params, scale)
        t = i.astype(jnp.float32) + 1.0
        mu = jax.tree.map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree.map(lambda v_, gg: 0.999 * v_ + 0.001 * gg * gg, nu, g)
        params = jax.tree.map(
            lambda p_, m, v_: p_ - cfg.lr * (m / (1 - 0.9 ** t)) /
            (jnp.sqrt(v_ / (1 - 0.999 ** t)) + 1e-8),
            params, mu, nu)
        return (params, mu, nu, loss)

    zeros = (jnp.zeros_like(v0), jnp.zeros_like(w0))
    (v, w), _, _, loss = jax.lax.fori_loop(
        0, cfg.steps, body, ((v0, w0), zeros, zeros, jnp.float32(0.0)))
    p = jax.nn.softmax(cfg.scale_end * _normalize_rows(v) @ _normalize_rows(w).T,
                       axis=-1)
    return jnp.argmax(p, axis=-1), loss


def kissing_sort(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: KissingConfig = KissingConfig(),
    key: jax.Array | None = None,
) -> tuple[np.ndarray, np.ndarray, float, bool]:
    """Returns (order, x[order], loss, valid).  ``valid`` is False when the
    argmax binarization contains duplicates (the paper's reported failure
    mode — Table III footnote)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    order, loss = _train(x, norm, key, hw=hw, cfg=cfg)
    order = np.asarray(order)
    valid = is_valid_permutation(order)
    return order, np.asarray(x)[order], float(loss), valid
