# Comparison methods from the paper's Table III.
from repro.core.baselines.gumbel_sinkhorn import gumbel_sinkhorn_sort  # noqa: F401
from repro.core.baselines.kissing import kissing_sort  # noqa: F401
