"""Gumbel-Sinkhorn permutation learning (Mena et al., ICLR 2018).

The N^2-parameter baseline from the paper's Table III: a logit matrix is
pushed toward a doubly-stochastic matrix by Sinkhorn normalization (with
Gumbel noise for exploration), trained with the same grid loss, and
binarized with the Hungarian algorithm (Jonker-Volgenant via scipy).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
from scipy.optimize import linear_sum_assignment

import jax
import jax.numpy as jnp

from repro.core.losses import (
    mean_pairwise_distance,
    neighbor_loss_grid,
    std_loss,
)


@dataclasses.dataclass(frozen=True)
class GumbelSinkhornConfig:
    steps: int = 600
    sinkhorn_iters: int = 20
    tau_start: float = 2.0
    tau_end: float = 0.05
    noise: float = 0.2          # gumbel noise scale (annealed with tau)
    lr: float = 0.05
    lambda_sigma: float = 2.0


def sinkhorn(log_alpha: jnp.ndarray, iters: int) -> jnp.ndarray:
    """Sinkhorn normalization in log space; returns a ~doubly-stochastic P."""
    def body(_, la):
        la = la - jax.nn.logsumexp(la, axis=1, keepdims=True)
        la = la - jax.nn.logsumexp(la, axis=0, keepdims=True)
        return la
    return jnp.exp(jax.lax.fori_loop(0, iters, body, log_alpha))


@functools.partial(jax.jit, static_argnames=("hw", "cfg"))
def _train(x, norm, key, *, hw, cfg: GumbelSinkhornConfig):
    n = x.shape[0]

    def loss_fn(logits, tau, noise_scale, key):
        g = -jnp.log(-jnp.log(jax.random.uniform(key, (n, n), minval=1e-9,
                                                 maxval=1.0) + 1e-9))
        p = sinkhorn((logits + noise_scale * g) / tau, cfg.sinkhorn_iters)
        y = p @ x
        return (neighbor_loss_grid(y.reshape(hw[0], hw[1], -1), norm)
                + cfg.lambda_sigma * std_loss(x, y))

    grad_fn = jax.value_and_grad(loss_fn)

    def body(i, carry):
        logits, mu, nu, key, _ = carry
        key, sub = jax.random.split(key)
        frac = i.astype(jnp.float32) / cfg.steps
        tau = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** frac
        loss, g = grad_fn(logits, tau, cfg.noise * (1.0 - frac), sub)
        t = i.astype(jnp.float32) + 1.0
        mu = 0.9 * mu + 0.1 * g
        nu = 0.999 * nu + 0.001 * jnp.square(g)
        logits = logits - cfg.lr * (mu / (1 - 0.9 ** t)) / (
            jnp.sqrt(nu / (1 - 0.999 ** t)) + 1e-8)
        return (logits, mu, nu, key, loss)

    logits0 = jnp.zeros((n, n), jnp.float32)
    logits, _, _, _, loss = jax.lax.fori_loop(
        0, cfg.steps, body,
        (logits0, jnp.zeros_like(logits0), jnp.zeros_like(logits0), key,
         jnp.float32(0.0)))
    return logits, loss


def gumbel_sinkhorn_sort(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: GumbelSinkhornConfig = GumbelSinkhornConfig(),
    key: jax.Array | None = None,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Returns (order, x[order], final_loss). order[i] = input row at grid i."""
    if key is None:
        key = jax.random.PRNGKey(0)
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    logits, loss = _train(x, norm, key, hw=hw, cfg=cfg)
    # Hungarian binarization guarantees a valid permutation.
    rows, cols = linear_sum_assignment(-np.asarray(logits))
    order = cols[np.argsort(rows)]
    return order, np.asarray(x)[order], float(loss)
