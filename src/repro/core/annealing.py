"""Convergence-driven adaptive annealing (ROADMAP item 4).

The fixed engines run a precomputed R-round geometric tau schedule to
the end, but on the paper-claims config the loss trace is flat over
roughly the last third of the rounds (EXPERIMENTS.md §Paper-claims) —
rounds a serving stack pays for without buying loss.  This module is
the opt-in ``ShuffleSoftSortConfig.schedule="adaptive"`` controller
that converts measured convergence into skipped rounds:

* **Plateau-driven tau decay** — a per-instance EWMA of the per-round
  loss; when its relative improvement stays below ``plateau_rtol`` for
  ``patience`` consecutive rungs, the instance JUMPS ``decay_rungs``
  rungs ahead in the nominal schedule (colder tau sooner).  A jump past
  the schedule end is an early stop: the instance leaves the anneal at
  that rung boundary.
* **Measured dense->banded switch** — instead of the linear-init model
  (``_band_switch_round``), each still-dense instance evaluates the
  TRUE tail bound ``core.softsort.band_tail_bound`` on its own
  end-of-round keys; it switches the moment its measured bound clears
  ``band_eps`` (one-way: the anneal is monotone, a switched instance
  stays banded).
* **Per-instance early stop** — ``restart_tournament`` and
  ``SortServer`` drop finished instances from subsequent dispatches;
  because every instance owns an independent PRNG stream (split per
  round from its own key), stopping one never perturbs another — the
  survivors stay bit-identical to an uninterrupted run.

Determinism contract: every decision here is a pure, elementwise
function of ONE instance's observations (its loss trace, its keys), in
host-side float32 — there are no batch-global reductions.  Any engine
that feeds a given instance the same per-round losses therefore makes
the same decisions for it, which is what keeps adaptive runs
bit-identical per seed across the sequential / vmap / shard_map /
tournament / kernel paths (asserted in tests/test_annealing.py and the
hypothesis suite in tests/test_properties.py).

Decision quantum: the controller observes only at rung boundaries,
every ``seg_len`` rounds, with ``seg_len`` dividing ``rounds`` and all
schedule jumps being multiples of ``seg_len`` — so every live
instance's remaining schedule is always a positive multiple of
``seg_len`` and every dispatch advances its whole group by exactly one
rung (no partial segments, no shape churn in the compile cache).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.softsort import band_tail_bound


def adaptive_seg_len(cfg) -> int:
    """The adaptive controller's decision quantum, in rounds.

    ``cfg.adapt_every`` if set (must divide ``cfg.rounds``); otherwise
    the largest divisor of ``rounds`` not exceeding ``rounds // 8`` —
    about 8 decision points across the schedule, and always a divisor
    so rung dispatches are uniform (see module docstring).
    """
    rounds = int(cfg.rounds)
    if cfg.adapt_every:
        seg = int(cfg.adapt_every)
        if not 1 <= seg <= rounds or rounds % seg:
            raise ValueError(
                f"adapt_every={cfg.adapt_every} must divide "
                f"cfg.rounds={rounds} (uniform decision quantum)")
        return seg
    target = max(1, rounds // 8)
    return max(d for d in range(1, target + 1) if rounds % d == 0)


@dataclasses.dataclass(frozen=True)
class RungDecision:
    """What the controller decided at one rung boundary (host record,
    exposed to ``SortServer`` counters and the benchmark tables)."""
    step: int                  # 1-based rung index
    boundary: int              # executed rounds at this boundary
    n_live: int                # instances that ran this rung
    fired: int                 # instances whose plateau fired (tau jump)
    stopped: int               # instances that left the anneal here
    switched: int              # instances that went dense->banded here


class AdaptiveController:
    """Plateau-driven schedule controller over BS flattened instances.

    Construct via ``core.shufflesoftsort.make_adaptive_controller``
    (which supplies the tau schedule and resolved band half-width from
    a config) unless you are wiring a custom schedule.

    State is per-instance numpy (host-side): ``pos`` — the instance's
    next position in the nominal tau schedule (jumps move it forward),
    ``executed`` — rounds actually run, ``done`` / ``culled`` — out of
    the anneal (converged / tournament-culled), ``banded`` — apply
    regime, plus the EWMA plateau bookkeeping.  ``observe`` is the only
    mutator the engines call; a tournament additionally calls
    ``mark_culled`` from its boundary hook.
    """

    def __init__(self, cfg, n_instances: int, *, taus, band: int | None,
                 seg_len: int):
        rounds = int(cfg.rounds)
        self.cfg = cfg
        self.taus = np.asarray(taus, np.float32)
        assert self.taus.shape == (rounds,), (self.taus.shape, rounds)
        self.band = band
        self.seg_len = int(seg_len)
        if not 1 <= self.seg_len <= rounds or rounds % self.seg_len:
            raise ValueError(
                f"seg_len={seg_len} must divide cfg.rounds={rounds}")
        self.rounds = rounds
        self.patience = int(cfg.patience)
        self.plateau_rtol = np.float32(cfg.plateau_rtol)
        self.alpha = np.float32(cfg.ewma_alpha)
        self.jump = self.seg_len * max(1, int(cfg.decay_rungs))
        if self.patience < 1:
            raise ValueError(f"patience={cfg.patience} must be >= 1")
        if not 0.0 < cfg.ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha={cfg.ewma_alpha} not in (0, 1]")

        bs = int(n_instances)
        self.pos = np.zeros(bs, np.int64)        # next schedule round
        self.executed = np.zeros(bs, np.int64)   # rounds actually run
        self.done = np.zeros(bs, bool)
        self.culled = np.zeros(bs, bool)
        self.banded = np.zeros(bs, bool)
        self.ewma = np.zeros(bs, np.float32)
        self.best = np.full(bs, np.inf, np.float32)
        self.plateau = np.zeros(bs, np.int64)
        self.fired = np.zeros(bs, np.int64)      # tau jumps taken
        self.decisions: list[RungDecision] = []

    # ---- checkpointing ---------------------------------------------------

    # Every mutable per-instance array `observe` / `mark_culled` touch.
    # `decisions` (the host audit log) is deliberately not state: it
    # feeds counters and tables, never a decision, so a resumed run's
    # log simply restarts at the resume rung.
    _STATE_FIELDS = ("pos", "executed", "done", "culled", "banded",
                     "ewma", "best", "plateau", "fired")

    def state_dict(self) -> dict[str, np.ndarray]:
        """Copy of the full decision state, keyed by field name — what
        ``runtime.anneal_checkpoint.AnnealCheckpointer`` persists at
        every committed rung (EXPERIMENTS.md §Robustness)."""
        return {f: getattr(self, f).copy() for f in self._STATE_FIELDS}

    def load_state_dict(self, state: dict) -> None:
        """Restore ``state_dict`` output (or its numpy round-trip).
        Arrays are cast back to the constructor dtypes so decisions
        after a resume are bitwise the ones an uninterrupted run makes.
        """
        for f in self._STATE_FIELDS:
            cur = getattr(self, f)
            new = np.asarray(state[f], dtype=cur.dtype)
            if new.shape != cur.shape:
                raise ValueError(
                    f"controller state {f!r} has shape {new.shape}, "
                    f"expected {cur.shape} (wrong instance count?)")
            setattr(self, f, new.copy())

    # ---- engine-facing queries ------------------------------------------

    def live_indices(self) -> np.ndarray:
        """Instances that should run the next rung."""
        return np.flatnonzero(~self.done & ~self.culled)

    def tau_rows(self, idx: np.ndarray) -> np.ndarray:
        """(seg_len, k) float32 — each selected instance's OWN slice of
        the nominal schedule starting at its current position (the
        layout ``_run_rounds_ragged*`` consumes)."""
        idx = np.asarray(idx)
        steps = self.pos[idx][:, None] + np.arange(self.seg_len)
        assert (steps < self.rounds).all(), "live instance past schedule end"
        return self.taus[steps].T.astype(np.float32)

    def rounds_saved(self) -> int:
        """Schedule rounds NOT executed across all instances (early
        stops, jumps, and culls all count — this is the compute the
        fixed engine would have spent)."""
        return int((self.rounds - self.executed).sum())

    # ---- mutators --------------------------------------------------------

    def mark_culled(self, idx) -> None:
        self.culled[np.asarray(idx)] = True

    def observe(self, idx: np.ndarray, losses: np.ndarray,
                ws: np.ndarray | None = None) -> RungDecision:
        """Commit one rung's observations for instances ``idx``.

        Args:
          idx: (k,) instance rows that just ran ``seg_len`` rounds.
          losses: (k, seg_len) float32 per-round losses, round-major
            per row.
          ws: optional (k, N) float32 end-of-rung soft-sort keys (the
            final round's trained ``w``), consulted for the measured
            dense->banded switch when a band is configured.

        All arithmetic is elementwise float32 per instance — see the
        module docstring's determinism contract.
        """
        idx = np.asarray(idx)
        losses = np.asarray(losses, np.float32)
        assert losses.shape == (idx.size, self.seg_len), (
            losses.shape, idx.size, self.seg_len)
        assert not (self.done[idx] | self.culled[idx]).any(), \
            "observed a rung for a stopped instance"

        # EWMA over the rung's rounds (first-ever round initializes).
        e = self.ewma[idx]
        seeded = self.executed[idx] > 0
        for t in range(self.seg_len):
            lt = losses[:, t]
            e = np.where(seeded, self.alpha * lt + (1 - self.alpha) * e, lt)
            seeded = np.ones_like(seeded)
        e = e.astype(np.float32)
        self.ewma[idx] = e
        self.executed[idx] += self.seg_len
        self.pos[idx] += self.seg_len

        # Relative improvement of the EWMA vs the best EWMA seen at any
        # prior boundary; first boundary never counts as a plateau.
        best = self.best[idx]
        finite = np.isfinite(best)
        with np.errstate(invalid="ignore", divide="ignore"):
            imp = (best - e) / np.maximum(np.abs(best), np.float32(1e-12))
        imp = np.where(finite, imp, np.float32(np.inf)).astype(np.float32)
        plat = np.where(imp < self.plateau_rtol, self.plateau[idx] + 1, 0)
        self.best[idx] = np.minimum(best, e)

        fire = plat >= self.patience
        plat[fire] = 0
        self.plateau[idx] = plat
        self.fired[idx] += fire
        pos = self.pos[idx]
        pos = np.where(fire, np.minimum(pos + self.jump, self.rounds), pos)
        self.pos[idx] = pos
        stopped = pos >= self.rounds
        self.done[idx] = stopped

        # Measured band switch: a still-dense, still-live instance goes
        # banded once the tail bound ON ITS OWN KEYS at its next-round
        # temperature clears band_eps (one-way switch).
        n_switched = 0
        if self.band is not None and ws is not None:
            sel = np.flatnonzero(~self.banded[idx] & ~stopped)
            if sel.size:
                rows = idx[sel]
                tau_next = self.taus[np.minimum(self.pos[rows],
                                                self.rounds - 1)]
                bound = np.asarray(band_tail_bound(
                    np.asarray(ws, np.float32)[sel], tau_next, self.band))
                flip = bound <= np.float32(self.cfg.band_eps)
                self.banded[rows] = flip
                n_switched = int(flip.sum())

        decision = RungDecision(
            step=len(self.decisions) + 1,
            boundary=int(self.executed[idx][0]) if idx.size else 0,
            n_live=int(idx.size),
            fired=int(fire.sum()),
            stopped=int(stopped.sum()),
            switched=n_switched,
        )
        self.decisions.append(decision)
        return decision
