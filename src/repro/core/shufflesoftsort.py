"""ShuffleSoftSort — Algorithm 1 of the paper.

Learns a permutation of N items with only N parameters by iterating:

  for r in 1..R:                      (outer: anneal tau, re-shuffle)
      tau_r = tau_start * (tau_end / tau_start) ** (r / R)
      w     = arange(N)               (linear init preserves incoming order)
      shuf  = randperm(N)
      for i in 1..I:                  (inner: a few SoftSort grad steps)
          tau_i = tau_r * (0.2 .. 1.0 ramp)
          P     = SoftSort_tau_i(w)           (streamed, never N^2)
          y     = unshuffle(P @ x[order][shuf])
          loss  = L_nbr(y) + l_s * L_s + l_sig * L_sigma      (eq. 2)
          w    <- Adam step
      order <- commit argsort(w) through the shuffle

The random shuffle re-linearizes the grid along a fresh path each outer
iteration, so elements can take long-range jumps that pure 1-D SoftSort
transport cannot (paper Fig. 3/4).  The whole outer body is one jitted
function; the R-loop stays in Python so callers can stream metrics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.losses import grid_sorting_loss, mean_pairwise_distance
from repro.core.softsort import softsort_apply_chunked


@dataclasses.dataclass(frozen=True)
class ShuffleSoftSortConfig:
    rounds: int = 1000          # R — outer iterations (paper: "few hundred")
    inner_steps: int = 8        # I — SoftSort grad steps per round (paper: 4)
    tau_start: float = 1.0
    tau_end: float = 0.2        # below ~0.2 the SoftSort gradient vanishes
    inner_tau_ramp: float = 0.2  # inner tau starts at ramp*tau_r
    lr: float = 0.3             # calibrated: see EXPERIMENTS.md §Paper-claims
    b1: float = 0.5             # short inner runs want fast-adapting Adam
    b2: float = 0.9
    lambda_s: float = 1.0       # eq. 2 regularizer weights (paper values)
    lambda_sigma: float = 2.0
    chunk: int = 256            # row-block size for streamed softsort
    use_kernel: bool = False    # route the apply through the Pallas kernel


def _loss_fn(w, x_shuf, inv_shuf, tau, hw, norm, cfg: ShuffleSoftSortConfig,
             apply_fn) -> jnp.ndarray:
    y_shuf, colsum = apply_fn(w, x_shuf, tau)
    y = y_shuf[inv_shuf]  # reverse-shuffle: loss sees the grid layout
    return grid_sorting_loss(
        y, colsum, x_shuf, hw, norm,
        lambda_s=cfg.lambda_s, lambda_sigma=cfg.lambda_sigma)


@functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)
def _outer_round(x, order, key, tau_r, norm, *, hw, cfg: ShuffleSoftSortConfig,
                 apply_fn):
    n = x.shape[0]
    shuf = jax.random.permutation(key, n)
    inv_shuf = jnp.argsort(shuf)
    x_cur = x[order]
    x_shuf = x_cur[shuf]

    w0 = jnp.arange(n, dtype=jnp.float32)
    grad_fn = jax.value_and_grad(_loss_fn)

    def inner(i, carry):
        w, mu, nu, _ = carry
        frac = i.astype(jnp.float32) / jnp.maximum(cfg.inner_steps - 1, 1)
        tau_i = tau_r * (cfg.inner_tau_ramp + (1.0 - cfg.inner_tau_ramp) * frac)
        loss, g = grad_fn(w, x_shuf, inv_shuf, tau_i, hw, norm, cfg, apply_fn)
        t = i.astype(jnp.float32) + 1.0
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / (1 - cfg.b1 ** t)
        nuhat = nu / (1 - cfg.b2 ** t)
        w = w - cfg.lr * mhat / (jnp.sqrt(nuhat) + 1e-8)
        return (w, mu, nu, loss)

    w, _, _, loss = jax.lax.fori_loop(
        0, cfg.inner_steps, inner,
        (w0, jnp.zeros_like(w0), jnp.zeros_like(w0), jnp.float32(0.0)))

    # Commit the hard permutation through the shuffle:
    #   new_grid[shuf[i]] = x_shuf[sort_idx[i]] = x_cur[shuf[sort_idx[i]]]
    sort_idx = jnp.argsort(w)          # == argmax(P_soft, -1) with repaired ties
    g = jnp.zeros(n, dtype=jnp.int32).at[shuf].set(shuf[sort_idx])
    return order[g], loss


def shuffle_soft_sort(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    key: jax.Array | None = None,
    callback: Optional[Callable[[int, np.ndarray, float], None]] = None,
) -> tuple[np.ndarray, np.ndarray, list[float]]:
    """Sort x (N, d) onto an (h, w) grid.  Returns (order, x[order], losses).

    ``order`` is the permutation (N int32) mapping grid cell -> input row;
    only these N indices — plus the N learnable weights inside each round
    — are ever stored, which is the paper's headline claim.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n = x.shape[0]
    assert n == hw[0] * hw[1], (n, hw)
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))

    if cfg.use_kernel:
        from repro.kernels.ops import softsort_apply as apply_fn
    else:
        apply_fn = functools.partial(softsort_apply_chunked, chunk=cfg.chunk)

    order = jnp.arange(n, dtype=jnp.int32)
    losses: list[float] = []
    for r in range(cfg.rounds):
        tau_r = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** ((r + 1) / cfg.rounds)
        key, sub = jax.random.split(key)
        order, loss = _outer_round(
            x, order, sub, jnp.float32(tau_r), norm,
            hw=hw, cfg=cfg, apply_fn=apply_fn)
        losses.append(float(loss))
        if callback is not None:
            callback(r, np.asarray(order), losses[-1])
    order = np.asarray(order)
    return order, np.asarray(x)[order], losses


# --------------------------------------------------------------------------
# Plain SoftSort baseline (paper Table III row 3): one weight vector trained
# end-to-end with the same loss and tau annealing, no shuffling.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("hw", "cfg", "apply_fn", "steps"))
def _softsort_train(x, norm, *, hw, cfg: ShuffleSoftSortConfig, apply_fn,
                    steps: int):
    n = x.shape[0]
    w0 = jnp.arange(n, dtype=jnp.float32)
    ident = jnp.arange(n, dtype=jnp.int32)
    grad_fn = jax.value_and_grad(_loss_fn)

    def body(i, carry):
        w, mu, nu, _ = carry
        frac = i.astype(jnp.float32) / steps
        tau = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** frac
        loss, g = grad_fn(w, x, ident, tau, hw, norm, cfg, apply_fn)
        t = i.astype(jnp.float32) + 1.0
        mu = 0.9 * mu + 0.1 * g
        nu = 0.999 * nu + 0.001 * jnp.square(g)
        mhat = mu / (1 - 0.9 ** t)
        nuhat = nu / (1 - 0.999 ** t)
        w = w - cfg.lr * mhat / (jnp.sqrt(nuhat) + 1e-8)
        return (w, mu, nu, loss)

    w, _, _, loss = jax.lax.fori_loop(
        0, steps, body, (w0, jnp.zeros_like(w0), jnp.zeros_like(w0),
                         jnp.float32(0.0)))
    return jnp.argsort(w), loss


def soft_sort_baseline(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    steps: int | None = None,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Pure SoftSort with the same budget (R*I steps by default)."""
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    if cfg.use_kernel:
        from repro.kernels.ops import softsort_apply as apply_fn
    else:
        apply_fn = functools.partial(softsort_apply_chunked, chunk=cfg.chunk)
    steps = steps or cfg.rounds * cfg.inner_steps
    order, loss = _softsort_train(x, norm, hw=hw, cfg=cfg, apply_fn=apply_fn,
                                  steps=steps)
    order = np.asarray(order)
    return order, np.asarray(x)[order], float(loss)
