"""ShuffleSoftSort — Algorithm 1 of the paper.

Learns a permutation of N items with only N parameters by iterating:

  for r in 1..R:                      (outer: anneal tau, re-shuffle)
      tau_r = tau_start * (tau_end / tau_start) ** (r / R)
      w     = arange(N)               (linear init preserves incoming order)
      shuf  = randperm(N)
      for i in 1..I:                  (inner: a few SoftSort grad steps)
          tau_i = tau_r * (0.2 .. 1.0 ramp)
          P     = SoftSort_tau_i(w)           (streamed, never N^2)
          y     = unshuffle(P @ x[order][shuf])
          loss  = L_nbr(y) + l_s * L_s + l_sig * L_sigma      (eq. 2)
          w    <- Adam step
      order <- commit argsort(w) through the shuffle

The random shuffle re-linearizes the grid along a fresh path each outer
iteration, so elements can take long-range jumps that pure 1-D SoftSort
transport cannot (paper Fig. 3/4).  The whole outer body is one jitted
function; in the sequential API the R-loop stays in Python so callers
can stream metrics.

Because one instance costs only N parameters (vs Gumbel-Sinkhorn's N^2),
many instances fit on a device at once.  ``shuffle_soft_sort_batched``
exploits that: it vmaps the outer round over B problems x S restarts
(each with its own PRNG stream, shuffle, and Adam state), runs the whole
annealing schedule as one scanned device program when no streaming
callback is requested, and keeps each problem's best-loss restart.
Per-seed results are bit-identical to the sequential API.

Above one device, the same engine shards: pass a 1-D "data" mesh
(``repro.launch.mesh.make_sort_mesh``) and the flattened B x S instance
axis is shard_mapped across devices — same per-instance program, tail
shard padded, winner picked by a cross-device argmin — still per-seed
bit-identical.  ``restart_tournament`` layers successive halving on
top: anneal in rungs, cull the worst restarts at each boundary, spend
the freed compute finishing only plausible seeds.  Scaling and
cull-tradeoff measurements: EXPERIMENTS.md §Scaling.

``run_round_segment`` exposes the same engines to continuous-batching
servers (``repro.launch.serve.SortServer``): one scanned device call
advances BS instances by ``seg_len`` rounds where each instance
consumes its OWN slice of the tau schedule, so requests join and leave
the annealing loop at round boundaries — the tournament's rung
structure as a preemption point — without cohort barriers, and chained
``orders``/``keys`` keep every instance bit-identical to an
uninterrupted run.

Orthogonally, ``cfg.band`` swaps the O(N^2) SoftSort apply for the
O(N * K) banded tier once the anneal is cold enough: the schedule
splits at a single dense->banded switch round (``_band_switch_round``,
a host-side model of the tail bound on the trainer's linear re-init),
so every engine — sequential, vmap, shard_map, tournament — runs the
identical per-round apply and the bit-identity contracts above carry
over unchanged.  Banded model + measured tradeoff: EXPERIMENTS.md
§Perf.

Return contract, shared by every driver here: ``order`` is the (N,)
int32 permutation mapping grid cell -> input row, ``sorted`` is
``x[order]``, and ``losses`` is the per-round loss trace (leading batch
axes in the batched API).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp
try:                                      # 0.4.x home (what we validate on)
    from jax.experimental.shard_map import shard_map
except ImportError:                       # pragma: no cover - jax >= 0.7
    from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.losses import grid_sorting_loss, mean_pairwise_distance
from repro.core.softsort import softsort_apply_banded, softsort_apply_chunked


@dataclasses.dataclass(frozen=True)
class ShuffleSoftSortConfig:
    rounds: int = 1000          # R — outer iterations (paper: "few hundred")
    inner_steps: int = 8        # I — SoftSort grad steps per round (paper: 4)
    tau_start: float = 1.0
    tau_end: float = 0.2        # below ~0.2 the SoftSort gradient vanishes
    inner_tau_ramp: float = 0.2  # inner tau starts at ramp*tau_r
    lr: float = 0.3             # calibrated: see EXPERIMENTS.md §Paper-claims
    b1: float = 0.5             # short inner runs want fast-adapting Adam
    b2: float = 0.9
    lambda_s: float = 1.0       # eq. 2 regularizer weights (paper values)
    lambda_sigma: float = 2.0
    chunk: int = 256            # row-block size for streamed softsort
    use_kernel: bool = False    # route the apply through the Pallas kernel
    # Banded apply tier (EXPERIMENTS.md §Perf): None = always dense;
    # an int K or "auto" enables the O(N*K) banded apply once the anneal
    # is cold enough that its modeled tail bound drops below band_eps —
    # early hot-tau rounds still run dense (see _band_switch_round).
    band: int | str | None = None
    band_eps: float = 1e-6      # tail-mass threshold for the tau switch
    # Kernel-tier compute precision ("float32" or "bfloat16"), honoured
    # only with use_kernel=True: bf16 halves the kernels' payload HBM
    # traffic and runs the score/payload math in bf16 while the keys
    # (the N parameters), softmax stats, accumulators, and this file's
    # Adam math all stay f32 (EXPERIMENTS.md §Perf precision table).
    compute_dtype: str = "float32"
    # Adaptive annealing (EXPERIMENTS.md §Adaptive).  "fixed" runs the
    # precomputed R-round schedule to the end — byte-for-byte the
    # behavior before the adaptive tier existed.  "adaptive" runs the
    # SAME nominal schedule under core.annealing.AdaptiveController:
    # when an instance's per-round loss EWMA improves by less than
    # plateau_rtol (relative) for patience consecutive rungs, it jumps
    # decay_rungs rungs ahead in the schedule (colder tau early; a jump
    # past the end stops the instance at that boundary), and the
    # dense->banded switch comes from the MEASURED band_tail_bound on
    # the instance's own keys instead of the linear-init model.  All
    # decisions are per-instance and host-side, so adaptive runs stay
    # bit-identical per seed across every engine path.
    schedule: str = "fixed"     # "fixed" | "adaptive"
    adapt_every: int = 0        # decision quantum in rounds (0 = auto:
                                # largest divisor of rounds <= rounds/8)
    patience: int = 2           # plateau rungs before a tau jump
    plateau_rtol: float = 1e-3  # relative EWMA improvement threshold
    ewma_alpha: float = 0.5     # per-round loss EWMA smoothing
    decay_rungs: int = 1        # rungs skipped per plateau fire


class NumericalDivergence(RuntimeError):
    """A rung-boundary sentinel saw a non-finite loss (or trained key).

    SoftSort's ``exp(-|w - sorted(w)| / tau)`` relaxation under/overflows
    exactly where long anneals spend most of their time — cold tau,
    reduced precision — and a NaN that enters the loss silently poisons
    the Adam moments and every later round.  The engines therefore check
    the (host-side, already-materialized) per-round losses at each rung
    boundary and raise this typed error with enough context to act on:
    ``round`` (first non-finite global round), ``tau`` (the nominal
    schedule temperature there), ``dtype`` (``cfg.compute_dtype``), and
    ``context`` (which engine tripped).  ``runtime.fault_tolerance
    .AnnealSupervisor`` catches it and — under an opt-in
    ``DivergencePolicy`` — retries from the last rung checkpoint with
    escalating fallbacks (EXPERIMENTS.md §Robustness).
    """

    def __init__(self, message: str, *, round: int | None = None,
                 tau: float | None = None, dtype: str | None = None,
                 context: str | None = None):
        super().__init__(message)
        self.round = round
        self.tau = tau
        self.dtype = dtype
        self.context = context


def _check_finite(losses_seg, start: int, cfg: "ShuffleSoftSortConfig",
                  context: str, ws=None) -> None:
    """Host-side divergence sentinel over one segment's losses.

    ``losses_seg`` is (T, ...) round-major, covering global rounds
    [start, start + T); ``ws`` optionally carries end-of-rung trained
    keys (the adaptive path has them on host anyway).  Raises
    ``NumericalDivergence`` pinpointing the first non-finite round.
    """
    losses_seg = np.asarray(losses_seg)
    bad = ~np.isfinite(losses_seg)
    if bad.any():
        per_round = bad.reshape(losses_seg.shape[0], -1).any(axis=1)
        t = int(np.argmax(per_round))
        rnd = start + t
        taus = _tau_schedule(cfg)
        tau = float(taus[min(rnd, cfg.rounds - 1)])
        raise NumericalDivergence(
            f"non-finite loss at round {rnd} (tau~{tau:.4g}, "
            f"compute_dtype={cfg.compute_dtype}, engine={context})",
            round=rnd, tau=tau, dtype=cfg.compute_dtype, context=context)
    if ws is not None and not np.isfinite(np.asarray(ws)).all():
        rnd = start + losses_seg.shape[0] - 1
        taus = _tau_schedule(cfg)
        tau = float(taus[min(rnd, cfg.rounds - 1)])
        raise NumericalDivergence(
            f"non-finite trained keys at round {rnd} (tau~{tau:.4g}, "
            f"compute_dtype={cfg.compute_dtype}, engine={context})",
            round=rnd, tau=tau, dtype=cfg.compute_dtype, context=context)


def _loss_fn(w, x_shuf, inv_shuf, tau, hw, norm, cfg: ShuffleSoftSortConfig,
             apply_fn) -> jnp.ndarray:
    y_shuf, colsum = apply_fn(w, x_shuf, tau)
    y = y_shuf[inv_shuf]  # reverse-shuffle: loss sees the grid layout
    return grid_sorting_loss(
        y, colsum, x_shuf, hw, norm,
        lambda_s=cfg.lambda_s, lambda_sigma=cfg.lambda_sigma)


def _outer_round_full(x, order, key, tau_r, norm, *, hw,
                      cfg: ShuffleSoftSortConfig, apply_fn):
    """``_outer_round_impl`` plus the round's final trained keys ``w``.

    The adaptive controller's measured dense->banded switch needs the
    end-of-round ``w`` to evaluate the true tail bound; the fixed
    engines wrap this and drop ``w`` (same trace — the extra output was
    always computed as the fori_loop carry), so exposing it does not
    perturb the fixed path.
    """
    n = x.shape[0]
    shuf = jax.random.permutation(key, n)
    inv_shuf = jnp.argsort(shuf)
    x_cur = x[order]
    x_shuf = x_cur[shuf]

    w0 = jnp.arange(n, dtype=jnp.float32)
    grad_fn = jax.value_and_grad(_loss_fn)

    def inner(i, carry):
        w, mu, nu, _ = carry
        frac = i.astype(jnp.float32) / jnp.maximum(cfg.inner_steps - 1, 1)
        tau_i = tau_r * (cfg.inner_tau_ramp + (1.0 - cfg.inner_tau_ramp) * frac)
        loss, g = grad_fn(w, x_shuf, inv_shuf, tau_i, hw, norm, cfg, apply_fn)
        t = i.astype(jnp.float32) + 1.0
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / (1 - cfg.b1 ** t)
        nuhat = nu / (1 - cfg.b2 ** t)
        w = w - cfg.lr * mhat / (jnp.sqrt(nuhat) + 1e-8)
        return (w, mu, nu, loss)

    # unroll=True: inner_steps is small and static, and an XLA while
    # loop here miscompiles under shard_map on this jax build —
    # non-zero shards silently compute different values (bit-identity
    # breaker found while validating the mesh engine; the unrolled body
    # is bit-exact on every shard).  Unrolling also fuses the few inner
    # steps into one block, which is what the short inner loop wants
    # anyway.
    w, _, _, loss = jax.lax.fori_loop(
        0, cfg.inner_steps, inner,
        (w0, jnp.zeros_like(w0), jnp.zeros_like(w0), jnp.float32(0.0)),
        unroll=True)

    # Commit the hard permutation through the shuffle:
    #   new_grid[shuf[i]] = x_shuf[sort_idx[i]] = x_cur[shuf[sort_idx[i]]]
    sort_idx = jnp.argsort(w)          # == argmax(P_soft, -1) with repaired ties
    g = jnp.zeros(n, dtype=jnp.int32).at[shuf].set(shuf[sort_idx])
    return order[g], loss, w


def _outer_round_impl(x, order, key, tau_r, norm, *, hw,
                      cfg: ShuffleSoftSortConfig, apply_fn):
    """One un-jitted outer round for a single problem instance.

    This is the unit the batched engine vmaps: every array argument is
    per-instance ((N, d) / (N,) / PRNG key), so ``jax.vmap`` over a
    leading batch axis gives B independent rounds — each with its own
    shuffle, PRNG stream, and (implicitly, via the inner fori_loop
    carry) its own Adam state.
    """
    order, loss, _ = _outer_round_full(x, order, key, tau_r, norm,
                                       hw=hw, cfg=cfg, apply_fn=apply_fn)
    return order, loss


_outer_round = functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)(_outer_round_impl)


@functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)
def _outer_round_batched(xs, orders, keys, tau_r, norms, *, hw,
                         cfg: ShuffleSoftSortConfig, apply_fn):
    """Vmapped outer round over a leading batch axis.

    Args:
      xs:     (BS, N, d) problem instances (restarts are tiled copies).
      orders: (BS, N) int32 current permutations.
      keys:   (BS, 2) uint32 per-instance PRNG keys for this round.
      tau_r:  scalar round temperature, shared across the batch.
      norms:  (BS,) per-instance loss normalization constants.

    Returns:
      (orders, losses): (BS, N) int32 and (BS,) float32.
    """
    def one(x, order, key, norm):
        return _outer_round_impl(x, order, key, tau_r, norm,
                                 hw=hw, cfg=cfg, apply_fn=apply_fn)

    return jax.vmap(one)(xs, orders, keys, norms)


def _run_rounds_impl(xs, orders, keys, taus, norms, *, hw,
                     cfg: ShuffleSoftSortConfig, apply_fn):
    """Whole-schedule batched run: lax.scan over the R outer rounds.

    One device program instead of R dispatches — the throughput path the
    batched benchmark measures.  Numerically identical to calling
    ``_outer_round_batched`` once per round (the scan body is the same
    vmapped round, consuming the same per-instance key splits), so
    results stay bit-identical to the sequential API per seed.

    Un-jitted on purpose: this is both the body ``_run_rounds_batched``
    jits for the single-device vmap engine AND the per-shard program
    ``_run_rounds_sharded`` maps over the mesh "data" axis — the two
    paths literally run the same code per instance, which is what makes
    the sharded engine's bit-identity contract hold.

    Args:
      taus: (R,) float32 precomputed outer-round temperature schedule
        (any contiguous slice of the full schedule works — the
        tournament scheduler runs the anneal rung by rung).

    Returns:
      (orders (BS, N), keys (BS, 2), losses (R, BS)).
    """
    def step(carry, tau_r):
        orders, keys = carry
        pair = jax.vmap(jax.random.split)(keys)
        keys, subs = pair[:, 0], pair[:, 1]

        def one(x, order, key, norm):
            return _outer_round_impl(x, order, key, tau_r, norm,
                                     hw=hw, cfg=cfg, apply_fn=apply_fn)

        orders, losses = jax.vmap(one)(xs, orders, subs, norms)
        return (orders, keys), losses

    (orders, keys), losses = jax.lax.scan(step, (orders, keys), taus)
    return orders, keys, losses


_run_rounds_batched = functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)(_run_rounds_impl)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "hw", "cfg", "apply_fn"),
)
def _run_rounds_sharded(xs, orders, keys, taus, norms, *, mesh, hw,
                        cfg: ShuffleSoftSortConfig, apply_fn):
    """Mesh-sharded whole-schedule run: ``_run_rounds_impl`` shard_mapped
    over the mesh's "data" axis.

    The flattened B x S instance axis is split across devices; each
    shard runs the identical scanned program on its slice (instances
    are embarrassingly parallel — no collectives until best-restart
    selection), so per-seed results are bit-identical to the vmap
    engine.  Callers pad the leading axis to a multiple of the mesh
    size first (``_pad_instances``).  Measured scaling lives in
    EXPERIMENTS.md §Scaling.
    """
    body = functools.partial(_run_rounds_impl, hw=hw, cfg=cfg,
                             apply_fn=apply_fn)
    # check_rep=False: the body is purely per-shard (no collectives), and
    # jax 0.4.x's replication checker both rejects some nested-pjit
    # bodies (TypeError in _check_rep) and — worse — its rewrite pass
    # silently perturbs values computed on non-zero shards, breaking
    # the bit-identity contract.  Verified identical with the vmap
    # engine per seed on 1/2/3/6/8 forced-host devices.
    return shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P(), P("data")),
        out_specs=(P("data"), P("data"), P(None, "data")),
        check_rep=False,
    )(xs, orders, keys, taus, norms)


@functools.partial(jax.jit, static_argnames=("b", "s"))
def _best_restart_device(orders, losses_rb, *, b, s):
    """Cross-device argmin over the restart axis.

    One jitted program over the still-sharded engine outputs; XLA
    inserts the gather/reduce collectives, so winner selection is a
    mesh-native step rather than host post-processing.  (The batched
    result contract also reports every restart, so the host gathers
    the full arrays regardless — this function exists to keep the
    selection itself on-device, and the tests assert it agrees with
    the host-side argmin exactly.)

    Returns (best (B,) int32, best_orders (B, N) int32).
    """
    final = losses_rb[-1, :b * s].reshape(b, s)
    best = jnp.argmin(final, axis=1)
    rows = jnp.arange(b) * s + best
    return best, orders[rows]


def _pad_instances(arrs, to: int):
    """Pad each array's leading instance axis to ``to`` rows by repeating
    instance 0 — valid (discarded) work, so uneven B x S grids shard
    over any mesh size."""
    out = []
    for a in arrs:
        p = to - a.shape[0]
        out.append(a if p == 0 else
                   jnp.concatenate([a, jnp.repeat(a[:1], p, axis=0)], axis=0))
    return out


def _engine_run(xs_t, orders, keys, taus, norms_t, *, hw, cfg, apply_fn,
                mesh):
    """Run one contiguous slice of the anneal on BS flattened instances,
    dispatching to the vmap engine (``mesh=None``) or the shard_map
    engine (padding/unpadding the instance axis to the mesh size).

    Returns (orders (BS, N), keys (BS, 2), losses (R_slice, BS)) — the
    sharded outputs stay device-resident jax Arrays sharded over "data".
    """
    taus = jnp.asarray(taus)
    if mesh is None:
        return _run_rounds_batched(xs_t, orders, keys, taus, norms_t,
                                   hw=hw, cfg=cfg, apply_fn=apply_fn)
    d_mesh = mesh.shape["data"]
    bs = xs_t.shape[0]
    pad = (-bs) % d_mesh
    if pad:
        xs_t, orders, keys, norms_t = _pad_instances(
            (xs_t, orders, keys, norms_t), bs + pad)
    orders, keys, losses = _run_rounds_sharded(
        xs_t, orders, keys, taus, norms_t,
        mesh=mesh, hw=hw, cfg=cfg, apply_fn=apply_fn)
    if pad:
        orders, keys, losses = orders[:bs], keys[:bs], losses[:, :bs]
    return orders, keys, losses


def _run_segments(xs_t, orders, keys, taus, norms_t, *, start: int,
                  switch: int, hw, cfg: ShuffleSoftSortConfig,
                  dense_fn, band_fn, mesh):
    """Run a contiguous slice of the anneal, splitting it at the
    dense->banded switch round.

    ``taus`` is the slice covering global rounds [start, start +
    len(taus)); ``switch`` is the GLOBAL round index from
    ``_band_switch_round`` (so the tournament's per-rung slices land on
    the same per-round apply the uninterrupted engines use — the
    bit-identity contract needs every engine to agree round-by-round on
    which apply ran).  At most two ``_engine_run`` calls: the dense
    prefix and the banded suffix; keys/orders chain through, so the PRNG
    streams are exactly those of a single unsegmented run.

    Returns (orders (BS, N), keys (BS, 2), losses (R_slice, BS)).
    """
    end = start + len(taus)
    cut = min(max(switch, start), end)
    parts = []
    for s0, s1, fn in ((start, cut, dense_fn), (cut, end, band_fn)):
        if s1 > s0:
            orders, keys, seg = _engine_run(
                xs_t, orders, keys, taus[s0 - start:s1 - start], norms_t,
                hw=hw, cfg=cfg, apply_fn=fn, mesh=mesh)
            parts.append(seg)
    losses = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return orders, keys, losses


# --------------------------------------------------------------------------
# Rung-boundary checkpointing (EXPERIMENTS.md §Robustness).
# --------------------------------------------------------------------------

def _open_checkpointer(checkpoint_dir, resume):
    """Resolve the ``checkpoint_dir=`` / ``resume=`` knobs to an
    ``AnnealCheckpointer`` (or None).  Imported lazily: core stays
    importable without the runtime package on the path."""
    if checkpoint_dir is None:
        if resume:
            raise ValueError("resume=True requires checkpoint_dir=")
        return None
    from repro.runtime.anneal_checkpoint import AnnealCheckpointer
    return AnnealCheckpointer(str(checkpoint_dir))


def _open_guardrails(guardrail, cfg: "ShuffleSoftSortConfig",
                     context: str):
    """Resolve the ``guardrail=`` knob to a ``GuardrailMonitor`` (or
    None).  Accepts a ``GuardrailPolicy`` (a fresh monitor is built for
    this run) or an existing monitor (callers that want to read
    incident history afterwards).  Imported lazily, like
    ``_open_checkpointer`` — core stays importable without the runtime
    package on the path."""
    if guardrail is None:
        return None
    from repro.runtime.guardrails import GuardrailMonitor, GuardrailPolicy
    if isinstance(guardrail, GuardrailMonitor):
        return guardrail if guardrail.active else None
    if not isinstance(guardrail, GuardrailPolicy):
        raise TypeError(
            "guardrail= must be a GuardrailPolicy or GuardrailMonitor, "
            f"got {guardrail!r}")
    if guardrail.mode == "off":
        return None
    return GuardrailMonitor(guardrail, context=context,
                            dtype=cfg.compute_dtype)


def _checkpoint_edges(rounds: int, every: int) -> list[int]:
    """Rung-boundary rounds at which the fixed engines checkpoint:
    every ``every`` rounds, with a final edge at ``rounds``."""
    every = max(1, int(every))
    edges = list(range(every, rounds, every))
    if not edges or edges[-1] != rounds:
        edges.append(rounds)
    return edges


def _engine_meta(kind: str, cfg: ShuffleSoftSortConfig, n: int, bs: int,
                 hw) -> dict:
    """Checkpoint meta record: the structural fingerprint a resume must
    match (everything but ``cfg``, which the divergence-degradation
    ladder is allowed to adjust mid-run) plus the full config repr for
    audit."""
    return {"engine": kind, "rounds": int(cfg.rounds), "n": int(n),
            "instances": int(bs), "hw": list(hw),
            "schedule": cfg.schedule, "cfg": repr(cfg)}


def _meta_expect(meta: dict) -> dict:
    return {k: v for k, v in meta.items() if k != "cfg"}


def _run_fixed_checkpointed(xs_t, orders, keys, taus, norms_t, *,
                            switch: int, hw,
                            cfg: ShuffleSoftSortConfig, dense_fn, band_fn,
                            mesh, ckpt, resume: bool, every: int,
                            rung_hook, meta: dict,
                            check_finite: bool = True,
                            band: int | None = None, monitor=None,
                            mesh_hook=None):
    """Fixed-schedule batched run in checkpointed rung segments.

    Chains ``_run_segments`` calls across the checkpoint edges — the
    PR 6 segment-chaining contract makes the chained run bit-identical
    to the single-dispatch fast path, so adding checkpoints never
    perturbs results.  After each segment the full cross-round carry
    (orders, chained keys, losses so far) is published atomically; on
    ``resume`` the run restarts from the newest checkpoint's round (a
    bare directory starts from scratch).  ``rung_hook(start_round)``
    fires before each segment — the chaos harness's kill point.

    ``mesh_hook(start_round, mesh) -> mesh | None`` fires right after
    ``rung_hook`` and may return a REPLACEMENT mesh to run the next
    segment on — the elastic re-shard point.  Because the carry is
    layout-free (``_engine_run`` re-pads per call), swapping the mesh at
    a rung boundary is purely a throughput change: results stay
    bit-identical per seed (tests/test_elastic.py).

    With a ``monitor`` (``runtime.guardrails.GuardrailMonitor``) the
    integrity probes run on each rung's synced state AFTER the finite
    sentinel and BEFORE ``ckpt.save`` — so the newest checkpoint is
    always the last *verified* rung, and a violation replays from
    there.  Shadow-sampled rungs snapshot the rung's input orders/keys
    to host first (the engines donate their input buffers) and re-run
    the segment through the pure-jnp oracle tier for comparison.

    Returns (orders (BS, N), keys (BS, 2), losses (R, BS) np.float32).
    """
    rounds = int(cfg.rounds)
    start = 0
    parts: list[np.ndarray] = []
    mon = monitor if (monitor is not None and monitor.active) else None
    if mon is not None:
        cfg_o = dataclasses.replace(cfg, use_kernel=False)
        dense_o = _select_apply_fn(cfg_o)
        band_o = dense_o if band is None else _select_apply_fn(cfg_o, band)
    if resume and ckpt is not None:
        got = ckpt.restore_latest(_meta_expect(meta))
        if got is not None:
            state, start, _ = got
            orders = jnp.asarray(state["orders"])
            keys = jnp.asarray(state["keys"])
            if start > 0:
                parts.append(np.asarray(state["losses"], np.float32))
            if start >= rounds:
                return orders, keys, parts[0]
    for end in _checkpoint_edges(rounds, every):
        if end <= start:
            continue
        if rung_hook is not None:
            rung_hook(start)
        if mesh_hook is not None:
            new_mesh = mesh_hook(start, mesh)
            if new_mesh is not None:
                mesh = new_mesh
                # The carry is committed to the old mesh's devices;
                # round-trip it through host numpy so the next dispatch
                # re-places (and re-pads) it onto the new mesh.
                orders = jnp.asarray(np.asarray(orders))
                keys = jnp.asarray(np.asarray(keys))
        k_in = o_in = None
        if mon is not None:
            k_in = np.asarray(keys)
            if mon.wants_shadow(start):
                o_in = np.asarray(orders)
        orders, keys, seg = _run_segments(
            xs_t, orders, keys, taus[start:end], norms_t, start=start,
            switch=switch, hw=hw, cfg=cfg, dense_fn=dense_fn,
            band_fn=band_fn, mesh=mesh)
        seg_np = np.asarray(seg, np.float32)
        if check_finite:
            _check_finite(seg_np, start, cfg, meta["engine"])
        if mon is not None:
            oracle_l = oracle_o = None
            if o_in is not None:
                o_sh, _, seg_sh = _run_segments(
                    xs_t, jnp.asarray(o_in), jnp.asarray(k_in),
                    taus[start:end], norms_t, start=start, switch=switch,
                    hw=hw, cfg=cfg_o, dense_fn=dense_o, band_fn=band_o,
                    mesh=mesh)
                oracle_l = np.asarray(seg_sh, np.float32)
                if mon.compare_orders():
                    oracle_o = np.asarray(o_sh)
            mon.check_rung(
                start=start, losses=seg_np, orders=np.asarray(orders),
                keys_in=k_in, keys_out=np.asarray(keys),
                seg_len=end - start, tau=float(taus[start]),
                oracle_losses=oracle_l, oracle_orders=oracle_o)
        parts.append(seg_np)
        if ckpt is not None:
            ckpt.save(end, {"orders": np.asarray(orders),
                            "keys": np.asarray(keys),
                            "losses": np.concatenate(parts, axis=0)},
                      meta=dict(meta, round=end))
        start = end
    losses = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
    return orders, keys, losses


def _run_rounds_ragged_impl(xs, orders, keys, tau_rows, norms, *, hw,
                            cfg: ShuffleSoftSortConfig, apply_fn):
    """Per-instance-temperature variant of ``_run_rounds_impl``.

    ``tau_rows`` is (T, BS): row t holds each instance's OWN outer-round
    temperature for the t-th round of this segment, so instances at
    DIFFERENT global positions in the anneal can share one scanned
    device program — the primitive continuous-batching servers need to
    let requests join and leave at round boundaries without waiting for
    a whole cohort to finish.  The scan body is the same vmapped
    ``_outer_round_impl`` the homogeneous engines run, with tau promoted
    from a broadcast scalar to a vmapped per-instance input; the tau
    math is elementwise f32, so per instance the computed values — and
    hence the committed orders and PRNG stream — are bit-identical to a
    homogeneous run at the same temperatures (asserted in
    tests/test_serving.py across the jnp, kernel, and banded tiers).

    Returns (orders (BS, N), keys (BS, 2), losses (T, BS)).
    """
    def step(carry, tau_b):
        orders, keys = carry
        pair = jax.vmap(jax.random.split)(keys)
        keys, subs = pair[:, 0], pair[:, 1]

        def one(x, order, key, norm, tau_r):
            return _outer_round_impl(x, order, key, tau_r, norm,
                                     hw=hw, cfg=cfg, apply_fn=apply_fn)

        orders, losses = jax.vmap(one)(xs, orders, subs, norms, tau_b)
        return (orders, keys), losses

    (orders, keys), losses = jax.lax.scan(step, (orders, keys), tau_rows)
    return orders, keys, losses


_run_rounds_ragged = functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)(_run_rounds_ragged_impl)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "hw", "cfg", "apply_fn"),
)
def _run_rounds_ragged_sharded(xs, orders, keys, tau_rows, norms, *, mesh,
                               hw, cfg: ShuffleSoftSortConfig, apply_fn):
    """``_run_rounds_ragged_impl`` shard_mapped over the mesh "data"
    axis: the instance axis (and each instance's tau column) splits
    across devices, each shard runs the identical ragged program on its
    slice.  Same check_rep=False rationale as ``_run_rounds_sharded``."""
    body = functools.partial(_run_rounds_ragged_impl, hw=hw, cfg=cfg,
                             apply_fn=apply_fn)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P(None, "data"),
                  P("data")),
        out_specs=(P("data"), P("data"), P(None, "data")),
        check_rep=False,
    )(xs, orders, keys, tau_rows, norms)


def _run_rounds_ragged_w_impl(xs, orders, keys, tau_rows, norms, *, hw,
                              cfg: ShuffleSoftSortConfig, apply_fn):
    """``_run_rounds_ragged_impl`` that additionally returns the LAST
    round's trained keys ``w`` per instance.

    The adaptive controller evaluates the measured ``band_tail_bound``
    on these at every rung boundary (the ws ride in the scan carry, so
    only the final round's (BS, N) slab leaves the device).  The
    orders/losses/keys math is the identical vmapped
    ``_outer_round_full`` body, so values are bit-identical to the
    plain ragged engine at the same temperatures.

    Returns (orders (BS, N), keys (BS, 2), losses (T, BS), ws (BS, N)).
    """
    def step(carry, tau_b):
        orders, keys, _ = carry
        pair = jax.vmap(jax.random.split)(keys)
        keys, subs = pair[:, 0], pair[:, 1]

        def one(x, order, key, norm, tau_r):
            return _outer_round_full(x, order, key, tau_r, norm,
                                     hw=hw, cfg=cfg, apply_fn=apply_fn)

        orders, losses, ws = jax.vmap(one)(xs, orders, subs, norms, tau_b)
        return (orders, keys, ws), losses

    ws0 = jnp.zeros(xs.shape[:2], jnp.float32)
    (orders, keys, ws), losses = jax.lax.scan(
        step, (orders, keys, ws0), tau_rows)
    return orders, keys, losses, ws


_run_rounds_ragged_w = functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)(_run_rounds_ragged_w_impl)


@functools.partial(
    jax.jit,
    static_argnames=("mesh", "hw", "cfg", "apply_fn"),
)
def _run_rounds_ragged_w_sharded(xs, orders, keys, tau_rows, norms, *,
                                 mesh, hw, cfg: ShuffleSoftSortConfig,
                                 apply_fn):
    """``_run_rounds_ragged_w_impl`` shard_mapped over the mesh "data"
    axis.  Same check_rep=False rationale as ``_run_rounds_sharded``."""
    body = functools.partial(_run_rounds_ragged_w_impl, hw=hw, cfg=cfg,
                             apply_fn=apply_fn)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P(None, "data"),
                  P("data")),
        out_specs=(P("data"), P("data"), P(None, "data"), P("data")),
        check_rep=False,
    )(xs, orders, keys, tau_rows, norms)


def _ragged_w_run(xs_t, orders, keys, tau_rows, norms_t, *, hw, cfg,
                  apply_fn, mesh):
    """Dispatch one ragged-with-w segment to the vmap or shard_map
    engine, padding/unpadding the instance axis (and each padded
    instance's tau column) to the mesh size.

    Returns (orders (BS, N), keys (BS, 2), losses (T, BS), ws (BS, N)).
    """
    tau_rows = jnp.asarray(tau_rows)
    if mesh is None:
        return _run_rounds_ragged_w(xs_t, orders, keys, tau_rows, norms_t,
                                    hw=hw, cfg=cfg, apply_fn=apply_fn)
    d_mesh = mesh.shape["data"]
    bs = xs_t.shape[0]
    pad = (-bs) % d_mesh
    if pad:
        xs_t, orders, keys, norms_t = _pad_instances(
            (xs_t, orders, keys, norms_t), bs + pad)
        tau_rows = jnp.concatenate(
            [tau_rows, jnp.repeat(tau_rows[:, :1], pad, axis=1)], axis=1)
    o, k, l, w = _run_rounds_ragged_w_sharded(
        xs_t, orders, keys, tau_rows, norms_t,
        mesh=mesh, hw=hw, cfg=cfg, apply_fn=apply_fn)
    if pad:
        o, k, l, w = o[:bs], k[:bs], l[:, :bs], w[:bs]
    return o, k, l, w


def _check_schedule(cfg: ShuffleSoftSortConfig) -> None:
    if cfg.schedule not in ("fixed", "adaptive"):
        raise ValueError(
            f"cfg.schedule={cfg.schedule!r} must be 'fixed' or 'adaptive'")


def make_adaptive_controller(cfg: ShuffleSoftSortConfig, n_instances: int,
                             n: int, seg_len: int | None = None):
    """Build a ``core.annealing.AdaptiveController`` wired to this
    config's tau schedule and resolved band half-width for problem size
    ``n``.  ``seg_len`` overrides the decision quantum (it must divide
    ``cfg.rounds``) — ``SortServer`` passes its own rung length so
    controller boundaries land exactly on scheduler boundaries."""
    from repro.core.annealing import AdaptiveController, adaptive_seg_len
    return AdaptiveController(
        cfg, n_instances, taus=_tau_schedule(cfg),
        band=resolve_band(cfg, n),
        seg_len=adaptive_seg_len(cfg) if seg_len is None else int(seg_len))


def _run_adaptive(xs_t, orders, keys, norms_t, *, hw,
                  cfg: ShuffleSoftSortConfig, mesh, controller,
                  boundary_hook=None, ckpt=None, resume: bool = False,
                  meta: dict | None = None, rung_hook=None,
                  hook_state: dict | None = None,
                  check_finite: bool = True, monitor=None,
                  mesh_hook=None):
    """Host-side adaptive decision loop around the ragged engines.

    Each iteration advances every live instance by one ``seg_len`` rung
    — live instances are grouped by apply regime (dense vs banded, per
    the controller's MEASURED switch state) and each group runs as one
    ragged dispatch consuming its instances' own schedule slices; the
    controller then observes the rung's losses and end-of-rung keys and
    decides jumps / stops / switches for the next rung.  Stopped (or
    culled) instances simply leave the dispatch groups — their PRNG
    streams are per-instance, so survivors are unperturbed.

    ``boundary_hook(step, controller, losses)`` runs after each
    boundary's observe — the tournament culls from it.

    Checkpointing (EXPERIMENTS.md §Robustness): with ``ckpt`` every
    committed rung publishes orders/keys/losses plus the controller's
    full ``state_dict`` and — for callers whose boundary hook carries
    its own cross-rung state (the adaptive tournament's alive sets) —
    the entries of ``hook_state`` (a mutable dict the caller owns;
    restored IN PLACE on resume, so the hook closure sees the resumed
    values).  ``rung_hook(executed_rounds)`` fires at the TOP of each
    rung, before any work — a kill there loses at most the in-flight
    rung, and the resumed run replays it from the last committed
    boundary bit-identically (the controller's decisions are pure
    functions of committed observations).  ``mesh_hook(executed_rounds,
    mesh) -> mesh | None`` fires right after ``rung_hook`` and may
    swap in a replacement mesh for the remaining rungs — the elastic
    re-shard point; the ragged carry is layout-free, so the swap is
    bit-identity-preserving (tests/test_elastic.py).

    Returns (orders (BS, N) device, keys (BS, 2) device,
    losses (BS, R) np.float32 — NaN at never-executed rounds,
    device_rounds — instance-rounds spent, mesh padding included).
    """
    ctrl = controller
    seg = ctrl.seg_len
    bs, n = xs_t.shape[0], xs_t.shape[1]
    dense_fn = _select_apply_fn(cfg)
    band_fn = (dense_fn if ctrl.band is None
               else _select_apply_fn(cfg, ctrl.band))
    mon = monitor if (monitor is not None and monitor.active) else None
    if mon is not None:
        cfg_o = dataclasses.replace(cfg, use_kernel=False)
        dense_o = _select_apply_fn(cfg_o)
        band_o = (dense_o if ctrl.band is None
                  else _select_apply_fn(cfg_o, ctrl.band))
    losses_mat = np.full((bs, cfg.rounds), np.nan, np.float32)
    d_mesh = 1 if mesh is None else mesh.shape["data"]
    device_rounds = 0
    step = 0
    if resume and ckpt is not None:
        got = ckpt.restore_latest(_meta_expect(meta or {}))
        if got is not None:
            state, _, m = got
            orders = jnp.asarray(state["orders"])
            keys = jnp.asarray(state["keys"])
            losses_mat = np.asarray(state["losses"], np.float32).copy()
            ctrl.load_state_dict(
                {f: state["ctrl_" + f] for f in ctrl._STATE_FIELDS})
            if hook_state is not None:
                hook_state.clear()
                hook_state.update({k[3:]: np.asarray(v)
                                   for k, v in state.items()
                                   if k.startswith("hs_")})
            step = int(m["step"])
            device_rounds = int(m["device_rounds"])
    while True:
        live = ctrl.live_indices()
        if live.size == 0:
            break
        if rung_hook is not None:
            rung_hook(step * seg)
        if mesh_hook is not None:
            new_mesh = mesh_hook(step * seg, mesh)
            if new_mesh is not None:
                mesh = new_mesh
                d_mesh = mesh.shape["data"]
                # Drop the device-committed carry to host: the next
                # ragged dispatch re-places it on the new mesh.
                orders = jnp.asarray(np.asarray(orders))
                keys = jnp.asarray(np.asarray(keys))
        # All live instances have executed exactly step * seg rounds —
        # stopped instances never rejoin, so executed stays uniform.
        exec0 = step * seg
        seg_losses = np.empty((live.size, seg), np.float32)
        ws_live = np.empty((live.size, n), np.float32)
        banded_mask = ctrl.banded[live]
        want_shadow = mon is not None and mon.wants_shadow(exec0)
        if mon is not None:
            orders_live = np.empty((live.size, n), np.int32)
            keys_in = np.asarray(jnp.take(keys, jnp.asarray(live), axis=0))
        if want_shadow:
            shadow_l = np.empty((live.size, seg), np.float32)
            shadow_o = np.empty((live.size, n), np.int32)
        for is_banded in (False, True):
            sel = np.flatnonzero(banded_mask == is_banded)
            if sel.size == 0:
                continue
            gidx = live[sel]
            rows = jnp.asarray(gidx)
            tau_rows_g = ctrl.tau_rows(gidx)
            # Shadow rungs snapshot the group's input carry to host
            # BEFORE the primary dispatch: the ragged engines donate
            # their input buffers, so post-hoc reads would be invalid.
            if want_shadow:
                o_in = np.asarray(jnp.take(orders, rows, axis=0))
                k_in = np.asarray(jnp.take(keys, rows, axis=0))
            o, k2, l, w = _ragged_w_run(
                jnp.take(xs_t, rows, axis=0),
                jnp.take(orders, rows, axis=0),
                jnp.take(keys, rows, axis=0),
                tau_rows_g,
                jnp.take(norms_t, rows, axis=0),
                hw=hw, cfg=cfg,
                apply_fn=band_fn if is_banded else dense_fn, mesh=mesh)
            orders = orders.at[rows].set(o)
            keys = keys.at[rows].set(k2)
            seg_losses[sel] = np.asarray(l).T
            ws_live[sel] = np.asarray(w)
            if mon is not None:
                orders_live[sel] = np.asarray(o)
            if want_shadow:
                o_sh, _, l_sh, _ = _ragged_w_run(
                    jnp.take(xs_t, rows, axis=0), jnp.asarray(o_in),
                    jnp.asarray(k_in), tau_rows_g,
                    jnp.take(norms_t, rows, axis=0),
                    hw=hw, cfg=cfg_o,
                    apply_fn=band_o if is_banded else dense_o, mesh=mesh)
                shadow_l[sel] = np.asarray(l_sh).T
                shadow_o[sel] = np.asarray(o_sh)
            device_rounds += seg * (-(-gidx.size // d_mesh) * d_mesh)
        if check_finite:
            _check_finite(seg_losses.T, exec0, cfg, "adaptive", ws=ws_live)
        if mon is not None:
            tau_vec = np.asarray(ctrl.tau_rows(live))[0]
            mon.check_rung(
                start=exec0, losses=seg_losses.T, orders=orders_live,
                keys_in=keys_in,
                keys_out=np.asarray(
                    jnp.take(keys, jnp.asarray(live), axis=0)),
                seg_len=seg, ws=ws_live, tau=tau_vec, band=ctrl.band,
                banded_mask=banded_mask,
                oracle_losses=shadow_l.T if want_shadow else None,
                oracle_orders=(shadow_o if want_shadow
                               and mon.compare_orders() else None))
        losses_mat[live, exec0:exec0 + seg] = seg_losses
        ctrl.observe(live, seg_losses, ws_live)
        if boundary_hook is not None:
            boundary_hook(step + 1, ctrl, losses_mat)
        step += 1
        if ckpt is not None:
            st = {"orders": np.asarray(orders), "keys": np.asarray(keys),
                  "losses": losses_mat.copy()}
            for f in ctrl._STATE_FIELDS:
                st["ctrl_" + f] = getattr(ctrl, f).copy()
            if hook_state is not None:
                for k, v in hook_state.items():
                    st["hs_" + k] = np.asarray(v)
            ckpt.save(step, st, meta=dict(meta or {}, step=step,
                                          device_rounds=device_rounds))
    return orders, keys, losses_mat, device_rounds


def rung_aligned_switch(cfg: ShuffleSoftSortConfig, n: int,
                        seg_len: int) -> int:
    """The dense->banded switch round snapped UP to the next multiple of
    ``seg_len`` (capped at ``cfg.rounds``).

    A continuous-batching scheduler preempts only at rung boundaries
    (multiples of its segment length), so it cannot split a segment at a
    mid-rung switch the way ``_run_segments`` does — instead the switch
    is deferred to the next boundary: a few extra rounds run dense
    (exact, just costlier) and no segment ever straddles regimes.  With
    this snapped switch every instance whose progress is a boundary
    multiple is unambiguously in ONE regime, which is what
    ``run_round_segment`` requires of its callers.
    """
    switch = _band_switch_round(cfg, n)
    if switch >= cfg.rounds:
        return cfg.rounds
    return min(-(-switch // seg_len) * seg_len, cfg.rounds)


def run_round_segment(xs, orders, keys, norms, progress, seg_len: int, *,
                      hw, cfg: ShuffleSoftSortConfig, mesh=None,
                      regime: str | None = None, with_w: bool = False,
                      guardrail=None):
    """Round-boundary join/leave hook for continuous-batching servers.

    Runs ``seg_len`` outer rounds on BS flattened instances where
    instance i consumes ITS OWN slice ``[progress[i], progress[i] +
    seg_len)`` of the tau schedule — so a device batch can mix requests
    that joined the annealing loop at different times, and a request
    leaves (or is preempted, culled, or re-queued after a fault) at any
    boundary without perturbing the survivors.  Chaining the returned
    ``orders``/``keys`` through successive calls reproduces an
    uninterrupted run bit-exactly, the same contract the tournament's
    rung segments rely on.

    Banded dispatch: all instances in one call must be in the same
    apply regime relative to the RUNG-ALIGNED switch round
    (``rung_aligned_switch``) — callers group instances by regime; a
    mixed or straddling segment raises ``ValueError`` rather than
    silently running the wrong apply.  An adaptive scheduler that
    decides regimes from the MEASURED tail bound instead passes
    ``regime="dense"`` / ``"banded"`` explicitly, which bypasses the
    model-based check (the caller owns the grouping); ``with_w=True``
    additionally returns each instance's end-of-segment trained keys —
    the observation ``core.annealing.AdaptiveController`` consumes.

    Args:
      xs:      (BS, N, d) instances.
      orders:  (BS, N) int32 current permutations.
      keys:    (BS, 2) uint32 current per-instance PRNG keys.
      norms:   (BS,) float32 per-instance loss normalizations.
      progress: (BS,) int — each instance's current global round.
      seg_len: rounds to run (the scheduler's preemption quantum).
      mesh:    optional 1-D "data" mesh; instance axis is shard_mapped
        (tail padded with discarded copies of instance 0).
      regime:  None (default) infers the apply regime from the
        model-based rung-aligned switch and validates the batch against
        it; "dense" / "banded" selects the apply explicitly (adaptive
        schedulers own the grouping).
      with_w:  also return the end-of-segment trained keys.
      guardrail: optional ``runtime.guardrails.GuardrailPolicy`` (or
        monitor) — runs the permutation-integrity probes on this
        segment's results (bijectivity, loss sanity, PRNG key-chain,
        band-tail audit when ``with_w``, and sampled oracle shadow
        recompute), raising ``IntegrityViolation`` on corruption.
        Probes are read-only; results are unchanged.

    Returns:
      (orders (BS, N), keys (BS, 2), losses (seg_len, BS)) — plus
      ``ws (BS, N)`` as a fourth element when ``with_w=True``.
    """
    xs = jnp.asarray(xs, jnp.float32)
    orders = jnp.asarray(orders, jnp.int32)
    keys = jnp.asarray(keys)
    norms = jnp.asarray(norms, jnp.float32)
    seg_len = int(seg_len)
    n = xs.shape[1]
    p = np.asarray(progress, np.int64)
    assert seg_len >= 1, seg_len
    assert p.shape == (xs.shape[0],), (p.shape, xs.shape)
    if (p < 0).any() or (p + seg_len > cfg.rounds).any():
        raise ValueError(
            f"segment [{p.min()}, {p.max() + seg_len}) escapes the "
            f"{cfg.rounds}-round schedule")
    band = resolve_band(cfg, n)
    if regime is not None:
        if regime not in ("dense", "banded"):
            raise ValueError(f"regime={regime!r} must be 'dense' or "
                             "'banded'")
        if regime == "banded" and band is None:
            raise ValueError("regime='banded' requires a resolvable "
                             "cfg.band for this problem size")
        seg_banded = regime == "banded"
        apply_fn = (_select_apply_fn(cfg, band) if seg_banded
                    else _select_apply_fn(cfg))
    else:
        switch = rung_aligned_switch(cfg, n, seg_len)
        if band is None or (p + seg_len <= switch).all():
            seg_banded = False
            apply_fn = _select_apply_fn(cfg)
        elif (p >= switch).all():
            seg_banded = True
            apply_fn = _select_apply_fn(cfg, band)
        else:
            raise ValueError(
                f"instances at rounds {sorted(set(p.tolist()))} mix apply "
                f"regimes across the rung-aligned dense->banded switch "
                f"{switch}; group instances by regime "
                f"(rung_aligned_switch)")

    mon = _open_guardrails(guardrail, cfg, "segment")
    o_in_np = k_in_np = None
    shadow = False
    if mon is not None:
        # Host snapshots BEFORE dispatch: the ragged engines donate
        # their input orders buffers.  Taken pre-padding so the shadow
        # recursion sees the caller's exact instance set.
        o_in_np = np.asarray(orders)
        k_in_np = np.asarray(keys)
        xs0, norms0, p0 = xs, norms, p.copy()
        shadow = mon.wants_shadow(int(p.min()))

    bs = xs.shape[0]
    if mesh is not None:
        d_mesh = mesh.shape["data"]
        pad = (-bs) % d_mesh
        if pad:
            xs, orders, keys, norms = _pad_instances(
                (xs, orders, keys, norms), bs + pad)
            p = np.concatenate([p, np.repeat(p[:1], pad)])
    taus = _tau_schedule(cfg)
    tau_rows = jnp.asarray(taus[p[:, None] + np.arange(seg_len)].T)
    ws = None
    if with_w:
        if mesh is None:
            orders, keys, losses, ws = _run_rounds_ragged_w(
                xs, orders, keys, tau_rows, norms,
                hw=hw, cfg=cfg, apply_fn=apply_fn)
        else:
            orders, keys, losses, ws = _run_rounds_ragged_w_sharded(
                xs, orders, keys, tau_rows, norms,
                mesh=mesh, hw=hw, cfg=cfg, apply_fn=apply_fn)
            orders, keys = orders[:bs], keys[:bs]
            losses, ws = losses[:, :bs], ws[:bs]
    else:
        if mesh is None:
            orders, keys, losses = _run_rounds_ragged(
                xs, orders, keys, tau_rows, norms,
                hw=hw, cfg=cfg, apply_fn=apply_fn)
        else:
            orders, keys, losses = _run_rounds_ragged_sharded(
                xs, orders, keys, tau_rows, norms,
                mesh=mesh, hw=hw, cfg=cfg, apply_fn=apply_fn)
            orders, keys, losses = orders[:bs], keys[:bs], losses[:, :bs]
    if mon is not None:
        oracle_l = oracle_o = None
        if shadow:
            res_sh = run_round_segment(
                xs0, o_in_np, k_in_np, norms0, p0, seg_len, hw=hw,
                cfg=dataclasses.replace(cfg, use_kernel=False),
                mesh=mesh, regime=regime)
            oracle_l = np.asarray(res_sh[2], np.float32)
            if mon.compare_orders():
                oracle_o = np.asarray(res_sh[0])
        mon.check_rung(
            start=int(p0.min()), losses=np.asarray(losses, np.float32),
            orders=np.asarray(orders), n=n, keys_in=k_in_np,
            keys_out=np.asarray(keys), seg_len=seg_len,
            ws=None if ws is None else np.asarray(ws),
            tau=taus[p0].astype(np.float32),
            band=band if (seg_banded and ws is not None) else None,
            oracle_losses=oracle_l, oracle_orders=oracle_o)
    if with_w:
        return orders, keys, losses, ws
    return orders, keys, losses


def _tau_schedule(cfg: ShuffleSoftSortConfig) -> np.ndarray:
    """Outer-round temperatures, (R,) float32: geometric anneal from
    tau_start to tau_end.

    Single source of truth for BOTH engines: the batched API's
    "per-seed bit-identical to sequential" contract holds only while
    the two paths consume the exact same float32 values, so neither
    may inline its own copy of the formula.
    """
    return np.float32(cfg.tau_start * (cfg.tau_end / cfg.tau_start)
                      ** (np.arange(1, cfg.rounds + 1) / cfg.rounds))


@functools.lru_cache(maxsize=None)
def _select_apply_fn(cfg: ShuffleSoftSortConfig, band: int | None = None):
    """Resolve (``use_kernel``, ``band``) to a per-instance apply callable.

    Memoized on the (frozen, hashable) config: the returned partial is
    the STATIC ``apply_fn`` argument of every jitted engine, and jax
    caches static callables by identity — without the cache each
    public-API call would mint a fresh partial and recompile, which a
    continuous-batching server dispatching one rung at a time cannot
    afford (one recompile per rung instead of one per shape).

    ``use_kernel=False`` — streamed pure-jnp ``softsort_apply_chunked``
    (runs everywhere; the everywhere-runnable oracle twin of the kernel
    path).  ``use_kernel=True`` — the fused Pallas TPU path from
    ``repro.kernels.ops``, which now covers the FULL train step: the
    forward is one online-softmax sweep plus the colsum pass, and the
    backward runs in Pallas too, reusing the forward's ``(perm, m, l,
    y)`` residuals instead of falling back to a jnp re-computation
    (``interpret=True`` automatically off-TPU; measured pass-count /
    HBM-traffic win in EXPERIMENTS.md §Perf).

    ``band`` (a RESOLVED half-width, see ``resolve_band``) swaps in the
    O(N * K) banded variant of whichever tier is selected — the windowed
    pure-jnp oracle or the band-grid Pallas kernels.  All four callables
    compute (P_soft @ x, colsum(P_soft)) without an (N, N) array and all
    are vmap- and grad-compatible, so every engine (sequential, vmap,
    mesh, tournament) accepts any of them transparently.

    ``cfg.compute_dtype`` reaches only the kernel paths (the jnp oracle
    tiers are the full-precision reference and stay f32), and the
    kernels' block sizes come from the committed autotune table
    (``repro.kernels.autotune``) since no explicit blocks are passed
    here — both are per-shape STATIC choices resolved at trace time, so
    every engine traces the identical apply for identical (N, d, K,
    dtype) and the bit-identity contracts hold per fixed choice.
    """
    if cfg.use_kernel:
        from repro.kernels.ops import softsort_apply
        from repro.kernels.ops import softsort_apply_banded as kernel_banded
        if band is not None:
            return functools.partial(kernel_banded, band=band,
                                     compute_dtype=cfg.compute_dtype)
        return functools.partial(softsort_apply,
                                 compute_dtype=cfg.compute_dtype)
    if band is not None:
        return functools.partial(softsort_apply_banded, band=band)
    return functools.partial(softsort_apply_chunked, chunk=cfg.chunk)


def resolve_band(cfg: ShuffleSoftSortConfig, n: int) -> int | None:
    """Resolve ``cfg.band`` to a concrete half-width K (or None = dense).

    ``"auto"`` sizes the band from two requirements (EXPERIMENTS.md
    §Perf): (a) large enough that the modeled tail bound clears
    ``band_eps`` at the COLDEST schedule temperature ``tau_end`` — the
    regime the run must finish banded in; hot early rounds are the
    DISPATCHER's job (``_band_switch_round`` holds them dense), so they
    don't inflate K.  With the trainer's linear re-init ``w =
    arange(N)`` each round the K-rank gap starts at K exactly and the
    per-round Adam drift is a few units, hence the half-gap model
    ``K >= 2 * tau_end * ln(N / eps)``.  And (b) a floor of N/16
    (rounded up to 64) so the asymptotic O(N/K) saving doesn't chase a
    needlessly tight window at large N.

    A resolved K >= N - 1 (tiny N, or an oversized explicit ``band``)
    covers every pair, so it resolves to None: the exact DENSE apply is
    the same math with none of the windowed gather overhead.
    """
    if cfg.band is None:
        return None
    if cfg.band == "auto":
        eps = max(cfg.band_eps, 1e-30)
        safety = int(np.ceil(2.0 * cfg.tau_end * np.log(max(n, 2) / eps)))
        floor = -(-max(n // 16, 1) // 64) * 64
        k = max(64, safety, floor)
    else:
        k = int(cfg.band)
    if k >= n - 1:
        return None
    return max(1, k)


def _band_switch_round(cfg: ShuffleSoftSortConfig, n: int) -> int:
    """First outer round whose temperature admits the banded apply;
    ``cfg.rounds`` means "never" (and None band means exactly that).

    The decision must be key-independent (the whole schedule compiles
    into one scanned program), so it uses the linear-init gap model: the
    trainer re-initializes ``w = arange(N)`` every round, making the
    K-rank key gap start at exactly K; a safety factor of 2 absorbs the
    few units of Adam drift the short inner loop can introduce.  A round
    switches once ``(N - K) * exp(-(K/2) / tau_r) <= band_eps`` at the
    round's hottest inner temperature ``tau_r``; the geometric anneal is
    monotone, so the rounds split into one dense prefix and one banded
    suffix.  The true data-dependent tail is reported by
    ``core.softsort.band_tail_bound`` for auditing.
    """
    k = resolve_band(cfg, n)
    if k is None:
        return cfg.rounds
    taus = _tau_schedule(cfg)
    ok = (n - k) * np.exp(-(k / 2.0) / taus) <= cfg.band_eps
    idx = np.flatnonzero(ok)
    return int(idx[0]) if idx.size else cfg.rounds


def shuffle_soft_sort(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    key: jax.Array | None = None,
    callback: Optional[Callable[[int, np.ndarray, float], None]] = None,
    *,
    checkpoint_dir: str | None = None,
    resume: bool = False,
    checkpoint_every: int | None = None,
    rung_hook: Optional[Callable[[int], None]] = None,
    check_finite: bool = True,
    guardrail=None,
) -> tuple[np.ndarray, np.ndarray, list[float]]:
    """Sort x (N, d) onto an (h, w) grid.  Returns (order, x[order], losses).

    ``order`` is the permutation (N int32) mapping grid cell -> input row;
    only these N indices — plus the N learnable weights inside each round
    — are ever stored, which is the paper's headline claim.  ``losses``
    is the Python list of per-round final losses (one host sync per
    round; use ``shuffle_soft_sort_batched`` for the sync-free
    throughput path).  ``cfg.use_kernel`` routes the SoftSort apply —
    forward AND backward — through the fused Pallas kernel tier instead
    of the chunked-jnp stream; identical semantics, see
    ``repro.kernels.ops``.

    For many problems or random restarts at once, use
    ``shuffle_soft_sort_batched`` — per-seed bit-identical to this
    function.

    ``cfg.schedule="adaptive"`` (EXPERIMENTS.md §Adaptive) runs the
    same schedule under the plateau controller — the run may stop at a
    converged rung boundary, so ``losses`` holds only the executed
    rounds.  The controller observes at rung boundaries, which is
    incompatible with the per-round ``callback`` stream.

    Preemption safety (EXPERIMENTS.md §Robustness): ``checkpoint_dir``
    publishes the cross-round carry (order, chained PRNG key, losses)
    every ``checkpoint_every`` rounds (default ``rounds // 8``) through
    ``runtime.anneal_checkpoint.AnnealCheckpointer``; ``resume=True``
    restarts from the newest checkpoint there (a bare directory starts
    fresh) and finishes bit-identical to an uninterrupted run with the
    same seed.  ``rung_hook(start_round)`` fires before each segment
    (the chaos harness's kill point); ``check_finite=False`` disables
    the per-round ``NumericalDivergence`` sentinel.

    ``guardrail=`` (a ``runtime.guardrails.GuardrailPolicy``) runs the
    permutation-integrity probes at every rung edge — invariant checks
    plus sampled oracle shadow recompute — raising a typed
    ``IntegrityViolation`` on silent corruption.  Probes are read-only:
    a guarded run returns bit-identical results to an unguarded one.
    """
    _check_schedule(cfg)
    if key is None:
        key = jax.random.PRNGKey(0)
    if cfg.schedule == "adaptive":
        if callback is not None:
            raise ValueError(
                "callback streaming is not supported with "
                "schedule='adaptive' (decisions happen at rung "
                "boundaries, not per round)")
        res = shuffle_soft_sort_batched(
            jnp.asarray(x, jnp.float32)[None], hw, cfg,
            n_restarts=1, keys=jnp.asarray(key)[None],
            checkpoint_dir=checkpoint_dir, resume=resume,
            checkpoint_every=checkpoint_every, rung_hook=rung_hook,
            check_finite=check_finite, guardrail=guardrail)
        executed = int(res.rounds_executed[0, 0])
        return (res.order[0], res.sorted[0],
                [float(v) for v in res.losses[0][:executed]])
    ckpt = _open_checkpointer(checkpoint_dir, resume)
    mon = _open_guardrails(guardrail, cfg, "sequential")
    if callback is not None and (ckpt is not None or rung_hook is not None):
        raise ValueError("checkpoint_dir/rung_hook are incompatible with "
                         "the per-round callback stream")
    n = x.shape[0]
    assert n == hw[0] * hw[1], (n, hw)
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    dense_fn = _select_apply_fn(cfg)
    band = resolve_band(cfg, n)
    switch = _band_switch_round(cfg, n)
    band_fn = dense_fn if band is None else _select_apply_fn(cfg, band)

    order = jnp.arange(n, dtype=jnp.int32)
    taus = _tau_schedule(cfg)
    losses: list[float] = []
    start = 0
    every = checkpoint_every or max(1, cfg.rounds // 8)
    meta = _engine_meta("sequential", cfg, n, 1, hw)
    if ckpt is not None or mon is not None:
        # Normalize a typed key to raw uint32 data so it survives the
        # numpy round-trip (identical stream either way).
        karr = jnp.asarray(key)
        if jnp.issubdtype(karr.dtype, jax.dtypes.prng_key):
            key = jax.random.key_data(karr)
    if ckpt is not None:
        if resume:
            got = ckpt.restore_latest(_meta_expect(meta))
            if got is not None:
                state, start, _ = got
                order = jnp.asarray(state["order"])
                key = jnp.asarray(state["key"])
                losses = [float(v) for v in state["losses"]]
    edges = set(_checkpoint_edges(cfg.rounds, every))
    if mon is not None:
        cfg_o = dataclasses.replace(cfg, use_kernel=False)
        dense_o = _select_apply_fn(cfg_o)
        band_o = dense_o if band is None else _select_apply_fn(cfg_o, band)
    seg_start = start
    o_snap = k_snap = None
    for r in range(start, cfg.rounds):
        if rung_hook is not None and (r == start or r % every == 0):
            rung_hook(r)
        if mon is not None and r == seg_start:
            # Rung-start carry snapshot for the key-chain probe and
            # (when this rung is sampled) the oracle shadow replay.
            o_snap = np.asarray(order)
            k_snap = np.asarray(key)
        key, sub = jax.random.split(key)
        order, loss = _outer_round(
            x, order, sub, jnp.float32(taus[r]), norm,
            hw=hw, cfg=cfg,
            apply_fn=band_fn if r >= switch else dense_fn)
        losses.append(float(loss))
        if check_finite:
            # Whole-segment sentinel (shared with the batched engines):
            # validates every round since the last rung edge, not just
            # the newest value, so the error pinpoints the FIRST bad
            # round even if a later one recovered to a finite loss.
            _check_finite(
                np.asarray(losses[seg_start:], np.float32)[:, None],
                seg_start, cfg, "sequential")
        if callback is not None:
            callback(r, np.asarray(order), losses[-1])
        if mon is not None and (r + 1) in edges:
            oracle_l = oracle_o = None
            if mon.wants_shadow(seg_start):
                o_sh, k_sh = jnp.asarray(o_snap), jnp.asarray(k_snap)
                shadow_losses = []
                for rr in range(seg_start, r + 1):
                    k_sh, sub_sh = jax.random.split(k_sh)
                    o_sh, l_sh = _outer_round(
                        x, o_sh, sub_sh, jnp.float32(taus[rr]), norm,
                        hw=hw, cfg=cfg_o,
                        apply_fn=band_o if rr >= switch else dense_o)
                    shadow_losses.append(float(l_sh))
                oracle_l = np.asarray(shadow_losses, np.float32)
                if mon.compare_orders():
                    oracle_o = np.asarray(o_sh)[None]
            mon.check_rung(
                start=seg_start,
                losses=np.asarray(losses[seg_start:], np.float32),
                orders=np.asarray(order)[None], n=n,
                keys_in=k_snap[None], keys_out=np.asarray(key)[None],
                seg_len=r + 1 - seg_start, tau=float(taus[seg_start]),
                oracle_losses=oracle_l, oracle_orders=oracle_o)
            seg_start = r + 1
        if ckpt is not None and (r + 1) in edges:
            ckpt.save(r + 1, {"order": np.asarray(order),
                              "key": np.asarray(key),
                              "losses": np.asarray(losses, np.float32)},
                      meta=dict(meta, round=r + 1))
    order = np.asarray(order)
    return order, np.asarray(x)[order], losses


# --------------------------------------------------------------------------
# Batched multi-problem / multi-restart engine.
# --------------------------------------------------------------------------

def _prep_instances(xs, hw, n_restarts, key, keys):
    """Normalize the batched-engine inputs into flattened instance arrays.

    Shared by ``shuffle_soft_sort_batched`` and ``restart_tournament``
    so both consume identical (BS, ...) instance layouts and identical
    PRNG streams — problem-major order, restart s of problem b at row
    ``b * S + s``.

    Returns (xs (B, N, d), B, S, N, keys (BS, 2), xs_t (BS, N, d),
    norms_t (BS,), orders (BS, N)).
    """
    xs = jnp.asarray(xs, jnp.float32)
    assert xs.ndim == 3, f"xs must be (B, N, d), got {xs.shape}"
    b, n, _ = xs.shape
    s = int(n_restarts)
    assert s >= 1, n_restarts
    assert n == hw[0] * hw[1], (n, hw)
    bs = b * s

    if keys is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, bs)
    keys = jnp.asarray(keys)
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        # New-style typed keys (jax.random.key) — unwrap to raw uint32
        # data so both key flavours drive identical streams.
        keys = jax.random.key_data(keys)
    keys = keys.reshape(bs, 2)

    # Per-problem loss normalization, tiled over restarts.
    norms = jax.vmap(mean_pairwise_distance)(xs).astype(jnp.float32)
    xs_t = jnp.repeat(xs, s, axis=0)                     # (BS, N, d)
    norms_t = jnp.repeat(norms, s, axis=0)               # (BS,)
    orders = jnp.tile(jnp.arange(n, dtype=jnp.int32), (bs, 1))
    return xs, b, s, n, keys, xs_t, norms_t, orders


@dataclasses.dataclass(frozen=True)
class BatchedSortResult:
    """Result of ``shuffle_soft_sort_batched`` over B problems x S restarts.

    The per-problem fields (``order``/``sorted``/``losses``) report the
    winning restart — the seed whose final-round loss is lowest.  The
    ``all_*`` fields keep every restart so callers can audit seed
    variance (and tests can check bit-identity against sequential runs).
    """
    order: np.ndarray          # (B, N) int32 — best restart's permutation
    sorted: np.ndarray         # (B, N, d) — xs gathered by ``order``
    losses: np.ndarray         # (B, R) — per-round losses of the best restart
    best_restart: np.ndarray   # (B,) int — argmin_s all_losses[:, s, -1]
    all_orders: np.ndarray     # (B, S, N) int32 — every restart's permutation
    all_losses: np.ndarray     # (B, S, R) — every restart's loss trace
    # schedule="adaptive" only: rounds each restart actually executed
    # (None on the fixed schedule; loss traces are NaN past the stop,
    # and ``best_restart`` compares LAST-EXECUTED losses instead of
    # round R-1 losses).
    rounds_executed: np.ndarray | None = None   # (B, S) int64


def shuffle_soft_sort_batched(
    xs: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    n_restarts: int = 1,
    key: jax.Array | None = None,
    keys: jax.Array | None = None,
    callback: Optional[Callable[[int, np.ndarray, np.ndarray], None]] = None,
    mesh=None,
    *,
    checkpoint_dir: str | None = None,
    resume: bool = False,
    checkpoint_every: int | None = None,
    rung_hook: Optional[Callable[[int], None]] = None,
    check_finite: bool = True,
    guardrail=None,
    mesh_hook=None,
) -> BatchedSortResult:
    """Sort B problems at once, S random restarts each.

    Runs B x S independent ShuffleSoftSort instances as a single vmapped
    program: one ``_outer_round_batched`` device call per round instead
    of B x S sequential calls, which amortizes dispatch overhead and
    lets XLA batch the (chunk, N) contractions — the throughput win the
    N-parameter footprint makes possible (an N^2-parameter method could
    not hold B x S instances in memory).

    With ``mesh`` (a 1-D "data" mesh from
    ``repro.launch.mesh.make_sort_mesh``) the flattened B x S instance
    axis is additionally sharded across devices via ``shard_map`` — the
    same per-instance program, split over the mesh, with the tail shard
    padded and the winning restart picked by a cross-device argmin.
    Measured devices x B x S scaling: EXPERIMENTS.md §Scaling.

    Each instance consumes exactly the PRNG stream the sequential API
    would: instance (b, s) with key ``keys[b, s]`` returns an order
    bit-identical to ``shuffle_soft_sort(xs[b], hw, cfg,
    key=keys[b, s])`` — on the vmap path AND on any mesh size.

    Args:
      xs: (B, N, d) batch of problems; all share N = hw[0] * hw[1].
      hw: target grid shape, shared by the batch.
      cfg: shared hyperparameters; ``cfg.use_kernel`` routes every
        instance through the batched Pallas path.
      n_restarts: S — independent seeds per problem; best final loss wins.
      key: base PRNG key, split into B x S instance keys (ignored when
        ``keys`` is given).
      keys: optional explicit instance keys, shape (B, S, 2) or (B*S, 2)
        uint32, ordered problem-major.
      callback: optional ``f(round, orders (B*S, N), losses (B*S,))``
        streamed per round (forces a host sync, like the sequential
        API).  Unsupported on the sharded path — streaming every round
        through the host would defeat the point of the mesh.
      mesh: optional jax Mesh with a "data" axis; shards the instance
        grid across its devices.
      checkpoint_dir / resume / checkpoint_every / rung_hook /
        check_finite: rung-boundary preemption safety, as in
        ``shuffle_soft_sort`` (EXPERIMENTS.md §Robustness).  Resumed
        runs are bit-identical per seed to uninterrupted runs on the
        vmap AND mesh paths — including resume under a different mesh
        size (the carry is stored in logical layout).
      guardrail: optional ``runtime.guardrails.GuardrailPolicy`` (or an
        existing monitor) — permutation-integrity probes at every rung
        boundary, raising ``IntegrityViolation`` on silent corruption.
        The fixed fast path reroutes through the rung-segmented runner
        (bit-identical by the segment-chaining contract) so probes see
        real rung boundaries.
      mesh_hook: optional ``f(start_round, mesh) -> mesh | None`` fired
        at each rung boundary; returning a mesh swaps the remaining
        rungs onto it — the elastic re-shard seam (device eviction /
        return at rung boundaries, EXPERIMENTS.md §Robustness "Elastic
        capacity").  Forces the rung-segmented runner on the fixed
        path, like ``rung_hook``.  The carry is layout-free, so a
        mid-run mesh swap keeps per-seed bit-identity
        (tests/test_elastic.py).

    Returns:
      ``BatchedSortResult`` — see its field docs.
    """
    _check_schedule(cfg)
    if mesh is not None and callback is not None:
        raise ValueError("callback streaming is not supported on the "
                         "sharded path; use mesh=None")
    ckpt = _open_checkpointer(checkpoint_dir, resume)
    mon = _open_guardrails(guardrail, cfg, "batched")
    if callback is not None and (ckpt is not None or rung_hook is not None
                                 or mon is not None
                                 or mesh_hook is not None):
        raise ValueError("checkpoint_dir/rung_hook/guardrail/mesh_hook are "
                         "incompatible with the per-round callback stream")
    xs, b, s, n, keys, xs_t, norms_t, orders = _prep_instances(
        xs, hw, n_restarts, key, keys)
    bs = b * s
    if cfg.schedule == "adaptive":
        if callback is not None:
            raise ValueError(
                "callback streaming is not supported with "
                "schedule='adaptive' (decisions happen at rung "
                "boundaries, not per round)")
        ctrl = make_adaptive_controller(cfg, bs, n)
        orders, _, losses_bs, _ = _run_adaptive(
            xs_t, orders, keys, norms_t, hw=hw, cfg=cfg, mesh=mesh,
            controller=ctrl, ckpt=ckpt, resume=resume,
            meta=_engine_meta("adaptive", cfg, n, bs, hw),
            rung_hook=rung_hook, check_finite=check_finite, monitor=mon,
            mesh_hook=mesh_hook)
        all_losses = losses_bs.reshape(b, s, cfg.rounds)
        all_orders = np.asarray(orders).reshape(b, s, n)
        executed = ctrl.executed.reshape(b, s)
        # Winner by LAST-EXECUTED loss (the adaptive analogue of the
        # fixed path's round-(R-1) loss); host argmin on every path —
        # the device argmin shortcut reads round R-1, which an early
        # stop leaves NaN.
        final = losses_bs[np.arange(bs), ctrl.executed - 1].reshape(b, s)
        best = np.argmin(final, axis=1)
        order = all_orders[np.arange(b), best]
        xs_np = np.asarray(xs)
        return BatchedSortResult(
            order=order,
            sorted=np.take_along_axis(xs_np, order[:, :, None], axis=1),
            losses=all_losses[np.arange(b), best],
            best_restart=best,
            all_orders=all_orders,
            all_losses=all_losses,
            rounds_executed=executed,
        )
    dense_fn = _select_apply_fn(cfg)
    band = resolve_band(cfg, n)
    switch = _band_switch_round(cfg, n)
    band_fn = dense_fn if band is None else _select_apply_fn(cfg, band)
    taus = _tau_schedule(cfg)

    if callback is None:
        if (ckpt is not None or rung_hook is not None or mon is not None
                or mesh_hook is not None):
            # Checkpointed path: the same schedule chained across rung
            # segments (bit-identical to the fast path — PR 6's
            # segment-chaining contract), publishing the carry at each
            # edge so a preempted run resumes instead of restarting.
            # Guardrail monitors ride the same seam: probes need rung-
            # boundary host syncs, which the fast path doesn't have.
            orders, _, losses_rb = _run_fixed_checkpointed(
                xs_t, orders, keys, taus, norms_t, switch=switch,
                hw=hw, cfg=cfg, dense_fn=dense_fn, band_fn=band_fn,
                mesh=mesh, ckpt=ckpt, resume=resume,
                every=checkpoint_every or max(1, cfg.rounds // 8),
                rung_hook=rung_hook,
                meta=_engine_meta("batched", cfg, n, bs, hw),
                check_finite=check_finite, band=band, monitor=mon,
                mesh_hook=mesh_hook)
        else:
            # Fast path: the whole R-round schedule as one scanned
            # device program (two when the band switch splits the
            # anneal) — no per-round host round-trips.  With a mesh the
            # same program runs per shard of the instance axis.
            orders, _, losses_rb = _run_segments(
                xs_t, orders, keys, taus, norms_t, start=0, switch=switch,
                hw=hw, cfg=cfg, dense_fn=dense_fn, band_fn=band_fn,
                mesh=mesh)
            if check_finite:
                _check_finite(np.asarray(losses_rb), 0, cfg, "batched")
        all_losses = np.asarray(losses_rb).T             # (BS, R)
    else:
        # Streaming path: one dispatch per round so the callback can
        # observe every intermediate state (same numerics as the scan).
        split_all = jax.vmap(jax.random.split)           # (BS,2) -> (BS,2,2)
        loss_rounds = []
        for r in range(cfg.rounds):
            pair = split_all(keys)
            keys, subs = pair[:, 0], pair[:, 1]
            orders, losses = _outer_round_batched(
                xs_t, orders, subs, jnp.float32(taus[r]), norms_t,
                hw=hw, cfg=cfg,
                apply_fn=band_fn if r >= switch else dense_fn)
            loss_rounds.append(losses)
            if check_finite:
                _check_finite(np.asarray(losses)[None], r, cfg, "batched")
            callback(r, np.asarray(orders), np.asarray(losses))
        all_losses = np.asarray(jnp.stack(loss_rounds, axis=-1))

    all_losses = all_losses.reshape(b, s, cfg.rounds)    # (B, S, R)
    all_orders = np.asarray(orders).reshape(b, s, n)     # (B, S, N)
    if mesh is not None:
        # Winner selection as a cross-device argmin + gather over the
        # sharded restart axis (identical result to the host argmin
        # below — asserted in tests/test_sharded.py).
        best_dev, order_dev = _best_restart_device(orders, losses_rb,
                                                   b=b, s=s)
        best = np.asarray(best_dev)                      # (B,)
        order = np.asarray(order_dev)                    # (B, N)
    else:
        best = np.argmin(all_losses[:, :, -1], axis=1)   # (B,)
        order = all_orders[np.arange(b), best]           # (B, N)
    xs_np = np.asarray(xs)
    xs_sorted = np.take_along_axis(xs_np, order[:, :, None], axis=1)
    return BatchedSortResult(
        order=order,
        sorted=xs_sorted,
        losses=all_losses[np.arange(b), best],
        best_restart=best,
        all_orders=all_orders,
        all_losses=all_losses,
    )


# --------------------------------------------------------------------------
# Restart tournament: successive-halving over the restart axis.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TournamentResult:
    """Result of ``restart_tournament`` — successive-halving restarts.

    ``rounds_run / rounds_full`` is the compute fraction the tournament
    spent vs. running every restart to completion; the quality cost of
    that saving (culling can drop a late-blooming seed) is measured in
    EXPERIMENTS.md §Scaling.
    """
    order: np.ndarray          # (B, N) int32 — winning restart's permutation
    sorted: np.ndarray         # (B, N, d) — xs gathered by ``order``
    final_loss: np.ndarray     # (B,) — winner's last-round loss
    best_restart: np.ndarray   # (B,) — winner's ORIGINAL restart index
    survivors: tuple           # per rung: (B, S_k) original restart indices
    all_losses: np.ndarray     # (B, S, R) — NaN after a restart is culled
    rounds_run: int            # instance-rounds executed on device, pad
                               # slots included (mesh path: each rung's
                               # live set rounds up to the mesh size)
    rounds_full: int           # B * S * R — the no-culling engine's cost


def _rung_boundaries(rounds: int, n_rungs: int) -> list[int]:
    """Split the R-round anneal into ``n_rungs`` contiguous segments;
    returns the (strictly increasing) end round of each rung, last == R."""
    assert n_rungs >= 1, n_rungs
    edges, prev = [], 0
    for k in range(n_rungs):
        end = round(rounds * (k + 1) / n_rungs)
        if end > prev:
            edges.append(end)
            prev = end
    assert edges[-1] == rounds, (edges, rounds)
    return edges


def _tournament_cull(final_losses: np.ndarray, keep: int) -> np.ndarray:
    """Pick the ``keep`` best restart slots per problem.

    Args:
      final_losses: (B, S_k) rung-end losses of the live restarts.
      keep: how many slots survive.

    Returns:
      (B, keep) int64 slot indices into the CURRENT live set, sorted
      ascending per problem so survivor bookkeeping stays problem-major
      and deterministic (stable sort — ties keep the lower slot).
    """
    b, s_k = final_losses.shape
    assert 1 <= keep <= s_k, (keep, s_k)
    sel = np.argsort(final_losses, axis=1, kind="stable")[:, :keep]
    sel.sort(axis=1)
    return sel


def _restart_tournament_adaptive(xs, b, s, n, keys_fl, xs_t, norms_t,
                                 orders, *, hw, cfg, cull_fraction,
                                 n_rungs, mesh, ckpt=None,
                                 resume=False, rung_hook=None,
                                 check_finite=True,
                                 monitor=None,
                                 mesh_hook=None) -> TournamentResult:
    """Adaptive-schedule tournament: the shared ``_run_adaptive`` loop
    with a cull hook at the rung edges.

    Edges are expressed in CONTROLLER steps (``_rung_boundaries`` over
    the R / seg_len decision points), so culls land on the same
    boundaries the plateau controller observes at.  Culling ranks every
    not-yet-culled restart by its LAST-EXECUTED loss — an early-stopped
    restart keeps competing with its final loss (it stopped because it
    converged, not because it lost), and a culled restart just leaves
    the winner set; either way the per-instance PRNG streams of the
    survivors never see a perturbation.

    The cross-rung cull state (current alive sets + the survivors log)
    lives in ``hstate``, which ``_run_adaptive`` persists alongside the
    controller at every committed rung — so a preempted adaptive
    tournament resumes with its culls intact, bit-identical to an
    uninterrupted run.
    """
    ctrl = make_adaptive_controller(cfg, b * s, n)
    n_steps = cfg.rounds // ctrl.seg_len
    edges = _rung_boundaries(n_steps, min(n_rungs, n_steps))
    interior = set(edges[:-1])
    edge_set = set(edges)
    # Checkpointed hook state: "alive" is the live (B, S_k) map;
    # "surv_<i>" entries are the per-edge survivors log (numbered keys
    # because the widths shrink — a ragged log can't be one array).
    hstate: dict[str, np.ndarray] = {
        "alive": np.tile(np.arange(s), (b, 1))}

    def hook(step, ctrl_, losses_mat):
        if step not in edge_set:
            return
        alive = hstate["alive"]
        s_k = alive.shape[1]
        keep = max(1, int(np.ceil(s_k * (1.0 - cull_fraction))))
        if step in interior and keep < s_k:
            rows = np.arange(b)[:, None] * s + alive     # flattened rows
            last = losses_mat[rows, ctrl_.executed[rows] - 1]
            sel = _tournament_cull(last, keep)           # (B, keep)
            kept_mask = np.zeros((b, s_k), bool)
            np.put_along_axis(kept_mask, sel, True, axis=1)
            ctrl_.mark_culled(rows[~kept_mask])
            alive = np.take_along_axis(alive, sel, axis=1)
            hstate["alive"] = alive
        n_logged = sum(1 for kk in hstate if kk.startswith("surv_"))
        hstate[f"surv_{n_logged:03d}"] = alive.copy()

    orders_f, _, losses_mat, device_rounds = _run_adaptive(
        xs_t, orders, keys_fl, norms_t, hw=hw, cfg=cfg, mesh=mesh,
        controller=ctrl, boundary_hook=hook, ckpt=ckpt, resume=resume,
        meta=_engine_meta("tournament-adaptive", cfg, n, b * s, hw),
        rung_hook=rung_hook, hook_state=hstate, check_finite=check_finite,
        monitor=monitor, mesh_hook=mesh_hook)
    # If every restart stopped before a late edge, its hook never fired;
    # the live set was already final, so log it for those rungs too.
    alive = hstate["alive"]
    survivors_log = [hstate[kk] for kk in
                     sorted(kk for kk in hstate if kk.startswith("surv_"))]
    while len(survivors_log) < len(edges):
        survivors_log.append(alive.copy())

    xs_np = np.asarray(xs)
    rows = np.arange(b)[:, None] * s + alive              # (B, S_fin)
    final = losses_mat[rows, ctrl.executed[rows] - 1]
    win = np.argmin(final, axis=1)
    best_restart = alive[np.arange(b), win]
    order = np.asarray(orders_f).reshape(b, s, n)[
        np.arange(b), best_restart]
    return TournamentResult(
        order=order,
        sorted=np.take_along_axis(xs_np, order[:, :, None], axis=1),
        final_loss=final[np.arange(b), win],
        best_restart=best_restart,
        survivors=tuple(survivors_log),
        all_losses=losses_mat.reshape(b, s, cfg.rounds),
        rounds_run=device_rounds,
        rounds_full=b * s * cfg.rounds,
    )


def restart_tournament(
    xs: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    n_restarts: int = 8,
    key: jax.Array | None = None,
    keys: jax.Array | None = None,
    cull_fraction: float = 0.5,
    n_rungs: int = 3,
    mesh=None,
    *,
    checkpoint_dir: str | None = None,
    resume: bool = False,
    rung_hook: Optional[Callable[[int], None]] = None,
    check_finite: bool = True,
    guardrail=None,
    mesh_hook=None,
) -> TournamentResult:
    """Successive-halving restart scheduler over the batched engine.

    Runs S restarts per problem for the first ``1/n_rungs`` fraction of
    the anneal, then at each rung boundary culls the worst
    ``cull_fraction`` of the live restarts (per problem, by rung-end
    loss) and keeps annealing only the survivors — so the device batch
    physically shrinks and later rounds run proportionally faster.  The
    freed wall-clock is the reinvestment: at equal time budget a caller
    can afford a larger initial S than the run-everything-to-the-end
    engine (measured tradeoff: EXPERIMENTS.md §Scaling).

    Surviving restarts consume exactly the PRNG stream an uninterrupted
    run would (segment keys are carried across rungs), so a restart
    that survives every cull finishes bit-identical to the same (b, s)
    instance under ``shuffle_soft_sort_batched`` — culling never
    perturbs the survivors' trajectories, it only stops losers early.

    Args:
      xs, hw, cfg, n_restarts, key, keys: as in
        ``shuffle_soft_sort_batched``.
      cull_fraction: fraction of live restarts dropped at each rung
        boundary (0 disables culling; 0.5 halves).
      n_rungs: number of anneal segments; culls happen at the
        ``n_rungs - 1`` interior boundaries.
      mesh: optional 1-D "data" mesh — each rung's (shrinking) instance
        grid is shard_mapped across it.
      checkpoint_dir / resume / rung_hook / check_finite: rung-boundary
        preemption safety (EXPERIMENTS.md §Robustness).  The tournament
        checkpoints at its OWN rung edges (the cull boundaries) — the
        natural seam, so alive sets and survivor logs are always
        consistent with the stored orders; ``checkpoint_every`` does
        not apply here.
      mesh_hook: optional ``f(start_round, mesh) -> mesh | None`` fired
        at each rung boundary (after ``rung_hook``); returning a mesh
        re-shards the remaining rungs over it — the elastic
        eviction/return seam (EXPERIMENTS.md §Robustness, "Elastic
        capacity").  Bit-identity-preserving: the rung carry is
        layout-free.

    Returns:
      ``TournamentResult`` — see its field docs.
    """
    assert 0.0 <= cull_fraction < 1.0, cull_fraction
    _check_schedule(cfg)
    ckpt = _open_checkpointer(checkpoint_dir, resume)
    mon = _open_guardrails(guardrail, cfg, "tournament")
    xs, b, s, n, keys_fl, xs_t, norms_t, orders = _prep_instances(
        xs, hw, n_restarts, key, keys)
    if cfg.schedule == "adaptive":
        return _restart_tournament_adaptive(
            xs, b, s, n, keys_fl, xs_t, norms_t, orders, hw=hw, cfg=cfg,
            cull_fraction=cull_fraction, n_rungs=n_rungs, mesh=mesh,
            ckpt=ckpt, resume=resume, rung_hook=rung_hook,
            check_finite=check_finite, monitor=mon, mesh_hook=mesh_hook)
    dense_fn = _select_apply_fn(cfg)
    band = resolve_band(cfg, n)
    switch = _band_switch_round(cfg, n)
    band_fn = dense_fn if band is None else _select_apply_fn(cfg, band)
    taus = _tau_schedule(cfg)
    edges = _rung_boundaries(cfg.rounds, n_rungs)

    # Live-set state, always problem-major: restart s_live of problem b
    # at flattened row b * s_k + s_live.  ``alive`` maps live slots back
    # to original restart indices.
    alive = np.tile(np.arange(s), (b, 1))                 # (B, S_k)
    xs_np = np.asarray(xs)
    cur = dict(xs=xs_t, orders=orders, keys=keys_fl, norms=norms_t)
    all_losses = np.full((b, s, cfg.rounds), np.nan, np.float32)
    survivors_log: list[np.ndarray] = []
    rounds_run = 0
    start = 0
    k_done = 0
    meta = _engine_meta("tournament", cfg, n, b * s, hw)
    if resume and ckpt is not None:
        got = ckpt.restore_latest(_meta_expect(meta))
        if got is not None:
            state, _, m = got
            alive = np.asarray(state["alive"])
            all_losses = np.asarray(state["all_losses"], np.float32).copy()
            k_done = int(m["rung"])
            survivors_log = [np.asarray(state[f"surv_{i:03d}"])
                             for i in range(k_done)]
            # xs for the live set is a pure gather of the inputs — only
            # the carry (orders/keys/norms/alive) needs storage.
            cur = dict(xs=jnp.repeat(xs, alive.shape[1], axis=0),
                       orders=jnp.asarray(state["orders"]),
                       keys=jnp.asarray(state["keys"]),
                       norms=jnp.asarray(state["norms"]))
            start = int(m["start"])
            rounds_run = int(m["rounds_run"])
    d_mesh = 1 if mesh is None else mesh.shape["data"]
    if mon is not None:
        cfg_o = dataclasses.replace(cfg, use_kernel=False)
        dense_o = _select_apply_fn(cfg_o)
        band_o = dense_o if band is None else _select_apply_fn(cfg_o, band)
    for k, end in enumerate(edges):
        if k < k_done:
            continue
        if rung_hook is not None:
            rung_hook(start)
        if mesh_hook is not None:
            new_mesh = mesh_hook(start, mesh)
            if new_mesh is not None:
                mesh = new_mesh
                d_mesh = mesh.shape["data"]
                # Survivor gathers keep the tournament carry on the old
                # mesh's devices; pull every array through host numpy
                # so the next rung re-places it on the new mesh.
                for nm in ("xs", "orders", "keys", "norms"):
                    cur[nm] = jnp.asarray(np.asarray(cur[nm]))
        s_k = alive.shape[1]
        k_in = o_in = None
        if mon is not None:
            k_in = np.asarray(cur["keys"])
            if mon.wants_shadow(start):
                o_in = np.asarray(cur["orders"])
        orders_d, keys_d, losses_d = _run_segments(
            cur["xs"], cur["orders"], cur["keys"], taus[start:end],
            cur["norms"], start=start, switch=switch,
            hw=hw, cfg=cfg, dense_fn=dense_fn, band_fn=band_fn, mesh=mesh)
        # Device compute actually spent: padded instances burn rounds
        # too, so uneven shards don't let rounds_run overstate savings.
        bs_exec = -(-b * s_k // d_mesh) * d_mesh
        rounds_run += (end - start) * bs_exec
        seg = np.asarray(losses_d).T.reshape(b, s_k, end - start)
        if check_finite:
            _check_finite(np.asarray(losses_d), start, cfg, "tournament")
        if mon is not None:
            oracle_l = oracle_o = None
            if o_in is not None:
                o_sh, _, seg_sh = _run_segments(
                    cur["xs"], jnp.asarray(o_in), jnp.asarray(k_in),
                    taus[start:end], cur["norms"], start=start,
                    switch=switch, hw=hw, cfg=cfg_o, dense_fn=dense_o,
                    band_fn=band_o, mesh=mesh)
                oracle_l = np.asarray(seg_sh, np.float32)
                if mon.compare_orders():
                    oracle_o = np.asarray(o_sh)
            mon.check_rung(
                start=start, losses=np.asarray(losses_d, np.float32),
                orders=np.asarray(orders_d), keys_in=k_in,
                keys_out=np.asarray(keys_d), seg_len=end - start,
                tau=float(taus[start]), oracle_losses=oracle_l,
                oracle_orders=oracle_o)
        all_losses[np.arange(b)[:, None], alive, start:end] = seg

        keep = max(1, int(np.ceil(s_k * (1.0 - cull_fraction))))
        if k < len(edges) - 1 and keep < s_k:
            sel = _tournament_cull(seg[:, :, -1], keep)   # (B, keep)
            alive = np.take_along_axis(alive, sel, axis=1)
            # Survivor gather stays on device — only the (small) rung
            # losses crossed to the host for the cull decision above.
            rows = jnp.asarray(
                (np.arange(b)[:, None] * s_k + sel).reshape(-1))
            cur = dict(
                xs=jnp.repeat(xs, keep, axis=0),
                orders=jnp.take(orders_d, rows, axis=0),
                keys=jnp.take(keys_d, rows, axis=0),
                norms=jnp.take(cur["norms"], rows, axis=0),
            )
        else:
            cur = dict(xs=cur["xs"], orders=orders_d, keys=keys_d,
                       norms=cur["norms"])
        survivors_log.append(alive.copy())
        start = end
        if ckpt is not None:
            st = {"orders": np.asarray(cur["orders"]),
                  "keys": np.asarray(cur["keys"]),
                  "norms": np.asarray(cur["norms"]),
                  "alive": alive.copy(),
                  "all_losses": all_losses.copy()}
            for i, sv in enumerate(survivors_log):
                st[f"surv_{i:03d}"] = sv
            ckpt.save(end, st, meta=dict(meta, rung=k + 1, start=end,
                                         rounds_run=rounds_run))

    s_fin = alive.shape[1]
    final = all_losses[np.arange(b)[:, None], alive, -1]  # (B, S_fin)
    win = np.argmin(final, axis=1)                        # live slot
    best_restart = alive[np.arange(b), win]
    order = np.asarray(cur["orders"]).reshape(b, s_fin, n)[np.arange(b), win]
    xs_sorted = np.take_along_axis(xs_np, order[:, :, None], axis=1)
    return TournamentResult(
        order=order,
        sorted=xs_sorted,
        final_loss=final[np.arange(b), win],
        best_restart=best_restart,
        survivors=tuple(survivors_log),
        all_losses=all_losses,
        rounds_run=rounds_run,
        rounds_full=b * s * cfg.rounds,
    )


# --------------------------------------------------------------------------
# Plain SoftSort baseline (paper Table III row 3): one weight vector trained
# end-to-end with the same loss and tau annealing, no shuffling.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("hw", "cfg", "apply_fn", "steps"))
def _softsort_train(x, norm, *, hw, cfg: ShuffleSoftSortConfig, apply_fn,
                    steps: int):
    n = x.shape[0]
    w0 = jnp.arange(n, dtype=jnp.float32)
    ident = jnp.arange(n, dtype=jnp.int32)
    grad_fn = jax.value_and_grad(_loss_fn)

    def body(i, carry):
        w, mu, nu, _ = carry
        # Same geometric anneal as _tau_schedule, but per inner step
        # (continuous frac) rather than per outer round — the baseline
        # has no rounds, so it cannot share the host-side (R,) array.
        frac = i.astype(jnp.float32) / steps
        tau = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** frac
        loss, g = grad_fn(w, x, ident, tau, hw, norm, cfg, apply_fn)
        t = i.astype(jnp.float32) + 1.0
        mu = 0.9 * mu + 0.1 * g
        nu = 0.999 * nu + 0.001 * jnp.square(g)
        mhat = mu / (1 - 0.9 ** t)
        nuhat = nu / (1 - 0.999 ** t)
        w = w - cfg.lr * mhat / (jnp.sqrt(nuhat) + 1e-8)
        return (w, mu, nu, loss)

    w, _, _, loss = jax.lax.fori_loop(
        0, steps, body, (w0, jnp.zeros_like(w0), jnp.zeros_like(w0),
                         jnp.float32(0.0)))
    return jnp.argsort(w), loss


def soft_sort_baseline(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    steps: int | None = None,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Pure SoftSort with the same budget (R*I steps by default).

    The baseline anneals tau continuously inside one ``fori_loop``, so
    there is no per-round boundary to segment at: ``cfg.band`` is
    honoured only when the switch model admits the band for the WHOLE
    schedule (switch round 0), otherwise the run stays dense.
    """
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    band = resolve_band(cfg, x.shape[0])
    use_band = band is not None and _band_switch_round(cfg, x.shape[0]) == 0
    apply_fn = _select_apply_fn(cfg, band if use_band else None)
    steps = steps or cfg.rounds * cfg.inner_steps
    order, loss = _softsort_train(x, norm, hw=hw, cfg=cfg, apply_fn=apply_fn,
                                  steps=steps)
    order = np.asarray(order)
    return order, np.asarray(x)[order], float(loss)
