"""ShuffleSoftSort — Algorithm 1 of the paper.

Learns a permutation of N items with only N parameters by iterating:

  for r in 1..R:                      (outer: anneal tau, re-shuffle)
      tau_r = tau_start * (tau_end / tau_start) ** (r / R)
      w     = arange(N)               (linear init preserves incoming order)
      shuf  = randperm(N)
      for i in 1..I:                  (inner: a few SoftSort grad steps)
          tau_i = tau_r * (0.2 .. 1.0 ramp)
          P     = SoftSort_tau_i(w)           (streamed, never N^2)
          y     = unshuffle(P @ x[order][shuf])
          loss  = L_nbr(y) + l_s * L_s + l_sig * L_sigma      (eq. 2)
          w    <- Adam step
      order <- commit argsort(w) through the shuffle

The random shuffle re-linearizes the grid along a fresh path each outer
iteration, so elements can take long-range jumps that pure 1-D SoftSort
transport cannot (paper Fig. 3/4).  The whole outer body is one jitted
function; in the sequential API the R-loop stays in Python so callers
can stream metrics.

Because one instance costs only N parameters (vs Gumbel-Sinkhorn's N^2),
many instances fit on a device at once.  ``shuffle_soft_sort_batched``
exploits that: it vmaps the outer round over B problems x S restarts
(each with its own PRNG stream, shuffle, and Adam state), runs the whole
annealing schedule as one scanned device program when no streaming
callback is requested, and keeps each problem's best-loss restart.
Per-seed results are bit-identical to the sequential API.

Return contract, shared by every driver here: ``order`` is the (N,)
int32 permutation mapping grid cell -> input row, ``sorted`` is
``x[order]``, and ``losses`` is the per-round loss trace (leading batch
axes in the batched API).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.losses import grid_sorting_loss, mean_pairwise_distance
from repro.core.softsort import softsort_apply_chunked


@dataclasses.dataclass(frozen=True)
class ShuffleSoftSortConfig:
    rounds: int = 1000          # R — outer iterations (paper: "few hundred")
    inner_steps: int = 8        # I — SoftSort grad steps per round (paper: 4)
    tau_start: float = 1.0
    tau_end: float = 0.2        # below ~0.2 the SoftSort gradient vanishes
    inner_tau_ramp: float = 0.2  # inner tau starts at ramp*tau_r
    lr: float = 0.3             # calibrated: see EXPERIMENTS.md §Paper-claims
    b1: float = 0.5             # short inner runs want fast-adapting Adam
    b2: float = 0.9
    lambda_s: float = 1.0       # eq. 2 regularizer weights (paper values)
    lambda_sigma: float = 2.0
    chunk: int = 256            # row-block size for streamed softsort
    use_kernel: bool = False    # route the apply through the Pallas kernel


def _loss_fn(w, x_shuf, inv_shuf, tau, hw, norm, cfg: ShuffleSoftSortConfig,
             apply_fn) -> jnp.ndarray:
    y_shuf, colsum = apply_fn(w, x_shuf, tau)
    y = y_shuf[inv_shuf]  # reverse-shuffle: loss sees the grid layout
    return grid_sorting_loss(
        y, colsum, x_shuf, hw, norm,
        lambda_s=cfg.lambda_s, lambda_sigma=cfg.lambda_sigma)


def _outer_round_impl(x, order, key, tau_r, norm, *, hw,
                      cfg: ShuffleSoftSortConfig, apply_fn):
    """One un-jitted outer round for a single problem instance.

    This is the unit the batched engine vmaps: every array argument is
    per-instance ((N, d) / (N,) / PRNG key), so ``jax.vmap`` over a
    leading batch axis gives B independent rounds — each with its own
    shuffle, PRNG stream, and (implicitly, via the inner fori_loop
    carry) its own Adam state.
    """
    n = x.shape[0]
    shuf = jax.random.permutation(key, n)
    inv_shuf = jnp.argsort(shuf)
    x_cur = x[order]
    x_shuf = x_cur[shuf]

    w0 = jnp.arange(n, dtype=jnp.float32)
    grad_fn = jax.value_and_grad(_loss_fn)

    def inner(i, carry):
        w, mu, nu, _ = carry
        frac = i.astype(jnp.float32) / jnp.maximum(cfg.inner_steps - 1, 1)
        tau_i = tau_r * (cfg.inner_tau_ramp + (1.0 - cfg.inner_tau_ramp) * frac)
        loss, g = grad_fn(w, x_shuf, inv_shuf, tau_i, hw, norm, cfg, apply_fn)
        t = i.astype(jnp.float32) + 1.0
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / (1 - cfg.b1 ** t)
        nuhat = nu / (1 - cfg.b2 ** t)
        w = w - cfg.lr * mhat / (jnp.sqrt(nuhat) + 1e-8)
        return (w, mu, nu, loss)

    w, _, _, loss = jax.lax.fori_loop(
        0, cfg.inner_steps, inner,
        (w0, jnp.zeros_like(w0), jnp.zeros_like(w0), jnp.float32(0.0)))

    # Commit the hard permutation through the shuffle:
    #   new_grid[shuf[i]] = x_shuf[sort_idx[i]] = x_cur[shuf[sort_idx[i]]]
    sort_idx = jnp.argsort(w)          # == argmax(P_soft, -1) with repaired ties
    g = jnp.zeros(n, dtype=jnp.int32).at[shuf].set(shuf[sort_idx])
    return order[g], loss


_outer_round = functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)(_outer_round_impl)


@functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)
def _outer_round_batched(xs, orders, keys, tau_r, norms, *, hw,
                         cfg: ShuffleSoftSortConfig, apply_fn):
    """Vmapped outer round over a leading batch axis.

    Args:
      xs:     (BS, N, d) problem instances (restarts are tiled copies).
      orders: (BS, N) int32 current permutations.
      keys:   (BS, 2) uint32 per-instance PRNG keys for this round.
      tau_r:  scalar round temperature, shared across the batch.
      norms:  (BS,) per-instance loss normalization constants.

    Returns:
      (orders, losses): (BS, N) int32 and (BS,) float32.
    """
    def one(x, order, key, norm):
        return _outer_round_impl(x, order, key, tau_r, norm,
                                 hw=hw, cfg=cfg, apply_fn=apply_fn)

    return jax.vmap(one)(xs, orders, keys, norms)


@functools.partial(
    jax.jit,
    static_argnames=("hw", "cfg", "apply_fn"),
    donate_argnums=(1,),
)
def _run_rounds_batched(xs, orders, keys, taus, norms, *, hw,
                        cfg: ShuffleSoftSortConfig, apply_fn):
    """Whole-schedule batched run: lax.scan over the R outer rounds.

    One device program instead of R dispatches — the throughput path the
    batched benchmark measures.  Numerically identical to calling
    ``_outer_round_batched`` once per round (the scan body is the same
    vmapped round, consuming the same per-instance key splits), so
    results stay bit-identical to the sequential API per seed.

    Args:
      taus: (R,) float32 precomputed outer-round temperature schedule.

    Returns:
      (orders (BS, N), keys (BS, 2), losses (R, BS)).
    """
    def step(carry, tau_r):
        orders, keys = carry
        pair = jax.vmap(jax.random.split)(keys)
        keys, subs = pair[:, 0], pair[:, 1]

        def one(x, order, key, norm):
            return _outer_round_impl(x, order, key, tau_r, norm,
                                     hw=hw, cfg=cfg, apply_fn=apply_fn)

        orders, losses = jax.vmap(one)(xs, orders, subs, norms)
        return (orders, keys), losses

    (orders, keys), losses = jax.lax.scan(step, (orders, keys), taus)
    return orders, keys, losses


def _tau_schedule(cfg: ShuffleSoftSortConfig) -> np.ndarray:
    """Outer-round temperatures, (R,) float32: geometric anneal from
    tau_start to tau_end.

    Single source of truth for BOTH engines: the batched API's
    "per-seed bit-identical to sequential" contract holds only while
    the two paths consume the exact same float32 values, so neither
    may inline its own copy of the formula.
    """
    return np.float32(cfg.tau_start * (cfg.tau_end / cfg.tau_start)
                      ** (np.arange(1, cfg.rounds + 1) / cfg.rounds))


def _select_apply_fn(cfg: ShuffleSoftSortConfig):
    """Resolve the ``use_kernel`` switch to a per-instance apply callable.

    ``use_kernel=False`` — streamed pure-jnp ``softsort_apply_chunked``
    (runs everywhere).  ``use_kernel=True`` — the fused Pallas TPU path
    from ``repro.kernels.ops`` (``interpret=True`` automatically
    off-TPU).  Both compute (P_soft @ x, colsum(P_soft)) in O(N * block)
    memory and both are vmap-compatible, so the batched engine accepts
    either transparently.
    """
    if cfg.use_kernel:
        from repro.kernels.ops import softsort_apply
        return softsort_apply
    return functools.partial(softsort_apply_chunked, chunk=cfg.chunk)


def shuffle_soft_sort(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    key: jax.Array | None = None,
    callback: Optional[Callable[[int, np.ndarray, float], None]] = None,
) -> tuple[np.ndarray, np.ndarray, list[float]]:
    """Sort x (N, d) onto an (h, w) grid.  Returns (order, x[order], losses).

    ``order`` is the permutation (N int32) mapping grid cell -> input row;
    only these N indices — plus the N learnable weights inside each round
    — are ever stored, which is the paper's headline claim.  ``losses``
    is the Python list of per-round final losses (one host sync per
    round; use ``shuffle_soft_sort_batched`` for the sync-free
    throughput path).  ``cfg.use_kernel`` routes the SoftSort apply
    through the fused Pallas kernel instead of the chunked-jnp stream —
    identical semantics, see ``repro.kernels.ops``.

    For many problems or random restarts at once, use
    ``shuffle_soft_sort_batched`` — per-seed bit-identical to this
    function.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n = x.shape[0]
    assert n == hw[0] * hw[1], (n, hw)
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    apply_fn = _select_apply_fn(cfg)

    order = jnp.arange(n, dtype=jnp.int32)
    taus = _tau_schedule(cfg)
    losses: list[float] = []
    for r in range(cfg.rounds):
        key, sub = jax.random.split(key)
        order, loss = _outer_round(
            x, order, sub, jnp.float32(taus[r]), norm,
            hw=hw, cfg=cfg, apply_fn=apply_fn)
        losses.append(float(loss))
        if callback is not None:
            callback(r, np.asarray(order), losses[-1])
    order = np.asarray(order)
    return order, np.asarray(x)[order], losses


# --------------------------------------------------------------------------
# Batched multi-problem / multi-restart engine.
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchedSortResult:
    """Result of ``shuffle_soft_sort_batched`` over B problems x S restarts.

    The per-problem fields (``order``/``sorted``/``losses``) report the
    winning restart — the seed whose final-round loss is lowest.  The
    ``all_*`` fields keep every restart so callers can audit seed
    variance (and tests can check bit-identity against sequential runs).
    """
    order: np.ndarray          # (B, N) int32 — best restart's permutation
    sorted: np.ndarray         # (B, N, d) — xs gathered by ``order``
    losses: np.ndarray         # (B, R) — per-round losses of the best restart
    best_restart: np.ndarray   # (B,) int — argmin_s all_losses[:, s, -1]
    all_orders: np.ndarray     # (B, S, N) int32 — every restart's permutation
    all_losses: np.ndarray     # (B, S, R) — every restart's loss trace


def shuffle_soft_sort_batched(
    xs: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    n_restarts: int = 1,
    key: jax.Array | None = None,
    keys: jax.Array | None = None,
    callback: Optional[Callable[[int, np.ndarray, np.ndarray], None]] = None,
) -> BatchedSortResult:
    """Sort B problems at once, S random restarts each, on one device.

    Runs B x S independent ShuffleSoftSort instances as a single vmapped
    program: one ``_outer_round_batched`` device call per round instead
    of B x S sequential calls, which amortizes dispatch overhead and
    lets XLA batch the (chunk, N) contractions — the throughput win the
    N-parameter footprint makes possible (an N^2-parameter method could
    not hold B x S instances in memory).

    Each instance consumes exactly the PRNG stream the sequential API
    would: instance (b, s) with key ``keys[b, s]`` returns an order
    bit-identical to ``shuffle_soft_sort(xs[b], hw, cfg,
    key=keys[b, s])``.

    Args:
      xs: (B, N, d) batch of problems; all share N = hw[0] * hw[1].
      hw: target grid shape, shared by the batch.
      cfg: shared hyperparameters; ``cfg.use_kernel`` routes every
        instance through the batched Pallas path.
      n_restarts: S — independent seeds per problem; best final loss wins.
      key: base PRNG key, split into B x S instance keys (ignored when
        ``keys`` is given).
      keys: optional explicit instance keys, shape (B, S, 2) or (B*S, 2)
        uint32, ordered problem-major.
      callback: optional ``f(round, orders (B*S, N), losses (B*S,))``
        streamed per round (forces a host sync, like the sequential API).

    Returns:
      ``BatchedSortResult`` — see its field docs.
    """
    xs = jnp.asarray(xs, jnp.float32)
    assert xs.ndim == 3, f"xs must be (B, N, d), got {xs.shape}"
    b, n, _ = xs.shape
    s = int(n_restarts)
    assert s >= 1, n_restarts
    assert n == hw[0] * hw[1], (n, hw)
    bs = b * s

    if keys is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, bs)
    keys = jnp.asarray(keys)
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        # New-style typed keys (jax.random.key) — unwrap to raw uint32
        # data so both key flavours drive identical streams.
        keys = jax.random.key_data(keys)
    keys = keys.reshape(bs, 2)

    # Per-problem loss normalization, tiled over restarts.
    norms = jax.vmap(mean_pairwise_distance)(xs).astype(jnp.float32)
    xs_t = jnp.repeat(xs, s, axis=0)                     # (BS, N, d)
    norms_t = jnp.repeat(norms, s, axis=0)               # (BS,)

    apply_fn = _select_apply_fn(cfg)
    orders = jnp.tile(jnp.arange(n, dtype=jnp.int32), (bs, 1))
    taus = _tau_schedule(cfg)

    if callback is None:
        # Fast path: the whole R-round schedule as one scanned device
        # program — no per-round host round-trips.
        orders, _, losses_rb = _run_rounds_batched(
            xs_t, orders, keys, jnp.asarray(taus), norms_t,
            hw=hw, cfg=cfg, apply_fn=apply_fn)
        all_losses = np.asarray(losses_rb).T             # (BS, R)
    else:
        # Streaming path: one dispatch per round so the callback can
        # observe every intermediate state (same numerics as the scan).
        split_all = jax.vmap(jax.random.split)           # (BS,2) -> (BS,2,2)
        loss_rounds = []
        for r in range(cfg.rounds):
            pair = split_all(keys)
            keys, subs = pair[:, 0], pair[:, 1]
            orders, losses = _outer_round_batched(
                xs_t, orders, subs, jnp.float32(taus[r]), norms_t,
                hw=hw, cfg=cfg, apply_fn=apply_fn)
            loss_rounds.append(losses)
            callback(r, np.asarray(orders), np.asarray(losses))
        all_losses = np.asarray(jnp.stack(loss_rounds, axis=-1))

    all_losses = all_losses.reshape(b, s, cfg.rounds)    # (B, S, R)
    all_orders = np.asarray(orders).reshape(b, s, n)     # (B, S, N)
    best = np.argmin(all_losses[:, :, -1], axis=1)       # (B,)
    order = all_orders[np.arange(b), best]               # (B, N)
    xs_np = np.asarray(xs)
    xs_sorted = np.take_along_axis(xs_np, order[:, :, None], axis=1)
    return BatchedSortResult(
        order=order,
        sorted=xs_sorted,
        losses=all_losses[np.arange(b), best],
        best_restart=best,
        all_orders=all_orders,
        all_losses=all_losses,
    )


# --------------------------------------------------------------------------
# Plain SoftSort baseline (paper Table III row 3): one weight vector trained
# end-to-end with the same loss and tau annealing, no shuffling.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("hw", "cfg", "apply_fn", "steps"))
def _softsort_train(x, norm, *, hw, cfg: ShuffleSoftSortConfig, apply_fn,
                    steps: int):
    n = x.shape[0]
    w0 = jnp.arange(n, dtype=jnp.float32)
    ident = jnp.arange(n, dtype=jnp.int32)
    grad_fn = jax.value_and_grad(_loss_fn)

    def body(i, carry):
        w, mu, nu, _ = carry
        # Same geometric anneal as _tau_schedule, but per inner step
        # (continuous frac) rather than per outer round — the baseline
        # has no rounds, so it cannot share the host-side (R,) array.
        frac = i.astype(jnp.float32) / steps
        tau = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** frac
        loss, g = grad_fn(w, x, ident, tau, hw, norm, cfg, apply_fn)
        t = i.astype(jnp.float32) + 1.0
        mu = 0.9 * mu + 0.1 * g
        nu = 0.999 * nu + 0.001 * jnp.square(g)
        mhat = mu / (1 - 0.9 ** t)
        nuhat = nu / (1 - 0.999 ** t)
        w = w - cfg.lr * mhat / (jnp.sqrt(nuhat) + 1e-8)
        return (w, mu, nu, loss)

    w, _, _, loss = jax.lax.fori_loop(
        0, steps, body, (w0, jnp.zeros_like(w0), jnp.zeros_like(w0),
                         jnp.float32(0.0)))
    return jnp.argsort(w), loss


def soft_sort_baseline(
    x: jnp.ndarray,
    hw: tuple[int, int],
    cfg: ShuffleSoftSortConfig = ShuffleSoftSortConfig(),
    steps: int | None = None,
) -> tuple[np.ndarray, np.ndarray, float]:
    """Pure SoftSort with the same budget (R*I steps by default)."""
    x = jnp.asarray(x, jnp.float32)
    norm = jnp.float32(mean_pairwise_distance(x))
    apply_fn = _select_apply_fn(cfg)
    steps = steps or cfg.rounds * cfg.inner_steps
    order, loss = _softsort_train(x, norm, hw=hw, cfg=cfg, apply_fn=apply_fn,
                                  steps=steps)
    order = np.asarray(order)
    return order, np.asarray(x)[order], float(loss)
