"""SoftSort — continuous relaxation of argsort (Prillo & Eisenschlos, 2020).

    SoftSort_tau(w) = softmax_rows( -|sort(w)_i - w_j| / tau )          (eq. 1)

Row i of the soft permutation matrix concentrates on the element of `w`
holding rank i, so ``P_soft @ x`` approximates ``x[argsort(w)]``.

Three implementations live here:

* ``softsort_matrix``           — materializes the full (N, N) matrix.
                                  Reference path; fine up to N ~ 8k.
* ``softsort_apply_chunked``    — row-block streaming evaluation of
                                  (P @ x, column_sums(P)) in O(N * chunk)
                                  memory, any N (the tail block pads and
                                  masks).  This is the paper's "row-wise
                                  manner" requirement (Sec. II) and the
                                  everywhere-runnable pure-jnp oracle
                                  twin of the fused Pallas kernel tier in
                                  ``repro.kernels`` — same math, no
                                  accelerator or interpret-mode
                                  dependency, the reference the kernel
                                  parity tests stream against.  Exact:
                                  every key pair is still scored, so the
                                  compute stays O(N^2 * d).
* ``softsort_apply_banded``     — O(N * K * d) *windowed* evaluation:
                                  the payload is gathered into sorted-key
                                  order and row i softmaxes only over the
                                  2K+1 keys whose rank is within K of i.
                                  At annealed temperatures SoftSort rows
                                  are exponentially concentrated near the
                                  diagonal in rank space, so the dropped
                                  tail mass is analytically bounded by
                                  ``band_tail_bound`` — the oracle twin
                                  of the banded Pallas kernels in
                                  ``repro.kernels.ops.softsort_apply_banded``
                                  and the parity reference the banded
                                  tests stream against.

Everything is differentiable; the chunked path uses ``jax.lax.map`` so
autodiff re-streams the blocks in the backward pass instead of saving an
N^2 residual (the Pallas tier goes further: its custom VJP saves the
(perm, m, l, y) residuals and runs the backward as kernels too —
see ``repro.kernels.ops``).  ``band_tail_bound`` is the diagnostic that
licenses the banded truncation; the engine dispatcher in
``repro.core.shufflesoftsort`` uses the same bound shape to decide when
the anneal is cold enough to switch from dense to banded.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def _sort_diff(w: jnp.ndarray) -> jnp.ndarray:
    """sort(w) written as gather-by-argsort.  Mathematically the same
    gradient as jnp.sort, but avoids this jaxlib build's broken
    grad-of-sort path (GatherDimensionNumbers operand_batching_dims)."""
    return w[jnp.argsort(jax.lax.stop_gradient(w))]


def softsort_matrix(w: jnp.ndarray, tau: float | jnp.ndarray,
                    descending: bool = False) -> jnp.ndarray:
    """Full (N, N) SoftSort matrix. Row i ~ one-hot of rank-i element."""
    ws = _sort_diff(w)
    if descending:
        ws = ws[::-1]
    d = jnp.abs(ws[:, None] - w[None, :])
    return jax.nn.softmax(-d / tau, axis=-1)


def softsort_apply_chunked(
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: float | jnp.ndarray,
    chunk: int = 256,
    descending: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming (P_soft @ x, column_sums(P_soft)) without an (N, N) array.

    Args:
      w: (N,) sort keys (the N learnable parameters), or (B, N) for a
        batch of B independent instances sharing one ``tau``.
      x: (N, d) payload vectors to be re-ordered ((B, N, d) when batched).
      tau: temperature.
      chunk: rows of P evaluated per step; memory is O(chunk * N)
        (O(B * chunk * N) batched — the batch stays vectorized inside
        each streamed row block, the same layout the batched engine's
        vmap produces).  N need not divide by chunk: the tail row block
        is padded (and masked out of the colsum), matching the Pallas
        wrapper's padding contract.
      descending: row i targets rank N-1-i instead of rank i, matching
        ``softsort_matrix(..., descending=True)``.  Reversing the sorted
        keys only reverses the ROW order of P, so this is a flip of y;
        the column sums are row-order invariant.

    Returns:
      y: (N, d) soft-sorted payload ((B, N, d) batched).
      colsum: (N,) column sums of P_soft, for the stochastic loss eq. 3
        ((B, N) batched).
    """
    if descending:
        y, colsum = softsort_apply_chunked(w, x, tau, chunk)
        return jnp.flip(y, axis=-2), colsum
    if w.ndim == 2:
        assert x.ndim == 3 and x.shape[:2] == w.shape, (w.shape, x.shape)
        return jax.vmap(
            lambda wi, xi: softsort_apply_chunked(wi, xi, tau, chunk)
        )(w, x)
    n = w.shape[0]
    if n <= chunk:
        p = softsort_matrix(w, tau)
        return p @ x, p.sum(axis=0)

    ws = _sort_diff(w)
    # Arbitrary N: pad the tail row block (matching the Pallas wrapper's
    # padding contract) — pad rows are not rows of P, so they are masked
    # out of the colsum and their y rows sliced off.
    nb = -(-n // chunk)
    pad = nb * chunk - n
    if pad:
        ws = jnp.concatenate([ws, jax.lax.stop_gradient(ws[-1:]) *
                              jnp.ones((pad,), ws.dtype)])
    ws_blocks = ws.reshape(nb, chunk)
    valid_blocks = (jnp.arange(nb * chunk) < n).astype(
        w.dtype).reshape(nb, chunk)

    def row_block(blk):
        ws_blk, valid_blk = blk
        # (chunk, N) scores for this row block — peak live memory.
        s = -jnp.abs(ws_blk[:, None] - w[None, :]) / tau
        p = jax.nn.softmax(s, axis=-1) * valid_blk[:, None]
        return p @ x, p.sum(axis=0)

    y_blocks, colsum_blocks = jax.lax.map(
        row_block, (ws_blocks, valid_blocks))
    return y_blocks.reshape(nb * chunk, x.shape[-1])[:n], \
        colsum_blocks.sum(axis=0)


def softsort_apply_banded(
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: float | jnp.ndarray,
    band: int,
    descending: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Windowed (P_soft @ x, column_sums(P_soft)) in O(N * K * d).

    The payload is gathered into sorted-key order (differentiable via
    the same gather-by-argsort trick as ``_sort_diff``); row i then
    softmaxes only over the keys whose RANK is within ``band`` of i —
    a width-(2K+1) diagonal band of the soft permutation matrix in rank
    space.  Out-of-band entries are treated as exactly zero; the
    neglected mass is upper-bounded by ``band_tail_bound(w, tau, band)``
    per row, which is what licenses the truncation once the anneal is
    cold.  The banded Pallas kernels
    (``repro.kernels.ops.softsort_apply_banded``) compute the identical
    truncated math — this function is their everywhere-runnable parity
    reference, vmap- and grad-compatible, any N.

    Args:
      w: (N,) sort keys, or (B, N) for a batch sharing one ``tau``.
      x: (N, d) payload ((B, N, d) batched).
      tau: temperature.
      band: K, the band half-width in rank space.  ``band >= N - 1``
        degenerates to the exact dense result.
      descending: as in ``softsort_apply_chunked`` — flips the row
        order of y, leaves colsum untouched.

    Returns:
      (y (N, d), colsum (N,)) — same contract (and same row/column
      order) as the dense and chunked paths, batched shapes when
      ``w.ndim == 2``.
    """
    if descending:
        y, colsum = softsort_apply_banded(w, x, tau, band)
        return jnp.flip(y, axis=-2), colsum
    if w.ndim == 2:
        assert x.ndim == 3 and x.shape[:2] == w.shape, (w.shape, x.shape)
        return jax.vmap(
            lambda wi, xi: softsort_apply_banded(wi, xi, tau, band)
        )(w, x)
    n = w.shape[0]
    k = int(band)
    assert k >= 1, band
    perm = jnp.argsort(jax.lax.stop_gradient(w))
    ws = w[perm]                                 # sorted keys, grad-carrying
    xs = x[perm]                                 # payload in rank order
    # (N, 2K+1) window of rank indices around each row's own rank; the
    # clip keeps gathers in-bounds and the mask zeroes the clipped slots,
    # so duplicated edge indices contribute exactly nothing.
    idx = jnp.arange(n)[:, None] + jnp.arange(-k, k + 1)[None, :]
    valid = (idx >= 0) & (idx < n)
    idxc = jnp.clip(idx, 0, n - 1)
    s = -jnp.abs(ws[:, None] - ws[idxc]) / tau
    # Finite mask value (not -inf): exp(-1e30 - m) underflows to exactly
    # 0.0 in f32 with no inf arithmetic in the softmax or its VJP —
    # same convention as the kernel tier's NEG_INF.
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)               # (N, 2K+1), masked slots 0
    y = jnp.einsum("nk,nkd->nd", p, xs[idxc])
    # Column sums in rank order (scatter-add over the windows; masked
    # p entries are exactly zero so clipped duplicates are harmless),
    # then back to original column order through the permutation.
    colsum_sorted = jnp.zeros(n, p.dtype).at[idxc.reshape(-1)].add(
        p.reshape(-1))
    colsum = jnp.zeros(n, p.dtype).at[perm].set(colsum_sorted)
    return y, colsum


def band_tail_bound(w: jnp.ndarray, tau: float | jnp.ndarray,
                    band: int) -> jnp.ndarray:
    """Analytic upper bound on the per-row probability mass a banded
    apply drops: ``(N - K) * exp(-g_K / tau)``.

    Row i of SoftSort scores key j as ``-|sort(w)_i - w_j| / tau`` with
    its own key at distance 0, so the softmax denominator is >= 1 (the
    ``exp(0)`` diagonal term).  Every key more than K ranks away sits at
    least ``g_K = min_i(sort(w)_{i+K} - sort(w)_i)`` — the tightest key
    spread across K ranks — from row i's key, so each of the <= N - K
    out-of-band terms contributes at most ``exp(-g_K / tau)`` to the
    dropped (un-normalized, hence also normalized) mass.  Exact-arithmetic
    bound; a float32 evaluation adds rounding noise of a few ULP on top.

    This is also the MEASURED switch criterion of the adaptive
    annealing tier (``core.annealing.AdaptiveController``): evaluated
    on each instance's actual trained keys at the instance's own next
    temperature — hence the per-instance ``tau`` broadcast below —
    instead of the linear-init model ``_band_switch_round`` uses for
    the fixed schedule.

    Args:
      w: (N,) keys or (B, N) batch.
      tau: temperature — a scalar (may be traced), or (B,) with a
        (B, N) ``w`` for per-instance temperatures (elementwise
        broadcast against the per-instance gap ``g_K``).
      band: K, the band half-width in rank space.

    Returns:
      scalar bound ((B,) batched); exactly 0 when the band already
      covers every pair (``band >= N - 1``).
    """
    n = w.shape[-1]
    k = int(band)
    assert k >= 1, band
    if k >= n - 1:
        return jnp.zeros(w.shape[:-1], jnp.float32)
    ws = jnp.sort(w, axis=-1)
    g = jnp.min(ws[..., k:] - ws[..., :n - k], axis=-1)
    return (n - k) * jnp.exp(-g / tau)


def hard_permutation(w: jnp.ndarray) -> jnp.ndarray:
    """argmax over rows of P_soft == argsort(w) with stable tie handling.

    Row i of SoftSort peaks at the element nearest to sort(w)[i]; for a
    vector without exact duplicates this is exactly argsort.  We compute
    it directly as argsort (O(N log N), no N^2), matching what
    ``argmax(P_soft, -1)`` returns in exact arithmetic.
    """
    return jnp.argsort(w)


def is_valid_permutation(idx: np.ndarray | jnp.ndarray) -> bool:
    idx = np.asarray(idx)
    return bool(np.all(np.sort(idx) == np.arange(idx.shape[0])))


def fix_permutation(idx: np.ndarray | jnp.ndarray) -> np.ndarray:
    """Greedy repair of an index vector with duplicates (paper Sec. II:
    'in very rare cases ... iterations are extended until valid' — we
    additionally provide a deterministic repair so the pipeline can
    never stall)."""
    idx = np.asarray(idx).copy()
    n = idx.shape[0]
    seen = np.zeros(n, dtype=bool)
    dup_rows = []
    for i in range(n):
        j = idx[i]
        if seen[j]:
            dup_rows.append(i)
        else:
            seen[j] = True
    missing = np.flatnonzero(~seen)
    # Assign each duplicate row the nearest missing value (both sorted —
    # monotone matching is optimal for L1 on a line).
    dup_rows_sorted = sorted(dup_rows, key=lambda r: idx[r])
    for r, m in zip(dup_rows_sorted, missing):
        idx[r] = m
    return idx
