"""SoftSort — continuous relaxation of argsort (Prillo & Eisenschlos, 2020).

    SoftSort_tau(w) = softmax_rows( -|sort(w)_i - w_j| / tau )          (eq. 1)

Row i of the soft permutation matrix concentrates on the element of `w`
holding rank i, so ``P_soft @ x`` approximates ``x[argsort(w)]``.

Two implementations live here:

* ``softsort_matrix``           — materializes the full (N, N) matrix.
                                  Reference path; fine up to N ~ 8k.
* ``softsort_apply_chunked``    — row-block streaming evaluation of
                                  (P @ x, column_sums(P)) in O(N * chunk)
                                  memory, any N (the tail block pads and
                                  masks).  This is the paper's "row-wise
                                  manner" requirement (Sec. II) and the
                                  everywhere-runnable pure-jnp oracle
                                  twin of the Pallas kernel tier in
                                  ``repro.kernels`` — same math, no
                                  accelerator or interpret-mode
                                  dependency, the reference the kernel
                                  parity tests stream against.

Everything is differentiable; the chunked path uses ``jax.lax.map`` so
autodiff re-streams the blocks in the backward pass instead of saving an
N^2 residual (the Pallas tier goes further: its custom VJP saves the
(perm, ws, m, l, y) residuals and runs the backward as kernels too —
see ``repro.kernels.ops``).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def _sort_diff(w: jnp.ndarray) -> jnp.ndarray:
    """sort(w) written as gather-by-argsort.  Mathematically the same
    gradient as jnp.sort, but avoids this jaxlib build's broken
    grad-of-sort path (GatherDimensionNumbers operand_batching_dims)."""
    return w[jnp.argsort(jax.lax.stop_gradient(w))]


def softsort_matrix(w: jnp.ndarray, tau: float | jnp.ndarray,
                    descending: bool = False) -> jnp.ndarray:
    """Full (N, N) SoftSort matrix. Row i ~ one-hot of rank-i element."""
    ws = _sort_diff(w)
    if descending:
        ws = ws[::-1]
    d = jnp.abs(ws[:, None] - w[None, :])
    return jax.nn.softmax(-d / tau, axis=-1)


def softsort_apply_chunked(
    w: jnp.ndarray,
    x: jnp.ndarray,
    tau: float | jnp.ndarray,
    chunk: int = 256,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming (P_soft @ x, column_sums(P_soft)) without an (N, N) array.

    Args:
      w: (N,) sort keys (the N learnable parameters), or (B, N) for a
        batch of B independent instances sharing one ``tau``.
      x: (N, d) payload vectors to be re-ordered ((B, N, d) when batched).
      tau: temperature.
      chunk: rows of P evaluated per step; memory is O(chunk * N)
        (O(B * chunk * N) batched — the batch stays vectorized inside
        each streamed row block, the same layout the batched engine's
        vmap produces).  N need not divide by chunk: the tail row block
        is padded (and masked out of the colsum), matching the Pallas
        wrapper's padding contract.

    Returns:
      y: (N, d) soft-sorted payload ((B, N, d) batched).
      colsum: (N,) column sums of P_soft, for the stochastic loss eq. 3
        ((B, N) batched).
    """
    if w.ndim == 2:
        assert x.ndim == 3 and x.shape[:2] == w.shape, (w.shape, x.shape)
        return jax.vmap(
            lambda wi, xi: softsort_apply_chunked(wi, xi, tau, chunk)
        )(w, x)
    n = w.shape[0]
    if n <= chunk:
        p = softsort_matrix(w, tau)
        return p @ x, p.sum(axis=0)

    ws = _sort_diff(w)
    # Arbitrary N: pad the tail row block (matching the Pallas wrapper's
    # padding contract) — pad rows are not rows of P, so they are masked
    # out of the colsum and their y rows sliced off.
    nb = -(-n // chunk)
    pad = nb * chunk - n
    if pad:
        ws = jnp.concatenate([ws, jax.lax.stop_gradient(ws[-1:]) *
                              jnp.ones((pad,), ws.dtype)])
    ws_blocks = ws.reshape(nb, chunk)
    valid_blocks = (jnp.arange(nb * chunk) < n).astype(
        w.dtype).reshape(nb, chunk)

    def row_block(blk):
        ws_blk, valid_blk = blk
        # (chunk, N) scores for this row block — peak live memory.
        s = -jnp.abs(ws_blk[:, None] - w[None, :]) / tau
        p = jax.nn.softmax(s, axis=-1) * valid_blk[:, None]
        return p @ x, p.sum(axis=0)

    y_blocks, colsum_blocks = jax.lax.map(
        row_block, (ws_blocks, valid_blocks))
    return y_blocks.reshape(nb * chunk, x.shape[-1])[:n], \
        colsum_blocks.sum(axis=0)


def hard_permutation(w: jnp.ndarray, tau: float | jnp.ndarray = 1.0,
                     chunk: int = 4096) -> jnp.ndarray:
    """argmax over rows of P_soft == argsort(w) with stable tie handling.

    Row i of SoftSort peaks at the element nearest to sort(w)[i]; for a
    vector without exact duplicates this is exactly argsort.  We compute
    it directly as argsort (O(N log N), no N^2), matching what
    ``argmax(P_soft, -1)`` returns in exact arithmetic.
    """
    del tau, chunk
    return jnp.argsort(w)


def is_valid_permutation(idx: np.ndarray | jnp.ndarray) -> bool:
    idx = np.asarray(idx)
    return bool(np.all(np.sort(idx) == np.arange(idx.shape[0])))


def fix_permutation(idx: np.ndarray | jnp.ndarray) -> np.ndarray:
    """Greedy repair of an index vector with duplicates (paper Sec. II:
    'in very rare cases ... iterations are extended until valid' — we
    additionally provide a deterministic repair so the pipeline can
    never stall)."""
    idx = np.asarray(idx).copy()
    n = idx.shape[0]
    seen = np.zeros(n, dtype=bool)
    dup_rows = []
    for i in range(n):
        j = idx[i]
        if seen[j]:
            dup_rows.append(i)
        else:
            seen[j] = True
    missing = np.flatnonzero(~seen)
    # Assign each duplicate row the nearest missing value (both sorted —
    # monotone matching is optimal for L1 on a line).
    dup_rows_sorted = sorted(dup_rows, key=lambda r: idx[r])
    for r, m in zip(dup_rows_sorted, missing):
        idx[r] = m
    return idx
