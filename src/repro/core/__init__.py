# The paper's primary contribution: ShuffleSoftSort permutation learning
# with N parameters (softsort, Algorithm 1 driver, losses eq. 2-4,
# metrics, and the baselines the paper compares against).
from repro.core.softsort import (  # noqa: F401
    band_tail_bound,
    softsort_matrix,
    softsort_apply_banded,
    softsort_apply_chunked,
    hard_permutation,
    is_valid_permutation,
    fix_permutation,
)
from repro.core.losses import (  # noqa: F401
    neighbor_loss_grid,
    stochastic_constraint_loss,
    std_loss,
    grid_sorting_loss,
)
from repro.core.metrics import dpq, mean_neighbor_distance  # noqa: F401
from repro.core.annealing import (  # noqa: F401
    AdaptiveController,
    RungDecision,
    adaptive_seg_len,
)
from repro.core.shufflesoftsort import (  # noqa: F401
    BatchedSortResult,
    ShuffleSoftSortConfig,
    TournamentResult,
    make_adaptive_controller,
    restart_tournament,
    run_round_segment,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
    soft_sort_baseline,
)
