"""GQA attention: full-sequence (train/prefill), single-token decode with
KV cache, and cross-attention — all sharded head-wise over the TP axis.

The decode path writes the new K/V at position ``pos`` with a dynamic
update and attends over the full cache with a length mask; KV caches can
additionally be sequence-sharded (SP) for the long-context cells by the
caller's sharding constraints — nothing here assumes replication.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (AxisRules, apply_rope, constrain_dims,
                                 init_linear, linear)


class KVCache(NamedTuple):
    k: jnp.ndarray   # (B, S, Hkv, Dh)
    v: jnp.ndarray   # (B, S, Hkv, Dh)


def init_attention(key, cfg, dtype, rules: AxisRules, *, cross: bool = False):
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    wq, sq = init_linear(ks[0], d, h * dh, dtype, bias=cfg.qkv_bias,
                         in_spec=rules.fsdp, out_spec=rules.tp)
    wk, sk = init_linear(ks[1], d, hkv * dh, dtype, bias=cfg.qkv_bias,
                         in_spec=rules.fsdp, out_spec=rules.tp)
    wv, sv = init_linear(ks[2], d, hkv * dh, dtype, bias=cfg.qkv_bias,
                         in_spec=rules.fsdp, out_spec=rules.tp)
    wo, so = init_linear(ks[3], h * dh, d, dtype,
                         in_spec=rules.tp, out_spec=rules.fsdp)
    return ({"wq": wq, "wk": wk, "wv": wv, "wo": wo},
            {"wq": sq, "wk": sk, "wv": sv, "wo": so})


def _split_heads(x, n_heads, dh):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, dh)


def _sdpa(q, k, v, mask):
    """Grouped-query attention without materializing repeated K/V.

    q: (B, Tq, H, Dh); k/v: (B, Tk, Hkv, Dh) with H % Hkv == 0.  The
    query heads are reshaped to (Hkv, rep) groups and contracted against
    the shared K/V heads directly — the old broadcast_in_dim repeat
    turned into GiB-scale all-gathers of the KV cache under SPMD
    (EXPERIMENTS.md §Perf, 405b decode).  fp32 softmax accumulation.
    """
    b, tq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    if rep == 1:
        # MHA fast path: flat einsum, no group dim (the extra broadcast
        # dim measurably inflates HLO bytes ~10% on MHA train cells)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
        scores = scores * (dh ** -0.5)
        if mask is not None:
            scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    qg = q.reshape(b, tq, hkv, rep, dh)
    scores = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k).astype(jnp.float32)
    scores = scores * (dh ** -0.5)
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs, v)
    return out.reshape(b, tq, h, dh)


def attention_full(params, cfg, x, *, causal: bool = True,
                   positions: Optional[jnp.ndarray] = None):
    """Train/prefill path over the whole sequence."""
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    q = _split_heads(linear(params["wq"], x), h, dh)
    k = _split_heads(linear(params["wk"], x), hkv, dh)
    v = _split_heads(linear(params["wv"], x), hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_seq_shard:
        # sequence-sharded attention: q rows over tp (heads stay whole);
        # k/v replicate (small for GQA) — no head-replication gathers.
        q = constrain_dims(q, {0: "dp", 1: "tp"})
        k = constrain_dims(k, {0: "dp"})
        v = constrain_dims(v, {0: "dp"})
    mask = None
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))[None, None]
    out = _sdpa(q, k, v, mask)
    return linear(params["wo"], out.reshape(b, t, h * dh))


def attention_prefill(params, cfg, x):
    """Full pass that also returns the populated KV cache."""
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    q = _split_heads(linear(params["wq"], x), h, dh)
    k = _split_heads(linear(params["wk"], x), hkv, dh)
    v = _split_heads(linear(params["wv"], x), hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_seq_shard:
        q = constrain_dims(q, {0: "dp", 1: "tp"})
        k = constrain_dims(k, {0: "dp"})
        v = constrain_dims(v, {0: "dp"})
    mask = jnp.tril(jnp.ones((t, t), bool))[None, None]
    out = _sdpa(q, k, v, mask)
    return linear(params["wo"], out.reshape(b, t, h * dh)), KVCache(k, v)


def attention_decode(params, cfg, x, cache: KVCache, pos: jnp.ndarray):
    """One-token step.  x: (B, 1, D); cache K/V: (B, S, Hkv, Dh);
    pos: scalar int32 — the index being written (same for the batch)."""
    b = x.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s = cache.k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _split_heads(linear(params["wq"], x), h, dh)
    k_new = _split_heads(linear(params["wk"], x), hkv, dh)
    v_new = _split_heads(linear(params["wv"], x), hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)

    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                     (0, pos, 0, 0))
    mask = (jnp.arange(s) <= pos)[None, None, None, :]       # (1,1,1,S)
    out = _sdpa(q, k, v, mask)
    return (linear(params["wo"], out.reshape(b, 1, h * dh)),
            KVCache(k, v))


def cross_attention(params, cfg, x, context_kv: KVCache):
    """Attend from x (B, T, D) to a precomputed context cache (no causal
    mask, no rope on context — positions come from the frontend stub)."""
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _split_heads(linear(params["wq"], x), h, dh)
    out = _sdpa(q, context_kv.k, context_kv.v, None)
    return linear(params["wo"], out.reshape(b, t, h * dh))


def context_kv(params, cfg, ctx):
    """Precompute K/V of the encoder/vision context (B, Tc, D)."""
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    k = _split_heads(linear(params["wk"], ctx), hkv, dh)
    v = _split_heads(linear(params["wv"], ctx), hkv, dh)
    return KVCache(k, v)


def empty_cache(cfg, batch: int, seq: int, dtype=jnp.bfloat16) -> KVCache:
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, seq, hkv, dh)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
