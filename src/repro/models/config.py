"""Model configuration for the architecture zoo.

One frozen dataclass describes every assigned architecture; the block
*program* (the repeating unit of layer kinds) is derived from it so that
heterogeneous stacks (hybrid SSM+attention, interleaved cross-attention,
alternating dense/MoE) can still be scanned with ``jax.lax.scan`` —
essential to keep HLO size and compile time flat in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal["attn_dense", "attn_moe", "mamba", "mamba_dense",
                    "mamba_moe", "cross_attn"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    qkv_bias: bool = False

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0            # expert hidden width (0 -> d_ff)
    moe_period: int = 1          # MoE FFN every k-th layer (1 = all layers)
    capacity_factor: float = 1.25
    moe_group_size: int = 512    # tokens per dispatch group: keeps the
                                 # one-hot dispatch einsum O(S*E*C*D) per
                                 # group instead of O(N^2)-ish globally
    moe_impl: str = "einsum"     # "einsum" (one-hot dispatch, baseline) |
                                 # "gather" (sparse slot-table dispatch,
                                 # §Perf variant — same math, O(E*C*D)
                                 # memory, no dispatch-einsum FLOPs)
    embed_shard: str = "vocab"   # "vocab": table (V->tp, D->fsdp); lookup
                                 #   needs a (B,S,D) psum over tp.
                                 # "hidden": table (V, D->tp); local
                                 #   lookup + small activation reshard
                                 #   (§Perf variant).
    attn_seq_shard: bool = False  # shard attention q over the TP axis by
                                  # SEQUENCE when heads %% tp != 0 (e.g.
                                  # llama4's 40 heads on 16-way TP) —
                                  # avoids replicated-head all-gathers.
    router_z_weight: float = 1e-3
    aux_loss_weight: float = 1e-2

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_layer_period: int = 0   # hybrid: one attention layer every k (jamba: 8)

    # VLM
    cross_attn_period: int = 0   # one cross-attn layer every k layers
    vision_tokens: int = 0       # stub patch-embedding count (frontend is a stub)
    vision_d: int = 0            # stub patch-embedding dim

    # Encoder-decoder (audio)
    encoder_layers: int = 0
    audio_frames: int = 0        # stub post-conv frame count

    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moment_dtype: str = "float32"   # optimizer moments; "bfloat16" for >=100B
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # "full" (nothing saveable) | "dots"
                                 # (matmul outputs saved, elementwise
                                 # recomputed — §Perf variant)
    scan_unroll: bool = False    # True: fully unroll the layer scan.  Used
                                 # by the roofline probes (XLA cost_analysis
                                 # counts a while body once; see dryrun).

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/unembed
        weights shard over any tensor-parallel degree up to 256
        (Megatron-style padding; logits >= vocab_size are masked)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: pure SSM or hybrid (tiny attention share)."""
        return self.family in ("ssm", "hybrid")

    def block_program(self) -> tuple[tuple[LayerKind, ...], int]:
        """Returns (unit, repeats): the repeating unit of layer kinds and
        how many times it is scanned.  len(unit) * repeats == num_layers."""
        kinds: list[LayerKind] = []
        for layer in range(self.num_layers):
            ffn = "moe" if self._layer_is_moe(layer) else "dense"
            if self.family == "ssm":
                kinds.append("mamba")        # pure mamba2: no FFN sub-block
            elif self.family == "hybrid":
                # jamba: 1 attention layer per attn_layer_period, rest
                # mamba; every layer carries a dense-or-MoE FFN sub-block.
                if (self.attn_layer_period
                        and layer % self.attn_layer_period
                        == self.attn_layer_period // 2):
                    kinds.append(f"attn_{ffn}")
                else:
                    kinds.append(f"mamba_{ffn}")
            elif (self.cross_attn_period
                  and layer % self.cross_attn_period
                  == self.cross_attn_period - 1):
                kinds.append("cross_attn")
            else:
                kinds.append(f"attn_{ffn}")

        # Find the smallest repeating unit so scan covers the whole stack.
        for unit_len in range(1, self.num_layers + 1):
            if self.num_layers % unit_len:
                continue
            unit = kinds[:unit_len]
            if unit * (self.num_layers // unit_len) == kinds:
                return tuple(unit), self.num_layers // unit_len
        return tuple(kinds), 1

    def _layer_is_moe(self, layer: int) -> bool:
        if not self.num_experts:
            return False
        return layer % self.moe_period == self.moe_period - 1

    # Hybrid mamba blocks keep the attention d_model; mamba inner width:
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def validate(self) -> None:
        unit, repeats = self.block_program()
        assert len(unit) * repeats == self.num_layers
        assert self.d_model % self.num_heads == 0 or self.head_dim
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.family == "ssm":
            assert self.ssm_state > 0
        if self.num_experts:
            assert self.num_experts_per_tok >= 1


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def cell_by_name(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)
