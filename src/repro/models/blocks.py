"""Layer-kind dispatch + scan-over-repeats stack assembly.

The stack is ``repeats`` copies of a fixed *unit* of layer kinds (see
``ModelConfig.block_program``).  Per-kind parameters are stacked along a
leading repeat axis and consumed by ``jax.lax.scan`` — HLO size and
compile time stay O(unit), not O(num_layers), which is what makes the
126-layer 405B dry-run compile in minutes on one host.

Caches thread through the same scan as per-repeat xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.layers import (AxisRules, constrain_act, init_mlp,
                                 init_rmsnorm, mlp, rmsnorm)
from repro.models.moe import init_moe, moe_ffn, moe_ffn_gather
from repro.models.ssm import SSMCache

PyTree = Any


# ------------------------------------------------------------- layer init

def init_layer(key, kind: str, cfg, dtype, rules: AxisRules):
    ks = jax.random.split(key, 8)
    p, s = {}, {}

    def add(name, tree):
        p[name], s[name] = tree

    if kind.startswith("attn") or kind == "cross_attn":
        add("norm1", init_rmsnorm(cfg.d_model, dtype))
        add("attn", attn.init_attention(ks[0], cfg, dtype, rules))
    if kind.startswith("mamba"):
        add("norm1", init_rmsnorm(cfg.d_model, dtype))
        add("mamba", ssm_mod.init_mamba(ks[1], cfg, dtype, rules))
    if kind == "cross_attn":
        add("norm_c", init_rmsnorm(cfg.d_model, dtype))
        add("xattn", attn.init_attention(ks[2], cfg, dtype, rules,
                                         cross=True))
        # gate scalar (llama-3.2-vision style tanh gate)
        p["xgate"] = jnp.zeros((), jnp.float32)
        s["xgate"] = jax.sharding.PartitionSpec()
    if kind.endswith("_moe"):
        add("norm2", init_rmsnorm(cfg.d_model, dtype))
        add("moe", init_moe(ks[3], cfg, dtype, rules))
    elif kind in ("attn_dense", "cross_attn", "mamba_dense"):
        add("norm2", init_rmsnorm(cfg.d_model, dtype))
        add("mlp", init_mlp(ks[4], cfg.d_model, cfg.d_ff, dtype, rules))
    return p, s


# ------------------------------------------------------------ layer apply

def apply_layer_full(params, kind: str, cfg, x, *, causal: bool,
                     ctx: Optional[jnp.ndarray]):
    """Train path: full sequence, no cache.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind.startswith("attn"):
        x = x + attn.attention_full(params["attn"], cfg,
                                    rmsnorm(params["norm1"], x, cfg.norm_eps),
                                    causal=causal)
    elif kind.startswith("mamba"):
        x = x + ssm_mod.mamba_forward(params["mamba"], cfg,
                                      rmsnorm(params["norm1"], x,
                                              cfg.norm_eps))
    elif kind == "cross_attn":
        x = x + attn.attention_full(params["attn"], cfg,
                                    rmsnorm(params["norm1"], x, cfg.norm_eps),
                                    causal=causal)
        ctx_kv = attn.context_kv(params["xattn"], cfg, ctx)
        gate = jnp.tanh(params["xgate"])
        x = x + (gate * attn.cross_attention(
            params["xattn"], cfg, rmsnorm(params["norm_c"], x, cfg.norm_eps),
            ctx_kv)).astype(x.dtype)

    if "moe" in params:
        moe = moe_ffn_gather if cfg.moe_impl == "gather" else moe_ffn
        y, moe_aux = moe(params["moe"], cfg,
                         rmsnorm(params["norm2"], x, cfg.norm_eps))
        x = x + y
        aux = aux + moe_aux["moe_balance"] + moe_aux["router_z"]
    elif "mlp" in params:
        x = x + mlp(params["mlp"], rmsnorm(params["norm2"], x, cfg.norm_eps))
    return x, aux


def apply_layer_prefill(params, kind: str, cfg, x, *,
                        ctx: Optional[jnp.ndarray]):
    """Prefill: full sequence + return this layer's cache."""
    cache = None
    if kind.startswith("attn"):
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        y, cache = attn.attention_prefill(params["attn"], cfg, h)
        x = x + y
    elif kind.startswith("mamba"):
        # run full forward; decode continues from a fresh recurrent state
        # computed below (prefill for SSM = run and keep the final state).
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        x = x + ssm_mod.mamba_forward(params["mamba"], cfg, h)
        cache = _ssm_state_after(params["mamba"], cfg, h)
    elif kind == "cross_attn":
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        y, cache = attn.attention_prefill(params["attn"], cfg, h)
        x = x + y
        ctx_kv = attn.context_kv(params["xattn"], cfg, ctx)
        gate = jnp.tanh(params["xgate"])
        x = x + (gate * attn.cross_attention(
            params["xattn"], cfg, rmsnorm(params["norm_c"], x, cfg.norm_eps),
            ctx_kv)).astype(x.dtype)

    if "moe" in params:
        moe = moe_ffn_gather if cfg.moe_impl == "gather" else moe_ffn
        y, _ = moe(params["moe"], cfg,
                   rmsnorm(params["norm2"], x, cfg.norm_eps))
        x = x + y
    elif "mlp" in params:
        x = x + mlp(params["mlp"], rmsnorm(params["norm2"], x, cfg.norm_eps))
    return x, cache


def _ssm_state_after(mparams, cfg, h):
    """Recompute the final SSM recurrent + conv state after a prefill pass
    (cheap relative to the forward; avoids threading state out of the
    chunked scan)."""
    b, t, _ = h.shape
    kw = cfg.ssm_conv
    cache = ssm_mod.empty_ssm_cache(cfg, b, h.dtype)
    # conv buffers: last kw-1 raw projected inputs
    conv_x = (h @ mparams["wx"])[:, -(kw - 1):, :]
    conv_b = (h @ mparams["wb"])[:, -(kw - 1):, :]
    conv_c = (h @ mparams["wc"])[:, -(kw - 1):, :]
    # final recurrent state: replay the last chunk... for exactness we
    # run a short scan over the whole sequence state recurrence in
    # chunked form (reuses mamba_forward internals would be ideal; here
    # we recompute via decode-style scan over chunks of the sequence).
    state = _final_state_scan(mparams, cfg, h)
    return SSMCache(conv_x.astype(cache.conv_x.dtype),
                    conv_b.astype(cache.conv_b.dtype),
                    conv_c.astype(cache.conv_c.dtype), state)


def _final_state_scan(mparams, cfg, h):
    """Final SSD state S_T = sum_j exp(sum_{i>j} la_i) dt_j B_j x_j^T,
    computed chunk-recurrently in O(T) memory."""
    b, t_true, _ = h.shape
    hh, nst, p_ = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, t_true)
    t = (t_true + q - 1) // q * q
    if t != t_true:
        h = jnp.pad(h, ((0, 0), (0, t - t_true), (0, 0)))
    nc = t // q
    x = jax.nn.silu(ssm_mod._causal_conv(h @ mparams["wx"],
                                         mparams["conv_x"]))
    bm = jax.nn.silu(ssm_mod._causal_conv(h @ mparams["wb"],
                                          mparams["conv_b"])).astype(jnp.float32)
    dt = jax.nn.softplus((h @ mparams["wdt"]).astype(jnp.float32)
                         + mparams["dt_bias"])
    if t != t_true:
        dt = dt * (jnp.arange(t) < t_true).astype(jnp.float32)[None, :, None]
    a = -jnp.exp(mparams["a_log"])
    la = dt * a
    xh = x.reshape(b, t, hh, p_).astype(jnp.float32)

    lac = la.reshape(b, nc, q, hh)
    cum = jnp.cumsum(lac, axis=2)
    xc = xh.reshape(b, nc, q, hh, p_)
    bc_ = bm.reshape(b, nc, q, nst)
    dtc = dt.reshape(b, nc, q, hh)
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtc
    s_local = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", w_end, bc_, xc)

    def scan_fn(s_prev, inp):
        cum_c, s_loc = inp
        s_next = jnp.exp(cum_c[:, -1, :])[:, :, None, None] * s_prev + s_loc
        return s_next, None

    s0 = jnp.zeros((b, hh, nst, p_), jnp.float32)
    s_fin, _ = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(cum, 1, 0), jnp.moveaxis(s_local, 1, 0)))
    return s_fin


def apply_layer_decode(params, kind: str, cfg, x, cache, pos,
                       ctx_kv: Optional[KVCache]):
    """One-token step. x: (B, 1, D)."""
    if kind.startswith("attn"):
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        y, cache = attn.attention_decode(params["attn"], cfg, h, cache, pos)
        x = x + y
    elif kind.startswith("mamba"):
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        y, cache = ssm_mod.mamba_decode(params["mamba"], cfg, h, cache)
        x = x + y
    elif kind == "cross_attn":
        h = rmsnorm(params["norm1"], x, cfg.norm_eps)
        y, cache = attn.attention_decode(params["attn"], cfg, h, cache, pos)
        x = x + y
        gate = jnp.tanh(params["xgate"])
        x = x + (gate * attn.cross_attention(
            params["xattn"], cfg, rmsnorm(params["norm_c"], x, cfg.norm_eps),
            ctx_kv)).astype(x.dtype)

    if "moe" in params:
        moe = moe_ffn_gather if cfg.moe_impl == "gather" else moe_ffn
        y, _ = moe(params["moe"], cfg,
                   rmsnorm(params["norm2"], x, cfg.norm_eps),
                   capacity_factor=float(cfg.num_experts))
        x = x + y
    elif "mlp" in params:
        x = x + mlp(params["mlp"], rmsnorm(params["norm2"], x, cfg.norm_eps))
    return x, cache


# -------------------------------------------------------- stack assembly

def init_stack(key, cfg, dtype, rules: AxisRules, *, unit=None, repeats=None):
    """Stacked per-kind params: for each position in the unit, leaves get a
    leading (repeats,) axis via vmap'd init."""
    if unit is None:
        unit, repeats = cfg.block_program()
    params, specs = [], []
    for pos, kind in enumerate(unit):
        keys = jax.random.split(jax.random.fold_in(key, pos), repeats)
        stacked = jax.vmap(
            lambda k: init_layer(k, kind, cfg, dtype, rules)[0])(keys)
        _, spec = init_layer(keys[0], kind, cfg, dtype, rules)
        # prepend the repeat axis (unsharded) to every spec
        spec = jax.tree.map(
            lambda s: jax.sharding.PartitionSpec(None, *s),
            spec,
            is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
        params.append(stacked)
        specs.append(spec)
    return tuple(params), tuple(specs)


def stack_full(params_stack, unit, cfg, x, *, causal=True, ctx=None):
    """Train-path scan over repeats. Returns (x, aux_sum)."""

    def unit_fn(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        x = constrain_act(x)
        for kind, p in zip(unit, unit_params):
            x, a = apply_layer_full(p, kind, cfg, x, causal=causal, ctx=ctx)
            x = constrain_act(x)
            aux = aux + a
        return x, aux

    if cfg.remat:
        if cfg.remat_policy == "dots":
            unit_fn = jax.checkpoint(
                unit_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            unit_fn = jax.checkpoint(unit_fn)

    def scan_fn(carry, unit_params):
        x, aux = carry
        x, a = unit_fn(x, unit_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_fn, (x, jnp.zeros((), jnp.float32)), params_stack,
        unroll=cfg.scan_unroll)
    return x, aux


def stack_prefill(params_stack, unit, cfg, x, *, ctx=None):
    """Prefill scan: returns (x, caches) with per-kind stacked caches."""

    def scan_fn(x, unit_params):
        caches = []
        x = constrain_act(x)
        for kind, p in zip(unit, unit_params):
            x, c = apply_layer_prefill(p, kind, cfg, x, ctx=ctx)
            x = constrain_act(x)
            caches.append(c)
        return x, tuple(caches)

    x, caches = jax.lax.scan(scan_fn, x, params_stack,
                             unroll=cfg.scan_unroll)
    return x, caches


def stack_decode(params_stack, unit, cfg, x, caches, pos, *, ctx_kvs=None):
    """Decode scan: caches are per-unit-position stacked pytrees (xs/ys)."""

    def scan_fn(x, inp):
        unit_params, unit_caches, unit_ctx = inp
        new_caches = []
        x = constrain_act(x)
        for i, (kind, p) in enumerate(zip(unit, unit_params)):
            ck = unit_ctx[i] if unit_ctx is not None else None
            x, c = apply_layer_decode(p, kind, cfg, x, unit_caches[i], pos,
                                      ck)
            new_caches.append(c)
        return x, tuple(new_caches)

    xs = (params_stack, caches,
          ctx_kvs if ctx_kvs is not None else None)
    if ctx_kvs is None:
        def scan_fn2(x, inp):
            unit_params, unit_caches = inp
            return scan_fn(x, (unit_params, unit_caches, None))
        x, new_caches = jax.lax.scan(scan_fn2, x, (params_stack, caches),
                                     unroll=cfg.scan_unroll)
    else:
        x, new_caches = jax.lax.scan(scan_fn, x, xs, unroll=cfg.scan_unroll)
    return x, new_caches


def make_caches(cfg, unit, repeats, batch: int, seq: int, dtype=jnp.bfloat16):
    """Empty stacked caches matching stack_decode's expected structure."""
    caches = []
    for kind in unit:
        if kind.startswith("attn") or kind == "cross_attn":
            c = attn.empty_cache(cfg, batch, seq, dtype)
        elif kind.startswith("mamba"):
            c = ssm_mod.empty_ssm_cache(cfg, batch, dtype)
        else:
            c = None
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape), c))
    return tuple(caches)
