"""Mamba2 / SSD (state-space duality) block — TPU-adapted.

The SSD algorithm (Dao & Gu, 2024) is implemented in its *chunked
matmul* form: the sequence is split into chunks of Q tokens; intra-chunk
terms are dense (Q, Q) masked matmuls (MXU-friendly — this is the TPU
adaptation: the CUDA kernel's warp-level scan becomes a batched matmul +
a short ``lax.scan`` over chunk boundaries), and inter-chunk terms pass
one (H, N, P) state through an associative recurrence.

Projections are kept separate (z / x / B / C / dt) instead of one packed
matmul so tensor-parallel sharding boundaries align with semantic dims.
Depthwise causal convs act per channel, so splitting is exact.

Decode is O(1): one state update per token, no KV cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import AxisRules, init_rmsnorm, rmsnorm


class SSMCache(NamedTuple):
    conv_x: jnp.ndarray   # (B, K-1, Din)
    conv_b: jnp.ndarray   # (B, K-1, N)
    conv_c: jnp.ndarray   # (B, K-1, N)
    state: jnp.ndarray    # (B, H, N, P)


def init_mamba(key, cfg, dtype, rules: AxisRules):
    d = cfg.d_model
    din = cfg.ssm_d_inner
    nst = cfg.ssm_state
    h = cfg.ssm_heads
    kw = cfg.ssm_conv
    ks = jax.random.split(key, 10)
    s = d ** -0.5

    def lin(k, di, do):
        return (jax.random.normal(k, (di, do), jnp.float32) * di ** -0.5
                ).astype(dtype)

    params = {
        "wz": lin(ks[0], d, din),
        "wx": lin(ks[1], d, din),
        "wb": lin(ks[2], d, nst),
        "wc": lin(ks[3], d, nst),
        "wdt": lin(ks[4], d, h),
        "conv_x": (jax.random.normal(ks[5], (kw, din), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_b": (jax.random.normal(ks[6], (kw, nst), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_c": (jax.random.normal(ks[7], (kw, nst), jnp.float32) * 0.1
                   ).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[8], (h,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(0.1))))),
        "d_skip": jnp.ones((h,), jnp.float32),
        "wo": lin(ks[9], din, d),
    }
    norm_p, norm_s = init_rmsnorm(din, dtype)
    params["norm"] = norm_p
    specs = {
        "wz": P(rules.fsdp, rules.tp),
        "wx": P(rules.fsdp, rules.tp),
        "wb": P(rules.fsdp, None),
        "wc": P(rules.fsdp, None),
        "wdt": P(rules.fsdp, rules.tp),
        "conv_x": P(None, rules.tp),
        "conv_b": P(None, None),
        "conv_c": P(None, None),
        "a_log": P(rules.tp),
        "dt_bias": P(rules.tp),
        "d_skip": P(rules.tp),
        "wo": P(rules.tp, rules.fsdp),
        "norm": norm_s,
    }
    return params, specs


def _causal_conv(x, kernel):
    """x: (B, T, C); kernel: (K, C) depthwise causal conv."""
    k = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # windowed dot: sum_k xp[:, t+k, c] * kernel[k, c]
    return sum(xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
               for i in range(k))


def _conv_step(buf, x_t, kernel):
    """buf: (B, K-1, C) previous inputs; x_t: (B, C). Returns (y_t, buf')."""
    window = jnp.concatenate([buf, x_t[:, None, :]], axis=1)   # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, kernel)
    return y, window[:, 1:, :]


def mamba_forward(params, cfg, xin):
    """Training/prefill pass. xin: (B, T, D) -> (B, T, D).

    T is padded internally to a chunk multiple; padded steps get dt = 0,
    i.e. an identity state transition and zero state injection, so they
    are exact no-ops (outputs sliced back to T)."""
    b, t_true, _ = xin.shape
    q = min(cfg.ssm_chunk, t_true) if t_true % min(cfg.ssm_chunk, t_true) == 0 \
        else cfg.ssm_chunk
    q = min(q, cfg.ssm_chunk)
    t = (t_true + q - 1) // q * q
    if t != t_true:
        xin = jnp.pad(xin, ((0, 0), (0, t - t_true), (0, 0)))
    h, nst, p_ = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    nc = t // q

    z = xin @ params["wz"]                                      # (B,T,Din)
    x = jax.nn.silu(_causal_conv(xin @ params["wx"], params["conv_x"]))
    bmat = jax.nn.silu(_causal_conv(xin @ params["wb"], params["conv_b"]))
    cmat = jax.nn.silu(_causal_conv(xin @ params["wc"], params["conv_c"]))
    dt = jax.nn.softplus((xin @ params["wdt"]).astype(jnp.float32)
                         + params["dt_bias"])                   # (B,T,H)
    if t != t_true:
        step_valid = (jnp.arange(t) < t_true).astype(jnp.float32)
        dt = dt * step_valid[None, :, None]

    a = -jnp.exp(params["a_log"])                               # (H,) < 0
    la = dt * a                                                 # (B,T,H) <= 0
    xh = x.reshape(b, t, h, p_).astype(jnp.float32)
    bm = bmat.astype(jnp.float32)
    cm = cmat.astype(jnp.float32)

    # chunk
    lac = la.reshape(b, nc, q, h)
    cum = jnp.cumsum(lac, axis=2)                               # (B,Nc,Q,H)
    xc = xh.reshape(b, nc, q, h, p_)
    bc_ = bm.reshape(b, nc, q, nst)
    cc = cm.reshape(b, nc, q, nst)
    dtc = dt.reshape(b, nc, q, h)

    # ---- intra-chunk (dense, MXU): M[h,i,j] = (C_i.B_j) e^{L_i-L_j} dt_j
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc_)                 # (B,Nc,Q,Q)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # i,j,(H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    m = m * cb[..., None] * dtc[:, :, None, :, :]               # (B,Nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # ---- chunk-local end states: S_c = sum_j e^{L_Q - L_j} dt_j B_j x_j^T
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtc              # (B,Nc,Q,H)
    s_local = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", w_end, bc_, xc)

    # ---- inter-chunk recurrence over Nc (short scan)
    def scan_fn(s_prev, inp):
        cum_c, c_c, s_loc = inp                 # (B,Q,H), (B,Q,N), (B,H,N,P)
        # y_inter[i] = e^{L_i} * C_i . S_prev
        y_int = (jnp.einsum("bqn,bhnp->bqhp", c_c, s_prev)
                 * jnp.exp(cum_c)[..., None])
        s_next = jnp.exp(cum_c[:, -1, :])[:, :, None, None] * s_prev + s_loc
        return s_next, y_int

    s0 = jnp.zeros((b, h, nst, p_), jnp.float32)
    cum_s = jnp.moveaxis(cum, 1, 0)                             # (Nc,B,Q,H)
    cc_s = jnp.moveaxis(cc, 1, 0)                               # (Nc,B,Q,N)
    sl_s = jnp.moveaxis(s_local, 1, 0)                          # (Nc,B,H,N,P)
    _, y_inter = jax.lax.scan(scan_fn, s0, (cum_s, cc_s, sl_s))
    y_inter = jnp.moveaxis(y_inter, 0, 1)                       # (B,Nc,Q,H,P)

    y = (y_intra + y_inter).reshape(b, t, h, p_)
    y = y + params["d_skip"][None, None, :, None] * xh
    y = y.reshape(b, t, h * p_).astype(xin.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["wo"]
    return out[:, :t_true, :]


def mamba_decode(params, cfg, xin, cache: SSMCache):
    """One-token step. xin: (B, 1, D)."""
    b = xin.shape[0]
    h, nst, p_ = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    xt = xin[:, 0, :]

    z = xt @ params["wz"]
    xr, conv_x = _conv_step(cache.conv_x, xt @ params["wx"], params["conv_x"])
    br, conv_b = _conv_step(cache.conv_b, xt @ params["wb"], params["conv_b"])
    cr, conv_c = _conv_step(cache.conv_c, xt @ params["wc"], params["conv_c"])
    x = jax.nn.silu(xr)
    bm = jax.nn.silu(br).astype(jnp.float32)                    # (B,N)
    cm = jax.nn.silu(cr).astype(jnp.float32)
    dt = jax.nn.softplus((xt @ params["wdt"]).astype(jnp.float32)
                         + params["dt_bias"])                   # (B,H)

    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dt * a)                                     # (B,H)
    xh = x.reshape(b, h, p_).astype(jnp.float32)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt, bm, xh)
    state = decay[:, :, None, None] * cache.state + upd
    y = jnp.einsum("bn,bhnp->bhp", cm, state)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, h * p_).astype(xin.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ params["wo"])[:, None, :]
    return out, SSMCache(conv_x, conv_b, conv_c, state)


def empty_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    kw = cfg.ssm_conv
    return SSMCache(
        conv_x=jnp.zeros((batch, kw - 1, cfg.ssm_d_inner), dtype),
        conv_b=jnp.zeros((batch, kw - 1, cfg.ssm_state), dtype),
        conv_c=jnp.zeros((batch, kw - 1, cfg.ssm_state), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                         cfg.ssm_head_dim), jnp.float32),
    )
