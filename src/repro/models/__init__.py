from repro.models.config import ModelConfig, ShapeCell, SHAPE_CELLS, cell_by_name  # noqa: F401
from repro.models.layers import AxisRules  # noqa: F401
from repro.models.model import (  # noqa: F401
    init_model,
    forward,
    loss_fn,
    prefill,
    decode_step,
    make_caches,
    param_count,
    reduced_config,
)
