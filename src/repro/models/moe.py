"""Token-choice Mixture-of-Experts with capacity-based einsum dispatch
(Shazeer-style dense dispatch/combine tensors — the XLA-SPMD-friendly
formulation: experts shard over the TP axis (EP), dispatch becomes an
all-to-all emitted by the partitioner).

Top-k selection is built from k iterated argmax+one-hot rounds instead
of ``jax.lax.top_k`` so no gather appears on the autodiff path (this
jaxlib build has a broken batched-gather gradient, see core/softsort).
Gradients flow through the ``probs * one_hot`` products, which is the
standard straight-through router formulation anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import AxisRules, constrain_moe


def init_moe(key, cfg, dtype, rules: AxisRules):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.num_experts
    ks = jax.random.split(key, 4)
    router = jax.random.normal(ks[0], (d, e), jnp.float32) * d ** -0.5
    wi = jax.random.normal(ks[1], (e, d, f), jnp.float32) * d ** -0.5
    wg = jax.random.normal(ks[2], (e, d, f), jnp.float32) * d ** -0.5
    wo = jax.random.normal(ks[3], (e, f, d), jnp.float32) * f ** -0.5
    params = {"router": router.astype(dtype), "wi": wi.astype(dtype),
              "wg": wg.astype(dtype), "wo": wo.astype(dtype)}
    # router is tiny (D x E): replicate it — a D-sharded router forces an
    # all-to-all of the (G,S,D) tokens to D-sharded layout per MoE layer
    # (measured: 15x collective regression on granite, EXPERIMENTS §Perf)
    specs = {"router": P(None, None),
             "wi": P(rules.tp, rules.fsdp, None),
             "wg": P(rules.tp, rules.fsdp, None),
             "wo": P(rules.tp, None, rules.fsdp)}
    return params, specs


def _topk_onehot(probs: jnp.ndarray, k: int):
    """probs: (T, E) -> (T, E) combined gate weights using k argmax rounds
    (gather-free).  Returns (gates, selected_mask)."""
    t, e = probs.shape
    remaining = probs
    gates = jnp.zeros_like(probs)
    sel = jnp.zeros_like(probs, dtype=bool)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                     # (T,)
        hot = jax.nn.one_hot(idx, e, dtype=probs.dtype)          # (T, E)
        gates = gates + probs * hot
        sel = sel | hot.astype(bool)
        remaining = remaining * (1.0 - hot) - hot                # mask out
    return gates, sel


def _topk_idx_gates(probs: jnp.ndarray, k: int):
    """k argmax rounds returning (expert_idx (N,k) int32, gate (N,k))."""
    remaining = probs
    idxs, gs = [], []
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                 # (N,)
        hot = jax.nn.one_hot(idx, probs.shape[-1], dtype=probs.dtype)
        gs.append(jnp.sum(probs * hot, axis=-1))
        idxs.append(idx)
        remaining = remaining * (1.0 - hot) - hot
    return (jnp.stack(idxs, -1).astype(jnp.int32), jnp.stack(gs, -1))


def moe_ffn_gather(params, cfg, x, *, capacity_factor: float | None = None):
    """Sparse (gather/scatter) dispatch — §Perf variant.

    Instead of the O(S*E*C) one-hot dispatch/combine tensors this builds
    an explicit slot table idx (G, E, C) -> token and moves rows with
    gathers: memory O(E*C*D) and zero dispatch-einsum FLOPs.  Discrete
    indices are stop-gradient; gradients flow through the gathered values
    and the router gates (straight-through, same estimator as the
    einsum form).  With experts pinned to the TP axis the combine gather
    is the layer's only cross-shard move (all-to-all equivalent).
    """
    from repro.models.layers import constrain_moe
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cf = capacity_factor or cfg.capacity_factor
    n = b * t
    s = min(cfg.moe_group_size, n)
    if n % s:
        s = n
    g = n // s
    tokens = x.reshape(g, s, d)
    cap = max(int(s * k * cf / e), 1)

    logits = jnp.einsum("gsd,de->gse", tokens,
                        params["router"].astype(tokens.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    eidx, gates = _topk_idx_gates(probs.reshape(n, e), k)     # (N,k) x2
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    eidx = eidx.reshape(g, s, k)
    gates = gates.reshape(g, s, k).astype(tokens.dtype)

    # slot of each (token, choice) inside its expert's queue, per group
    sel = jax.nn.one_hot(eidx, e, dtype=jnp.int32)            # (G,S,k,E)
    pos = jnp.cumsum(sel.reshape(g, s * k, e), axis=1
                     ).reshape(g, s, k, e) - 1
    slot = jnp.take_along_axis(pos, eidx[..., None],
                               axis=-1)[..., 0]               # (G,S,k)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)                       # cap = drop bin

    # idx[g, e, c] = source token s (or S = sentinel row of zeros)
    gg = jnp.broadcast_to(jnp.arange(g)[:, None, None], (g, s, k))
    ss = jnp.broadcast_to(jnp.arange(s)[None, :, None], (g, s, k))
    idx = jnp.full((g, e, cap + 1), s, jnp.int32)
    idx = idx.at[gg, eidx, slot_c].set(ss, mode="drop")[:, :, :cap]
    idx = jax.lax.stop_gradient(idx)

    tok_pad = jnp.concatenate(
        [tokens, jnp.zeros((g, 1, d), tokens.dtype)], axis=1)  # (G,S+1,D)
    xe = jnp.take_along_axis(
        tok_pad, idx.reshape(g, e * cap)[..., None], axis=1
    ).reshape(g, e, cap, d)
    xe = constrain_moe(xe, {0: "dp", 1: "tp"})

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["wg"])) \
        * jnp.einsum("gecd,edf->gecf", xe, params["wi"])
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])        # (G,E,C,D)
    ye = constrain_moe(ye, {0: "dp", 1: "tp"})

    # combine: token (g,s) reads its k slots back
    ye_flat = jnp.concatenate(
        [ye.reshape(g, e * cap, d),
         jnp.zeros((g, 1, d), ye.dtype)], axis=1)             # drop bin
    flat_idx = jnp.where(keep, eidx * cap + slot_c, e * cap)  # (G,S,k)
    flat_idx = jax.lax.stop_gradient(flat_idx)
    yk = jnp.take_along_axis(ye_flat,
                             flat_idx.reshape(g, s * k)[..., None],
                             axis=1).reshape(g, s, k, d)
    y = jnp.einsum("gskd,gsk->gsd", yk, gates).astype(x.dtype)

    probs_flat = probs.reshape(n, e)
    me = probs_flat.mean(axis=0)
    sel_f = sel.sum(2).reshape(n, e).astype(jnp.float32)
    ce = sel_f.mean(axis=0) * e / k
    aux = {
        "moe_balance": jnp.sum(me * ce) * cfg.aux_loss_weight * e,
        "router_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
                    * cfg.router_z_weight,
    }
    return y.reshape(b, t, d), aux


def moe_ffn(params, cfg, x, *, capacity_factor: float | None = None):
    """x: (B, T, D) -> (B, T, D), plus aux losses dict.

    Grouped dense dispatch: tokens are split into groups of
    ``cfg.moe_group_size``; within each group a token gets a per-expert
    capacity slot by cumulative sum, over-capacity tokens drop to the
    residual (standard capacity semantics).  Grouping keeps the one-hot
    dispatch/combine einsums at O(S*E*C*D) per group — without it the
    dispatch tensor contraction dominates total FLOPs for small-expert
    configs like granite (d_ff=512, top-8 of 40).
    """
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cf = capacity_factor or cfg.capacity_factor
    n = b * t
    s = min(cfg.moe_group_size, n)
    if n % s:
        # fall back to one group (decode / odd shapes)
        s = n
    g = n // s
    tokens = x.reshape(g, s, d)
    cap = max(int(s * k * cf / e), 1)

    # router matmul in token dtype (a fp32 cast of the full-seq tokens
    # derails SPMD into fp32 all-to-alls — see EXPERIMENTS.md §Perf);
    # logits upcast AFTER the contraction, softmax still fp32.
    logits = jnp.einsum("gsd,de->gse", tokens,
                        params["router"].astype(tokens.dtype)
                        ).astype(jnp.float32)                     # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = _topk_onehot(probs.reshape(n, e), k)              # (N, E)
    gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    gates = gates.reshape(g, s, e)
    sel = sel.reshape(g, s, e)

    # capacity slot per (token, expert): rank within the group's queue
    pos_in_expert = jnp.cumsum(sel.astype(jnp.int32), axis=1) - 1  # (G, S, E)
    keep = sel & (pos_in_expert < cap)
    dispatch = jax.nn.one_hot(jnp.where(keep, pos_in_expert, cap),
                              cap + 1, dtype=tokens.dtype)[..., :cap]
    combine = dispatch * gates[..., None].astype(tokens.dtype)     # (G,S,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, tokens)            # (G,E,C,D)
    # EP: pin experts to the TP axis, groups to DP, so the partitioner
    # emits an all-to-all instead of replicating the dispatch tensors
    # (active only under the launcher's moe_shard context).
    xe = constrain_moe(xe, {0: "dp", 1: "tp"})
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["wg"])) \
        * jnp.einsum("gecd,edf->gecf", xe, params["wi"])
    h = constrain_moe(h, {0: "dp", 1: "tp"})
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])             # (G,E,C,D)
    ye = constrain_moe(ye, {0: "dp", 1: "tp"})
    y = jnp.einsum("gsec,gecd->gsd", combine,
                   ye.astype(tokens.dtype)).astype(x.dtype)

    # aux losses: load-balance (Switch) + router z-loss
    probs_flat = probs.reshape(n, e)
    me = probs_flat.mean(axis=0)                                   # (E,)
    ce = sel.reshape(n, e).astype(jnp.float32).mean(axis=0) * e / k
    aux = {
        "moe_balance": jnp.sum(me * ce) * cfg.aux_loss_weight * e,
        "router_z": jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
                    * cfg.router_z_weight,
    }
    return y.reshape(b, t, d), aux
