"""End-to-end language model: embed -> (encoder ->) block stack -> head.

Covers all assigned families:
  dense / moe / ssm / hybrid    : decoder-only LM
  vlm                           : decoder LM + cross-attn to stub patch
                                  embeddings (frontend is a stub per the
                                  assignment — ``input_specs`` provides
                                  precomputed embeddings)
  audio                         : Whisper-style enc-dec; conv frontend
                                  stubbed the same way (precomputed
                                  frames at d_model)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import blocks
from repro.models.attention import KVCache, context_kv
from repro.models.config import ModelConfig
from repro.models.layers import (
    AxisRules,
    _dtype,
    constrain_act,
    embed,
    init_embedding,
    init_linear,
    init_rmsnorm,
    linear,
    rmsnorm,
    unembed,
)

PyTree = Any


class LMOutputs(NamedTuple):
    logits: jnp.ndarray
    aux_loss: jnp.ndarray


# ------------------------------------------------------------------- init

def init_model(key, cfg: ModelConfig, rules: AxisRules | None = None):
    rules = rules or AxisRules()
    dtype = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    unit, repeats = cfg.block_program()

    params, specs = {}, {}
    params["embed"], specs["embed"] = init_embedding(
        ks[0], cfg.padded_vocab, cfg.d_model, dtype, rules)
    if cfg.embed_shard == "hidden":
        # local lookup (vocab replicated), hidden dim over tp: avoids the
        # per-forward (B,S,D) psum of a vocab-sharded table (§Perf)
        specs["embed"] = {"table": P(None, rules.tp)}
    params["blocks"], specs["blocks"] = blocks.init_stack(
        ks[1], cfg, dtype, rules, unit=unit, repeats=repeats)
    params["final_norm"], specs["final_norm"] = init_rmsnorm(
        cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = init_linear(
            ks[2], cfg.d_model, cfg.padded_vocab, dtype,
            in_spec=rules.fsdp, out_spec=rules.tp)

    if cfg.family == "vlm":
        params["vision_proj"], specs["vision_proj"] = init_linear(
            ks[3], cfg.vision_d, cfg.d_model, dtype,
            in_spec=None, out_spec=rules.fsdp)
    if cfg.is_encdec:
        enc_unit = ("attn_dense",)
        params["encoder"], specs["encoder"] = blocks.init_stack(
            ks[4], cfg, dtype, rules, unit=enc_unit,
            repeats=cfg.encoder_layers)
        params["enc_norm"], specs["enc_norm"] = init_rmsnorm(
            cfg.d_model, dtype)
    return params, specs


# ------------------------------------------------------------ context enc

def _encode_context(params, cfg, context):
    """Project / encode the modality context into (B, Tc, D)."""
    if context is None:
        return None
    if cfg.family == "vlm":
        return linear(params["vision_proj"], context)
    if cfg.is_encdec:
        # context: precomputed conv-frontend frames at d_model (stub)
        x, _ = blocks.stack_full(params["encoder"], ("attn_dense",), cfg,
                                 context, causal=False, ctx=None)
        return rmsnorm(params["enc_norm"], x, cfg.norm_eps)
    return context


def _head(params, cfg, x):
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = linear(params["lm_head"], x.astype(jnp.float32)
                        ).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        # mask vocab-padding columns so softmax/argmax never see them
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return constrain_act(logits, vocab_dim=True)


# ---------------------------------------------------------------- forward

def forward(params, cfg: ModelConfig, tokens, context=None) -> LMOutputs:
    """Training forward. tokens: (B, T) int32; context: stub embeddings."""
    unit, _ = cfg.block_program()
    ctx = _encode_context(params, cfg, context)
    x = constrain_act(embed(params["embed"], tokens))
    x, aux = blocks.stack_full(params["blocks"], unit, cfg, x,
                               causal=True, ctx=ctx)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return LMOutputs(_head(params, cfg, x), aux)


def loss_fn(params, cfg: ModelConfig, batch) -> tuple[jnp.ndarray, dict]:
    """Next-token cross entropy (fp32 logsumexp) + MoE aux losses."""
    out = forward(params, cfg, batch["tokens"], batch.get("context"))
    logits = out.logits                                   # (B, T, V) fp32
    labels = batch["labels"]                              # (B, T)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - ll).mean()
    total = nll + out.aux_loss
    return total, {"nll": nll, "aux": out.aux_loss,
                   "ppl": jnp.exp(jnp.minimum(nll, 20.0))}


def prefill(params, cfg: ModelConfig, tokens, context=None):
    """Returns (last-position logits, caches) for subsequent decode."""
    unit, _ = cfg.block_program()
    ctx = _encode_context(params, cfg, context)
    x = constrain_act(embed(params["embed"], tokens))
    x, caches = blocks.stack_prefill(params["blocks"], unit, cfg, x, ctx=ctx)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return _head(params, cfg, x), caches


def precompute_ctx_kvs(params, cfg: ModelConfig, context):
    """Per-cross-layer context K/V, computed once per request (prefill
    time) so decode steps never re-encode the modality context."""
    unit, _ = cfg.block_program()
    ctx = _encode_context(params, cfg, context)
    if ctx is None or not any(k == "cross_attn" for k in unit):
        return None
    ctx_kvs = []
    for i, kind in enumerate(unit):
        if kind == "cross_attn":
            xp = params["blocks"][i]["xattn"]
            ck = jax.vmap(lambda w: context_kv(w, cfg, ctx))(xp)
        else:
            ck = None
        ctx_kvs.append(ck)
    return tuple(ctx_kvs)


def decode_step(params, cfg: ModelConfig, token, caches, pos, context=None,
                ctx_kvs=None):
    """One decode step. token: (B, 1) int32; pos: scalar int32 (position
    being written).  Returns (logits (B, 1, V), new caches).  Pass
    ``ctx_kvs`` (from ``precompute_ctx_kvs``) to avoid re-encoding the
    modality context every step."""
    unit, _ = cfg.block_program()
    if ctx_kvs is None:
        ctx_kvs = precompute_ctx_kvs(params, cfg, context)
    x = constrain_act(embed(params["embed"], token))
    x, caches = blocks.stack_decode(params["blocks"], unit, cfg, x, caches,
                                    pos, ctx_kvs=ctx_kvs)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _head(params, cfg, x), caches


def make_caches(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    unit, repeats = cfg.block_program()
    return blocks.make_caches(cfg, unit, repeats, batch, seq, dtype)


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))


def reduced_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test twin: same family/block pattern, tiny dims."""
    unit, _ = cfg.block_program()
    small = dict(
        num_layers=2 * len(unit) if len(unit) <= 8 else len(unit),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 2,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        moe_d_ff=32 if cfg.moe_d_ff else 0,
        num_experts=min(cfg.num_experts, 4),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        vision_tokens=16 if cfg.vision_tokens else 0,
        vision_d=32 if cfg.vision_d else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        audio_frames=16 if cfg.audio_frames else 0,
        param_dtype="float32",
        moment_dtype="float32",
        remat=False,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
