"""Functional building blocks (no flax in this env — params are pytrees).

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with ``jax.sharding.PartitionSpec`` leaves.  Sharding
rules (mesh topology rationale: ``repro/launch/mesh.py``):

  * tensor-parallel dims (heads, ffn hidden, experts, vocab) -> "model"
  * one remaining large dim per weight -> FSDP axis ("data", and
    ("pod","data") on the multi-pod mesh) — ZeRO-3 style
  * small vectors (norm scales, biases) -> replicated

The FSDP/TP axis names are injected via ``AxisRules`` so the same model
code serves the single-pod (data, model) and multi-pod (pod, data,
model) meshes and any future topology.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AxisRules:
    fsdp: Any = "data"           # axis (or tuple of axes) for param FSDP
    tp: Any = "model"            # axis for tensor parallelism
    dp: Any = ("data",)          # axes over which the batch is sharded
    sp: Any = None               # sequence-parallel axis for long-context KV


# --------------------------------------------------------------------------
# Activation sharding constraints.  SPMD propagation alone loses the batch
# sharding at the embedding gather (the table is (vocab->tp, d->fsdp)
# sharded, and XLA resolves the conflict by replicating the batch), which
# silently turns the whole model batch-replicated.  The launcher installs
# (mesh, dp axes) here; model code calls ``constrain_act`` at layer
# boundaries.  Outside a launcher context (unit tests, single-device) it
# is a no-op.
# --------------------------------------------------------------------------
import contextlib
import contextvars

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding_ctx(mesh, dp_axes, tp_axis="model", sp_axis=None,
                            dshard_axis=None, moe_shard=False):
    """dshard_axis: shard the hidden (last) dim of activations over this
    axis — '2-D weight-stationary' serving mode where tiny activations
    reshard instead of all-gathering FSDP weight shards every layer.
    moe_shard: constrain MoE dispatch intermediates (experts->tp)."""
    tok = _ACT_CTX.set({"mesh": mesh, "dp": dp_axes, "tp": tp_axis,
                        "sp": sp_axis, "dshard": dshard_axis,
                        "moe_shard": moe_shard})
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def constrain_act(x, *, vocab_dim: bool = False, seq_dim: bool = False):
    """Pin (B, T, ...) activations to batch-over-dp (+ optional vocab->tp
    on the last dim, seq->sp on dim 1, hidden->dshard in weight-
    stationary serving mode)."""
    ctx = _ACT_CTX.get()
    if ctx is None or x.ndim < 2:
        return x
    mesh, dp = ctx["mesh"], ctx["dp"]
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    spec = [None] * x.ndim
    if x.shape[0] % dp_size == 0:
        spec[0] = dp
    if seq_dim and ctx["sp"] and x.shape[1] % mesh.shape[ctx["sp"]] == 0:
        spec[1] = ctx["sp"]
    if vocab_dim and x.shape[-1] % mesh.shape[ctx["tp"]] == 0:
        spec[-1] = ctx["tp"]
    elif (not vocab_dim and ctx.get("dshard")
          and x.shape[-1] % mesh.shape[ctx["dshard"]] == 0):
        # weight-stationary: hidden dim takes the dshard axis; the batch
        # dim must release it (decode batches are tiny — replication is
        # the point: activations move, weights stay put)
        spec[-1] = ctx["dshard"]
        used = ctx["dshard"]
        if spec[0] is not None:
            kept = tuple(a for a in (spec[0] if isinstance(spec[0], tuple)
                                     else (spec[0],)) if a != used)
            size = 1
            for a in kept:
                size *= mesh.shape[a]
            spec[0] = kept if kept and x.shape[0] % size == 0 else None
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, P(*spec)))


def constrain_dims(t, dims: dict, *, gate: str | None = None):
    """Constrain arbitrary tensor dims to mesh axes when a launcher
    context is active.  ``dims`` maps axis-index -> 'dp'|'tp'; ``gate``
    names a context flag that must be truthy (None = always on)."""
    ctx = _ACT_CTX.get()
    if ctx is None or (gate is not None and not ctx.get(gate)):
        return t
    mesh = ctx["mesh"]
    spec = [None] * t.ndim
    for i, role in dims.items():
        axes = ctx["dp"] if role == "dp" else ctx["tp"]
        size = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            size *= mesh.shape[a]
        if t.shape[i] % size == 0:
            spec[i] = axes
    return jax.lax.with_sharding_constraint(
        t, jax.sharding.NamedSharding(mesh, P(*spec)))


def constrain_moe(t, dims: dict):
    """MoE dispatch intermediates (experts->tp, groups->dp).  Always on
    under a launcher context: without the expert pin the gather dispatch
    lets SPMD replicate the (G,E,C,D) tensors — measured 15x collective
    regression on granite (EXPERIMENTS.md §Perf)."""
    return constrain_dims(t, dims)


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ------------------------------------------------------------------ linear

def init_linear(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
                in_spec=None, out_spec=None, scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    params = {"w": w.astype(dtype)}
    specs = {"w": P(in_spec, out_spec)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
        specs["b"] = P(out_spec)
    return params, specs


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# ----------------------------------------------------------------- rmsnorm

def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": P(None)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------- embedding

def init_embedding(key, vocab: int, d: int, dtype, rules: AxisRules):
    e = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"table": e.astype(dtype)}, {"table": P(rules.tp, rules.fsdp)}


def embed(params, tokens):
    # gather rows; tokens (B, T) -> (B, T, D)
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    # (B, T, D) @ (D, V) -> logits (B, T, V); fp32 for a stable softmax.
    return jnp.einsum("btd,vd->btv", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


# -------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, Dh); positions: (B, T) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ swiglu

def init_mlp(key, d_model: int, d_ff: int, dtype, rules: AxisRules):
    k1, k2, k3 = jax.random.split(key, 3)
    wi, si = init_linear(k1, d_model, d_ff, dtype,
                         in_spec=rules.fsdp, out_spec=rules.tp)
    wg, sg = init_linear(k2, d_model, d_ff, dtype,
                         in_spec=rules.fsdp, out_spec=rules.tp)
    wo, so = init_linear(k3, d_ff, d_model, dtype,
                         in_spec=rules.tp, out_spec=rules.fsdp)
    return ({"wi": wi, "wg": wg, "wo": wo},
            {"wi": si, "wg": sg, "wo": so})


def mlp(params, x):
    h = jax.nn.silu(linear(params["wg"], x)) * linear(params["wi"], x)
    return linear(params["wo"], h)
