"""Llama-3-405B [arXiv:2407.21783]: 126L dense GQA.  Optimizer moments in
bf16 so params+moments fit 16 GB/chip on the 256-chip single-pod mesh
(topology: ``repro/launch/mesh.py``)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    moment_dtype="bfloat16",
    remat_policy="dots",  # §Perf E: -18% recompute FLOPs, fits HBM
)
