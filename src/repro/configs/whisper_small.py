"""Whisper-small [arXiv:2212.04356]: enc-dec; the conv frontend is a STUB
(input_specs() provides precomputed frame embeddings at d_model, 1500
frames).  Decoder layers: self-attn + cross-attn + MLP."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,              # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    cross_attn_period=1,        # every decoder layer cross-attends
    encoder_layers=12,
    audio_frames=1500,
    attn_seq_shard=True,        # 12 heads don't divide 16-way TP (§Perf)
)
