"""Llama-4-Scout-17B-16E [hf:meta-llama]: MoE top-1 routing, 16 experts.
(The release interleaves a shared expert; we model pure top-1 routed
experts every layer — a deliberate simplification, recorded here so the
config is not mistaken for a faithful replica.)"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    num_experts=16,
    num_experts_per_tok=1,
    moe_d_ff=8192,
    moe_period=1,
    # §Perf defaults (EXPERIMENTS.md): 40 heads don't divide 16-way TP ->
    # sequence-sharded attention; sparse gather dispatch for the MoE.
    attn_seq_shard=True,
    moe_impl="gather",
)
