"""StableLM-3B [hf:stabilityai]: dense, full MHA (kv=32)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
)
