"""Mamba2-370m [arXiv:2405.21060]: pure SSD (state-space duality),
attention-free; O(1) decode state."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=16,          # unused (attention-free); kept for validation
    num_kv_heads=16,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
)
