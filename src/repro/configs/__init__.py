"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full-size ModelConfig; every config is
exercised at full size only through the dry-run (ShapeDtypeStruct — no
allocation) and at reduced size in the smoke tests.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "jamba-v0.1-52b",
    "granite-moe-3b-a800m",
    "llama4-scout-17b-a16e",
    "mamba2-370m",
    "stablelm-3b",
    "llama3-405b",
    "qwen1.5-0.5b",
    "mistral-nemo-12b",
    "llama-3.2-vision-90b",
    "whisper-small",
)


def _module_name(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_module_name(arch)}")
    cfg = mod.CONFIG
    cfg.validate()
    return cfg


def list_archs() -> tuple[str, ...]:
    return ARCHS
