"""Jamba-v0.1-52B [arXiv:2403.19887]: hybrid Mamba+attention 1:7
interleave with MoE every other layer (16 experts, top-2)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    num_experts_per_tok=2,
    moe_period=2,
    attn_layer_period=8,        # 1 attention layer per 8 (1:7)
    ssm_state=16,               # Jamba uses Mamba(1)-style d_state=16
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    # NOTE: moe_impl stays "einsum" here — the gather dispatch inside the
    # 8-layer hybrid scan unit blows up SPMD compile time (>10 min);
    # einsum compiles in ~35 s.  Recorded in EXPERIMENTS.md §Perf.
)
