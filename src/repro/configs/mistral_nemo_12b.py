"""Mistral-Nemo-12B [hf:mistralai]: dense GQA, head_dim 128 (5120/32=160
is NOT the head dim — Nemo pins 128), 128k context."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    remat_policy="dots",  # §Perf E: -18% recompute FLOPs, fits HBM
)
