"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite]: 40 experts top-8 with
narrow (512) expert FFNs in every layer."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    num_experts_per_tok=8,
    moe_d_ff=512,
    moe_period=1,
    # §Perf defaults: 24 heads don't divide 16-way TP; narrow experts
    # want small dispatch groups + sparse gather dispatch.
    attn_seq_shard=True,
    moe_impl="gather",
    moe_group_size=256,
)
