"""Llama-3.2-Vision-90B [hf:meta-llama]: decoder backbone with gated
cross-attention image layers every 5th layer (20 of 100).  The vision
frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, 1601, 1280)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_period=5,
    vision_tokens=1601,
    vision_d=1280,
    moment_dtype="bfloat16",
    remat_policy="dots",  # §Perf E: -18% recompute FLOPs, fits HBM
)
