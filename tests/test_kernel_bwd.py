"""Fused-kernel backward parity tests — hypothesis-free on purpose.

``tests/test_kernels.py`` skips entirely when hypothesis is absent (the
minimal CI env), so the gradient contract of the Pallas backward is
asserted here with plain pytest only: batched (B > 1), uneven N and d
not multiples of the 128 lane width, interpret mode (CPU), fused
fwd+bwd vs the ``kernels/ref.py`` dense oracle AND vs a per-instance
loop, including the ``dtau`` cotangent.  Also hosts the
``softsort_apply_chunked`` tail-padding regression (N=300, chunk=256).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.softsort import softsort_apply_chunked, softsort_matrix
from repro.kernels.ops import softsort_apply, softsort_apply_v1
from repro.kernels.ref import softsort_apply_ref


def _loss_of(apply_fn, a, b):
    def f(w, x, tau):
        y, c = apply_fn(w, x, tau)
        return jnp.sum(y * a) + jnp.sum(c * b)
    return f


def _assert_grads_close(got, want, rtol=1e-4):
    for g, r in zip(got, want):
        scale = float(jnp.max(jnp.abs(r))) + 1e-9
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   atol=rtol * scale)


# ------------------------------------------------- unbatched parity

@pytest.mark.parametrize("n,d", [(64, 3), (100, 2), (300, 7), (129, 17),
                                 (96, 130)])
def test_fused_gradients_match_dense_oracle(n, d):
    """Uneven N and d (not multiples of 128): dw, dx AND dtau."""
    keys = jax.random.split(jax.random.PRNGKey(n * 13 + d), 4)
    w = jax.random.normal(keys[0], (n,)) * 3
    x = jax.random.normal(keys[1], (n, d))
    a = jax.random.normal(keys[2], (n, d))
    b = jax.random.normal(keys[3], (n,))
    gk = jax.grad(_loss_of(softsort_apply, a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    gr = jax.grad(_loss_of(softsort_apply_ref, a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    _assert_grads_close(gk, gr)


def test_fused_forward_matches_dense_oracle():
    n, d = 300, 7
    w = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 2
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    y, c = softsort_apply(w, x, 0.5)
    yr, cr = softsort_apply_ref(w, x, 0.5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=2e-5)


def test_fused_matches_v1_baseline_gradients():
    """The legacy v1 path (3-pass fwd + jnp-scan bwd) and the fused path
    must agree — they implement the same math."""
    n, d = 129, 5
    keys = jax.random.split(jax.random.PRNGKey(77), 4)
    w = jax.random.normal(keys[0], (n,)) * 2
    x = jax.random.normal(keys[1], (n, d))
    a = jax.random.normal(keys[2], (n, d))
    b = jax.random.normal(keys[3], (n,))
    gf = jax.grad(_loss_of(softsort_apply, a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.8))
    gv = jax.grad(_loss_of(softsort_apply_v1, a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.8))
    _assert_grads_close(gf, gv)


# ------------------------------------------------- batched parity

@pytest.mark.parametrize("bsz,n,d", [(3, 100, 7), (2, 300, 2), (4, 64, 130)])
def test_batched_gradients_match_per_instance_loop(bsz, n, d):
    """B > 1: the batched fused fwd+bwd must equal B independent dense
    oracle problems, with dtau summing across instances."""
    keys = jax.random.split(jax.random.PRNGKey(bsz * 1000 + n + d), 4)
    w = jax.random.normal(keys[0], (bsz, n)) * 2
    x = jax.random.normal(keys[1], (bsz, n, d))
    a = jax.random.normal(keys[2], (bsz, n, d))
    b = jax.random.normal(keys[3], (bsz, n))
    tau = jnp.float32(0.7)

    dw, dx, dtau = jax.grad(_loss_of(softsort_apply, a, b),
                            argnums=(0, 1, 2))(w, x, tau)

    dtau_sum = 0.0
    for bi in range(bsz):
        dwi, dxi, dti = jax.grad(_loss_of(softsort_apply_ref, a[bi], b[bi]),
                                 argnums=(0, 1, 2))(w[bi], x[bi], tau)
        _assert_grads_close((dw[bi], dx[bi]), (dwi, dxi))
        dtau_sum += float(dti)
    scale = abs(dtau_sum) + 1e-9
    np.testing.assert_allclose(float(dtau), dtau_sum, atol=1e-4 * scale)


def test_batched_gradients_match_vmapped_unbatched_call():
    """The B-leading batched call and vmap over the unbatched call are
    the same computation."""
    bsz, n, d = 3, 96, 4
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    w = jax.random.normal(keys[0], (bsz, n))
    x = jax.random.normal(keys[1], (bsz, n, d))
    a = jax.random.normal(keys[2], (bsz, n, d))

    def loss_batched(w, x):
        y, _ = softsort_apply(w, x, 0.5)
        return jnp.sum(y * a)

    def loss_vmapped(w, x):
        y, _ = jax.vmap(lambda wi, xi: softsort_apply(wi, xi, 0.5))(w, x)
        return jnp.sum(y * a)

    gb = jax.grad(loss_batched, argnums=(0, 1))(w, x)
    gv = jax.grad(loss_vmapped, argnums=(0, 1))(w, x)
    _assert_grads_close(gb, gv)


def test_colsum_cotangent_only():
    """dc alone (dy = 0) exercises the P @ dc term of the delta pass."""
    n, d = 200, 3
    w = jax.random.normal(jax.random.PRNGKey(21), (n,)) * 2
    x = jax.random.normal(jax.random.PRNGKey(22), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(23), (n,))

    def loss(fn):
        def f(w, x, tau):
            _, c = fn(w, x, tau)
            return jnp.sum(jnp.square(c) * b)
        return f

    gk = jax.grad(loss(softsort_apply), argnums=(0, 2))(
        w, x, jnp.float32(0.4))
    gr = jax.grad(loss(softsort_apply_ref), argnums=(0, 2))(
        w, x, jnp.float32(0.4))
    _assert_grads_close(gk, gr)


# --------------------------------------- chunked tail-padding regression

def test_chunked_tail_padding_matches_dense():
    """N=300, chunk=256 — previously an assertion failure; the tail row
    block now pads and masks, matching the kernel wrapper's contract."""
    n, chunk = 300, 256
    w = jax.random.normal(jax.random.PRNGKey(30), (n,))
    x = jax.random.normal(jax.random.PRNGKey(31), (n, 5))
    p = softsort_matrix(w, 0.7)
    y, cs = softsort_apply_chunked(w, x, 0.7, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(p @ x), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(p.sum(0)),
                               atol=1e-5)


@pytest.mark.parametrize("n,chunk", [(300, 256), (513, 128), (5, 2)])
def test_chunked_tail_padding_gradients(n, chunk):
    x = jax.random.normal(jax.random.PRNGKey(n), (n, 3))
    w = jax.random.normal(jax.random.PRNGKey(n + 1), (n,))

    def loss_chunked(w):
        y, cs = softsort_apply_chunked(w, x, 0.5, chunk=chunk)
        return jnp.sum(y ** 2) + jnp.sum(cs ** 3)

    def loss_dense(w):
        p = softsort_matrix(w, 0.5)
        return jnp.sum((p @ x) ** 2) + jnp.sum(p.sum(0) ** 3)

    np.testing.assert_allclose(np.asarray(jax.grad(loss_chunked)(w)),
                               np.asarray(jax.grad(loss_dense)(w)),
                               atol=1e-4)
