"""Adaptive annealing tier (core/annealing.py + schedule="adaptive").

Hypothesis-free companion of tests/test_properties.py: everything here
runs with the stock container deps, so the adaptive determinism
contract keeps local coverage even where hypothesis is unavailable.

Covers the rung machinery edge cases (``_rung_boundaries`` with more
rungs than rounds, single-round schedules, ``rung_aligned_switch``
landing exactly on a rung / on the final round), the
``AdaptiveController`` unit behavior, and the cross-engine bit-identity
contract: per seed, adaptive runs produce identical results on the
sequential / vmap / shard_map / tournament / kernel paths, and a
controller that never fires reproduces the fixed schedule exactly.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core.annealing import AdaptiveController, adaptive_seg_len
from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    _band_switch_round,
    _rung_boundaries,
    _tau_schedule,
    make_adaptive_controller,
    restart_tournament,
    resolve_band,
    rung_aligned_switch,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.core.softsort import is_valid_permutation
from repro.launch.mesh import make_sort_mesh

N, HW, D = 16, (4, 4), 2

# Always-plateau controller: relative improvement is always < 1.0, so
# every boundary past the first fires a jump — deterministic early
# exits without depending on the loss landscape.
FIRE = dict(schedule="adaptive", patience=1, plateau_rtol=1.0,
            adapt_every=2)
# Never-fire controller: patience larger than the number of rungs.
NEVER = dict(schedule="adaptive", patience=10**6)


def _problems(count, n=N, d=D, seed=0):
    rng = np.random.RandomState(seed)
    return rng.rand(count, n, d).astype(np.float32)


# ---------------------------------------------------- rung machinery

def test_rung_boundaries_basic():
    assert _rung_boundaries(8, 4) == [2, 4, 6, 8]
    assert _rung_boundaries(10, 3) == [3, 7, 10]
    assert _rung_boundaries(5, 1) == [5]


def test_rung_boundaries_more_rungs_than_rounds():
    # n_rungs > rounds: duplicate edges collapse; strictly increasing,
    # last == rounds, at most ``rounds`` rungs survive.
    edges = _rung_boundaries(3, 7)
    assert edges[-1] == 3
    assert all(b > a for a, b in zip(edges, edges[1:]))
    assert len(edges) <= 3
    assert _rung_boundaries(2, 100) == [1, 2]


def test_rung_boundaries_single_round():
    assert _rung_boundaries(1, 1) == [1]
    assert _rung_boundaries(1, 5) == [1]


def test_rung_aligned_switch_no_band_is_never():
    cfg = ShuffleSoftSortConfig(rounds=8, band=None)
    for seg in (1, 2, 4, 8):
        assert rung_aligned_switch(cfg, N, seg) == 8


def test_rung_aligned_switch_snaps_up_to_boundary():
    # A band tight enough to admit banding mid-schedule: check the
    # snapped switch against the model switch for every divisor quantum.
    cfg = ShuffleSoftSortConfig(rounds=8, band=4, band_eps=1e-2,
                                tau_start=2.0, tau_end=0.01)
    switch = _band_switch_round(cfg, N)
    assert 0 < switch < cfg.rounds     # mid-schedule, else the test is vacuous
    assert rung_aligned_switch(cfg, N, 1) == switch
    for seg in (2, 4, 8):
        snapped = rung_aligned_switch(cfg, N, seg)
        assert snapped % seg == 0
        assert switch <= snapped < switch + seg or snapped == cfg.rounds
    # Exactly on a boundary: seg == switch leaves it unmoved.
    if switch in (2, 4):
        assert rung_aligned_switch(cfg, N, switch) == switch


def test_rung_aligned_switch_at_final_round_exactly():
    # A band the model only admits at the coldest temperature: the raw
    # switch can land on rounds - 1 or rounds; snapping with
    # seg == rounds must cap at rounds, never beyond.
    cfg = ShuffleSoftSortConfig(rounds=8, band=4, band_eps=1e-9,
                                tau_start=2.0, tau_end=2.0)
    assert _band_switch_round(cfg, N) == cfg.rounds   # "never"
    for seg in (1, 2, 4, 8):
        assert rung_aligned_switch(cfg, N, seg) == cfg.rounds


def test_rung_aligned_switch_single_round_schedule():
    cfg = ShuffleSoftSortConfig(rounds=1, band=None)
    assert rung_aligned_switch(cfg, N, 1) == 1


# ---------------------------------------------------- adaptive_seg_len

def test_adaptive_seg_len_explicit_divisor():
    assert adaptive_seg_len(
        ShuffleSoftSortConfig(rounds=8, adapt_every=2)) == 2
    assert adaptive_seg_len(
        ShuffleSoftSortConfig(rounds=8, adapt_every=8)) == 8


def test_adaptive_seg_len_rejects_non_divisor():
    with pytest.raises(ValueError, match="adapt_every"):
        adaptive_seg_len(ShuffleSoftSortConfig(rounds=8, adapt_every=3))
    with pytest.raises(ValueError, match="adapt_every"):
        adaptive_seg_len(ShuffleSoftSortConfig(rounds=8, adapt_every=16))


def test_adaptive_seg_len_default_rule():
    # Largest divisor of rounds not exceeding rounds // 8.
    assert adaptive_seg_len(ShuffleSoftSortConfig(rounds=40)) == 5
    assert adaptive_seg_len(ShuffleSoftSortConfig(rounds=64)) == 8
    assert adaptive_seg_len(ShuffleSoftSortConfig(rounds=7)) == 1
    assert adaptive_seg_len(ShuffleSoftSortConfig(rounds=1)) == 1


# ---------------------------------------------------- controller units

def _ctrl(bs=3, rounds=8, seg=2, **kw):
    cfg = ShuffleSoftSortConfig(rounds=rounds, schedule="adaptive",
                                adapt_every=seg, **kw)
    return AdaptiveController(cfg, bs, taus=_tau_schedule(cfg),
                              band=None, seg_len=seg)


def test_controller_validates_config():
    cfg = ShuffleSoftSortConfig(rounds=8, schedule="adaptive")
    taus = _tau_schedule(cfg)
    with pytest.raises(ValueError, match="seg_len"):
        AdaptiveController(cfg, 2, taus=taus, band=None, seg_len=3)
    bad = ShuffleSoftSortConfig(rounds=8, schedule="adaptive", patience=0)
    with pytest.raises(ValueError, match="patience"):
        AdaptiveController(bad, 2, taus=_tau_schedule(bad), band=None,
                           seg_len=2)
    bad = ShuffleSoftSortConfig(rounds=8, schedule="adaptive",
                                ewma_alpha=0.0)
    with pytest.raises(ValueError, match="ewma_alpha"):
        AdaptiveController(bad, 2, taus=_tau_schedule(bad), band=None,
                           seg_len=2)


def test_controller_improving_losses_never_fire():
    c = _ctrl(bs=2, plateau_rtol=1e-3)
    # Halving losses each round: relative improvement stays >> rtol.
    for step in range(4):
        idx = c.live_indices()
        assert idx.tolist() == [0, 1]
        losses = np.full((2, 2), 2.0 ** -(step + 1), np.float32)
        losses[:, 1] /= 2
        d = c.observe(idx, losses)
        assert d.fired == 0 and d.stopped == (2 if step == 3 else 0)
    assert (c.executed == 8).all() and (c.pos == 8).all()
    assert c.done.all() and c.rounds_saved() == 0
    assert [d.boundary for d in c.decisions] == [2, 4, 6, 8]


def test_controller_first_boundary_never_fires():
    # best is inf before the first observe — an instant plateau on the
    # very first rung would fire on zero evidence.
    c = _ctrl(bs=1, patience=1, plateau_rtol=np.inf)
    d = c.observe(np.array([0]), np.ones((1, 2), np.float32))
    assert d.fired == 0
    d = c.observe(np.array([0]), np.ones((1, 2), np.float32))
    assert d.fired == 1


def test_controller_plateau_jump_and_early_stop():
    c = _ctrl(bs=1, patience=1, plateau_rtol=1.0)
    flat = np.ones((1, 2), np.float32)
    c.observe(np.array([0]), flat)            # seed: no fire
    assert c.pos[0] == 2 and not c.done[0]
    c.observe(np.array([0]), flat)            # fire: jump 2 -> pos 6
    assert c.pos[0] == 6 and c.executed[0] == 4 and not c.done[0]
    d = c.observe(np.array([0]), flat)        # fire past the end: stop
    assert d.stopped == 1 and c.done[0]
    assert c.executed[0] == 6 and c.pos[0] == 8
    assert c.rounds_saved() == 2
    assert c.live_indices().size == 0


def test_controller_tau_rows_follow_per_instance_position():
    c = _ctrl(bs=2, patience=1, plateau_rtol=1.0)
    taus = c.taus
    np.testing.assert_array_equal(c.tau_rows(np.array([0, 1])),
                                  np.stack([taus[0:2]] * 2, axis=1))
    c.observe(np.array([0, 1]), np.ones((2, 2), np.float32))
    c.observe(np.array([1]), np.ones((1, 2), np.float32))  # 1 jumps to 6
    np.testing.assert_array_equal(c.tau_rows(np.array([0])),
                                  taus[2:4][:, None])
    np.testing.assert_array_equal(c.tau_rows(np.array([1])),
                                  taus[6:8][:, None])


def test_controller_rejects_observing_stopped_instances():
    c = _ctrl(bs=2, patience=1, plateau_rtol=1.0)
    c.mark_culled([1])
    assert c.live_indices().tolist() == [0]
    with pytest.raises(AssertionError):
        c.observe(np.array([0, 1]), np.ones((2, 2), np.float32))


def test_make_adaptive_controller_wires_schedule_and_band():
    cfg = ShuffleSoftSortConfig(rounds=8, **FIRE, band=4)
    c = make_adaptive_controller(cfg, 5, N)
    assert c.seg_len == 2 and c.band == resolve_band(cfg, N)
    np.testing.assert_array_equal(c.taus, _tau_schedule(cfg))
    assert make_adaptive_controller(cfg, 5, N, seg_len=4).seg_len == 4


# ------------------------------------------- schedule gating + fixed parity

def test_unknown_schedule_rejected_everywhere():
    cfg = ShuffleSoftSortConfig(rounds=2, schedule="bogus")
    x = _problems(1)[0]
    with pytest.raises(ValueError, match="schedule"):
        shuffle_soft_sort(x, HW, cfg)
    with pytest.raises(ValueError, match="schedule"):
        shuffle_soft_sort_batched(x[None], HW, cfg)
    with pytest.raises(ValueError, match="schedule"):
        restart_tournament(x[None], HW, cfg, n_restarts=2)


def test_adaptive_rejects_per_round_callback():
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=1, **NEVER)
    x = _problems(1)[0]
    with pytest.raises(ValueError, match="callback"):
        shuffle_soft_sort(x, HW, cfg, key=jax.random.PRNGKey(0),
                          callback=lambda *a: None)
    with pytest.raises(ValueError, match="callback"):
        shuffle_soft_sort_batched(x[None], HW, cfg,
                                  callback=lambda *a: None)


def test_adaptive_equals_fixed_when_controller_never_fires():
    """The opt-in invariant: schedule='adaptive' whose controller never
    fires (and has no band) is bit-identical to the fixed schedule —
    same orders AND same loss traces, full rounds executed."""
    fixed = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=N)
    adapt = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=N,
                                  **NEVER)
    x = _problems(1, seed=3)[0]
    key = jax.random.PRNGKey(42)
    o_f, s_f, l_f = shuffle_soft_sort(x, HW, fixed, key=key)
    o_a, s_a, l_a = shuffle_soft_sort(x, HW, adapt, key=key)
    np.testing.assert_array_equal(o_f, o_a)
    np.testing.assert_array_equal(s_f, s_a)
    np.testing.assert_array_equal(np.float32(l_f), np.float32(l_a))

    res = shuffle_soft_sort_batched(x[None], HW, adapt, n_restarts=2,
                                    key=key)
    assert (res.rounds_executed == 8).all()
    assert not np.isnan(res.all_losses).any()


# ------------------------------------------- cross-engine bit-identity

def test_adaptive_bit_identical_sequential_vmap_mesh_tournament():
    """The tentpole determinism contract: per seed, the adaptive engine
    produces identical permutations and loss traces on the sequential,
    vmap, shard_map, and (cull-free) tournament paths, early exits
    included."""
    cfg = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=N, **FIRE)
    xs = _problems(3, seed=7)
    keys = jnp_keys = jax.vmap(jax.random.PRNGKey)(np.arange(3))

    res = shuffle_soft_sort_batched(xs, HW, cfg, keys=keys)
    assert res.rounds_executed is not None
    assert (res.rounds_executed < cfg.rounds).all()     # early exits happened

    for i in range(3):
        o, s, l = shuffle_soft_sort(xs[i], HW, cfg, key=jnp_keys[i])
        np.testing.assert_array_equal(o, res.order[i])
        r = int(res.rounds_executed[i, 0])
        assert len(l) == r
        np.testing.assert_array_equal(np.float32(l), res.losses[i, :r])
        assert np.isnan(res.losses[i, r:]).all()        # NaN past the stop

    mesh = make_sort_mesh(min(2, jax.device_count()))
    res_m = shuffle_soft_sort_batched(xs, HW, cfg, keys=keys, mesh=mesh)
    np.testing.assert_array_equal(res.order, res_m.order)
    np.testing.assert_array_equal(res.all_losses, res_m.all_losses)
    np.testing.assert_array_equal(res.rounds_executed, res_m.rounds_executed)

    tr = restart_tournament(xs, HW, cfg, n_restarts=1, keys=keys,
                            cull_fraction=0.0, n_rungs=2)
    np.testing.assert_array_equal(tr.order, res.order)
    np.testing.assert_array_equal(tr.all_losses[:, 0], res.all_losses[:, 0])
    assert tr.rounds_run == int(res.rounds_executed.sum())
    assert tr.rounds_run < tr.rounds_full


def test_adaptive_bit_identical_on_kernel_path():
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=1, chunk=N,
                                use_kernel=True, schedule="adaptive",
                                patience=1, plateau_rtol=1.0,
                                adapt_every=1)
    x = _problems(1, seed=11)[0]
    key = jax.random.PRNGKey(5)
    o_seq, _, l_seq = shuffle_soft_sort(x, HW, cfg, key=key)
    res = shuffle_soft_sort_batched(x[None], HW, cfg, keys=key[None])
    np.testing.assert_array_equal(o_seq, res.order[0])
    r = int(res.rounds_executed[0, 0])
    assert len(l_seq) == r < cfg.rounds
    np.testing.assert_array_equal(np.float32(l_seq), res.losses[0, :r])


def test_adaptive_tournament_culls_and_saves_rounds():
    cfg = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=N, **FIRE)
    xs = _problems(2, seed=13)
    tr = restart_tournament(xs, HW, cfg, n_restarts=4,
                            key=jax.random.PRNGKey(1),
                            cull_fraction=0.5, n_rungs=2)
    for o in tr.order:
        assert is_valid_permutation(o)
    assert tr.survivors[0].shape == (2, 2)               # 4 -> 2 at the cull
    assert tr.rounds_run < tr.rounds_full
    # The winner is one of the survivors and its trace is NaN-free up to
    # its own stop.
    for b in range(2):
        assert tr.best_restart[b] in tr.survivors[-1][b]


def test_measured_band_switch_flips_instances_to_banded():
    """With a loose band_eps the measured tail bound clears immediately:
    instances go banded at the first boundary (long before the
    linear-init model would switch) and the run stays deterministic."""
    cfg = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=N,
                                band=4, band_eps=1e3, schedule="adaptive",
                                patience=10**6, adapt_every=2)
    xs = _problems(2, seed=17)
    keys = jax.vmap(jax.random.PRNGKey)(np.arange(2))
    ctrl = make_adaptive_controller(cfg, 2, N)
    assert ctrl.band is not None and not ctrl.banded.any()

    res1 = shuffle_soft_sort_batched(xs, HW, cfg, keys=keys)
    res2 = shuffle_soft_sort_batched(xs, HW, cfg, keys=keys)
    np.testing.assert_array_equal(res1.order, res2.order)
    np.testing.assert_array_equal(res1.all_losses, res2.all_losses)
    for o in res1.order:
        assert is_valid_permutation(o)

    # The controller itself flips on these keys: drive one observe with
    # real end-of-rung keys via the engine's own controller plumbing.
    from repro.core.shufflesoftsort import _run_adaptive, _prep_instances
    _, b, s, n, keys_fl, xs_t, norms_t, orders = _prep_instances(
        xs, HW, 1, None, keys)
    ctrl = make_adaptive_controller(cfg, b * s, n)
    _run_adaptive(xs_t, orders, keys_fl, norms_t, hw=HW, cfg=cfg,
                  mesh=None, controller=ctrl)
    assert ctrl.banded.all()
    assert sum(d.switched for d in ctrl.decisions) == b * s


def test_adaptive_rounds_saved_accounting():
    cfg = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=N, **FIRE)
    x = _problems(1, seed=19)[0]
    res = shuffle_soft_sort_batched(x[None], HW, cfg,
                                    keys=jax.random.PRNGKey(3)[None])
    executed = int(res.rounds_executed[0, 0])
    assert 0 < executed < cfg.rounds
    n_valid = int((~np.isnan(res.losses[0])).sum())
    assert n_valid == executed
