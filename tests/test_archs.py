"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs.  Full-size
configs are exercised only via the dry-run (no allocation)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import (
    decode_step,
    forward,
    init_model,
    loss_fn,
    make_caches,
    prefill,
    reduced_config,
)

B, T = 2, 16


def _batch(cfg, key=0, t=T):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, t), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, t), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["context"] = jax.random.normal(
            ks[2], (B, cfg.vision_tokens, cfg.vision_d))
    if cfg.is_encdec:
        batch["context"] = jax.random.normal(
            ks[2], (B, cfg.audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_valid(arch):
    cfg = get_config(arch)
    cfg.validate()
    unit, repeats = cfg.block_program()
    assert len(unit) * repeats == cfg.num_layers


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_shapes_no_nans(arch):
    cfg = reduced_config(get_config(arch))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    out = forward(params, cfg, batch["tokens"], batch.get("context"))
    assert out.logits.shape == (B, T, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(out.logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    """One loss+grad+sgd-update step: loss finite, grads finite."""
    cfg = reduced_config(get_config(arch))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(g))) for g in leaves)
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = loss_fn(new_params, cfg, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize(
    "arch", ["qwen1.5-0.5b", "mamba2-370m", "jamba-v0.1-52b",
             "llama-3.2-vision-90b", "granite-moe-3b-a800m", "whisper-small"])
def test_prefill_decode_consistency(arch):
    """prefill(T) + decode(T) == forward(T+1) at the last position.
    MoE archs run with no-drop capacity so the comparison is exact."""
    cfg = reduced_config(get_config(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    toks = batch["tokens"]
    ctx = batch.get("context")

    lg_pref, caches = prefill(params, cfg, toks, ctx)
    out_full = forward(params, cfg, toks, ctx)
    np.testing.assert_allclose(
        np.asarray(lg_pref), np.asarray(out_full.logits[:, -1:, :]),
        atol=1e-2)

    next_tok = jax.random.randint(jax.random.PRNGKey(7), (B, 1), 0,
                                  cfg.vocab_size)
    toks2 = jnp.concatenate([toks, next_tok], axis=1)

    def pad_cache(c):
        if c.ndim >= 4 and c.shape[2] == T:          # attn caches: pad S
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, 8)
            return jnp.pad(c, pad)
        return c

    caches_p = jax.tree.map(pad_cache, caches)
    lg_dec, new_caches = decode_step(params, cfg, next_tok, caches_p,
                                     jnp.int32(T), ctx)
    out2 = forward(params, cfg, toks2, ctx)
    np.testing.assert_allclose(
        np.asarray(lg_dec), np.asarray(out2.logits[:, -1:, :]), atol=1e-2)
    # caches structurally unchanged
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches_p)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-370m"])
def test_decode_from_empty_cache_greedy_loop(arch):
    """Greedy decode 8 tokens from an empty cache — shapes + finiteness."""
    cfg = reduced_config(get_config(arch))
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    caches = make_caches(cfg, B, 16, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(8):
        logits, caches = decode_step(params, cfg, tok, caches,
                                     jnp.int32(pos))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits)))
        tok = jnp.argmax(logits[:, :, :], axis=-1).astype(jnp.int32)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 some tokens drop but output stays finite & bounded."""
    cfg = reduced_config(get_config("granite-moe-3b-a800m"))
    cfg = dataclasses.replace(cfg, capacity_factor=1.0)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    out = forward(params, cfg, batch["tokens"])
    assert np.all(np.isfinite(np.asarray(out.logits)))


def test_mamba_chunked_equals_sequential_decode():
    from repro.models.ssm import (empty_ssm_cache, init_mamba,
                                  mamba_decode, mamba_forward)
    from repro.models.layers import AxisRules
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      ssm_state=16, ssm_head_dim=8, ssm_expand=2,
                      ssm_chunk=8, param_dtype="float32")
    params, _ = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32,
                           AxisRules())
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32))
    y_chunked = mamba_forward(params, cfg, x)
    c = empty_ssm_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(24):
        yt, c = mamba_decode(params, cfg, x[:, t:t + 1], c)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_chunked), atol=1e-3)


def test_mamba_unaligned_seq_padding_is_noop():
    """T not divisible by chunk: padded result == unpadded chunk=T run."""
    import dataclasses as dc
    from repro.models.ssm import init_mamba, mamba_forward
    from repro.models.layers import AxisRules
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=64,
                      ssm_state=16, ssm_head_dim=8, ssm_expand=2,
                      ssm_chunk=8, param_dtype="float32")
    params, _ = init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32,
                           AxisRules())
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 19, 32))
    y1 = mamba_forward(params, cfg, x)                     # padded to 24
    cfg2 = dc.replace(cfg, ssm_chunk=19)
    y2 = mamba_forward(params, cfg2, x)                    # single chunk
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
