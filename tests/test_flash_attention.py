"""Flash-attention Pallas kernel: shape/GQA/causal sweeps + grads vs the
pure-jnp oracle (interpret mode on CPU; TPU is the target)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # degrade gracefully where absent
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.flash_ops import flash_attention, flash_attention_ref


CASES = [
    # (B, Tq, S, H, Hkv, Dh, causal)
    (2, 64, 64, 4, 2, 32, True),       # GQA train-like
    (1, 100, 100, 5, 5, 16, True),     # MHA, unaligned lengths
    (2, 1, 128, 8, 2, 64, True),       # decode: one query vs cache
    (2, 48, 80, 6, 3, 32, False),      # cross-attention (no mask)
    (1, 256, 256, 2, 1, 128, True),    # MQA, lane-aligned
]


@pytest.mark.parametrize("b,tq,s,h,hkv,dh,causal", CASES)
def test_forward_matches_ref(b, tq, s, h, hkv, dh, causal):
    ks = jax.random.split(jax.random.PRNGKey(b * tq + h), 3)
    q = jax.random.normal(ks[0], (b, tq, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    off = s - tq if causal else 0
    out = flash_attention(q, k, v, causal, off)
    ref = flash_attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("blocks", [(32, 128), (128, 128), (8, 256)])
def test_block_shape_sweep(blocks):
    bq, bk = blocks
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 96, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 96, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 96, 2, 32))
    out = flash_attention(q, k, v, True, 0, bq, bk)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 32)).astype(dtype)
    out = flash_attention(q, k, v)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=tol)


def test_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = jax.random.normal(ks[0], (1, 40, 4, 16))
    k = jax.random.normal(ks[1], (1, 40, 2, 16))
    v = jax.random.normal(ks[2], (1, 40, 2, 16))
    a = jax.random.normal(ks[3], (1, 40, 4, 16))

    g1 = jax.grad(lambda q, k, v: jnp.sum(
        flash_attention(q, k, v, True, 0, 128, 128, 16) * a),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(
        flash_attention_ref(q, k, v, causal=True) * a),
        argnums=(0, 1, 2))(q, k, v)
    for x, y in zip(g1, g2):
        scale = float(jnp.max(jnp.abs(y))) + 1e-9
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=2e-3 * scale)


@given(st.integers(1, 2), st.integers(1, 3), st.sampled_from([1, 2, 4]))
@settings(max_examples=8, deadline=None)
def test_property_rows_are_convex_combinations(b, g, rep):
    """Attention outputs lie in the convex hull of V rows: with V == 1
    everywhere the output is exactly 1."""
    h = g * rep
    q = jax.random.normal(jax.random.PRNGKey(g), (b, 16, h, 8))
    k = jax.random.normal(jax.random.PRNGKey(g + 1), (b, 16, g, 8))
    v = jnp.ones((b, 16, g, 8))
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.ones_like(out), atol=1e-5)
