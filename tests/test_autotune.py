"""Autotune subsystem tests — hypothesis-free.

Covers the round-trip contract from the PR's acceptance criteria: a
cold search WRITES the table, a warm dispatch READS it without
re-searching (``lookup_blocks`` has no search path at all — it is a
pure table read with a hardcoded fallback), corrupt or missing tables
degrade to the safe fallback instead of failing dispatch, and the
committed table passes the ``tools/check_bench.py`` schema (including
the winner-in-candidate-grid rule).
"""
import importlib.util
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.autotune import (
    FALLBACK,
    SMOKE_CANDIDATES,
    lookup_blocks,
    search_cell,
    write_table,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(REPO, "tools", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_cold_search_writes_warm_lookup_reads(tmp_path):
    """Cold search -> committed winners; warm dispatch reads them back
    exactly, with zero re-search (lookup is a pure table read)."""
    path = str(tmp_path / "table.json")
    rows = [
        search_cell("fused", 128, 4, 0, "float32",
                    SMOKE_CANDIDATES["fused"], reps=1),
        search_cell("banded", 256, 4, 48, "bfloat16",
                    SMOKE_CANDIDATES["banded"], reps=1),
    ]
    write_table(rows, SMOKE_CANDIDATES, path)

    got = lookup_blocks("fused", 128, 4, dtype="float32", path=path)
    want = tuple(rows[0]["winner"])
    assert got == (want if len(want) > 1 else (want[0], want[0]))

    got_b = lookup_blocks("banded", 256, 4, k=48, dtype="bfloat16",
                          path=path)
    assert got_b[0] == rows[1]["winner"][0]

    # The timings recorded cover every (deduplicated) candidate.
    for row in rows:
        assert set(row["candidate_s"]) == {
            "x".join(str(v) for v in c) if isinstance(c, (list, tuple))
            else str(c)
            for c in SMOKE_CANDIDATES[row["tier"]]}


def test_write_table_merges_across_backends(tmp_path):
    """Re-tuning must MERGE into the table, not replace it: rows from
    other backends survive, a re-searched cell replaces its old row,
    and candidate grids union (so a narrow re-tune can't strand
    committed winners outside the grid)."""
    path = str(tmp_path / "merge.json")
    r_cpu = {"tier": "fused", "N": 64, "d": 2, "K": 0, "dtype": "float32",
             "backend": "cpu", "winner": [128, 128], "winner_s": 1.0,
             "candidate_s": {"128x128": 1.0}}
    write_table([r_cpu], SMOKE_CANDIDATES, path)
    r_tpu = dict(r_cpu, backend="tpu", winner=[256, 256],
                 candidate_s={"256x256": 1.0})
    write_table([r_tpu], {"fused": [(256, 256)]}, path)
    with open(path) as f:
        doc = json.load(f)
    assert sorted(c["backend"] for c in doc["cells"]) == ["cpu", "tpu"]
    # union kept the original grid alongside the narrow re-tune's
    grid = {tuple(c) for c in doc["candidates"]["fused"]}
    assert (128, 128) in grid and (256, 256) in grid
    # re-searching the same cell replaces its row
    write_table([dict(r_cpu, winner=[256, 256],
                      candidate_s={"256x256": 0.5})],
                SMOKE_CANDIDATES, path)
    with open(path) as f:
        doc = json.load(f)
    assert len(doc["cells"]) == 2
    # (lookup honours only this host's backend, so assert row content
    # directly rather than through lookup_blocks)
    cpu_row = [c for c in doc["cells"] if c["backend"] == "cpu"][0]
    assert cpu_row["winner"] == [256, 256]


def test_lookup_misses_fall_back(tmp_path):
    """Unknown shapes, unknown dtypes, missing files, and corrupt JSON
    all resolve to the hardcoded fallback — dispatch never fails."""
    assert lookup_blocks("fused", 7777, 3,
                         path="/nonexistent/x.json") == FALLBACK["fused"]
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert lookup_blocks("banded", 128, 3, k=16,
                         path=str(bad)) == FALLBACK["banded"]
    wrong = tmp_path / "wrong.json"
    wrong.write_text(json.dumps({"bench": "something_else", "cells": []}))
    assert lookup_blocks("fused", 128, 3,
                         path=str(wrong)) == FALLBACK["fused"]


def test_lookup_keys_are_shape_dtype_backend_specific(tmp_path):
    path = str(tmp_path / "t.json")
    row = {"tier": "fused", "N": 512, "d": 8, "K": 0, "dtype": "bfloat16",
           "backend": jax.default_backend(), "winner": [128, 128],
           "winner_s": 1.0, "candidate_s": {"128x128": 1.0}}
    write_table([row], SMOKE_CANDIDATES, path)
    assert lookup_blocks("fused", 512, 8, dtype="bfloat16",
                         path=path) == (128, 128)
    # Different dtype / N / d miss to the fallback.
    assert lookup_blocks("fused", 512, 8, dtype="float32",
                         path=path) == FALLBACK["fused"]
    assert lookup_blocks("fused", 1024, 8, dtype="bfloat16",
                         path=path) == FALLBACK["fused"]


def test_committed_table_passes_schema_and_is_consulted():
    """The committed table must exist, validate under check_bench's
    autotune schema, and be what production dispatch reads."""
    assert os.path.exists(autotune.TABLE_PATH), (
        "committed autotune table missing — run "
        "`python -m repro.kernels.autotune`")
    cb = _load_check_bench()
    errors = cb.check_file(autotune.TABLE_PATH, tol=2e-3, tol_bf16=2e-2)
    assert not errors, errors

    with open(autotune.TABLE_PATH) as f:
        doc = json.load(f)
    # Every committed cell round-trips through the production lookup
    # (when its backend matches this host's).
    backend = jax.default_backend()
    checked = 0
    for cell in doc["cells"]:
        if cell["backend"] != backend:
            continue
        got = lookup_blocks(cell["tier"], cell["N"], cell["d"],
                            k=cell["K"], dtype=cell["dtype"])
        want = tuple(cell["winner"])
        assert got == (want if len(want) > 1 else (want[0], want[0]))
        checked += 1
    assert checked or all(c["backend"] != backend for c in doc["cells"])


def test_winner_blocks_compute_identical_results():
    """Block size is pure performance: any candidate tiling computes the
    same math (so consulting the table can never perturb results beyond
    the fixed choice it pins)."""
    from repro.kernels.ops import softsort_apply
    w = jax.random.normal(jax.random.PRNGKey(0), (300,)) * 2
    x = jax.random.normal(jax.random.PRNGKey(1), (300, 5))
    y_ref, c_ref = softsort_apply(w, x, 0.5, 256, 256)
    for br, bc in [(128, 128), (128, 256), (256, 128)]:
        y, c = softsort_apply(w, x, 0.5, br, bc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-6)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   atol=2e-6)
