"""Fault tolerance / elastic / compression / SOG-codec tests."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.compression import compress_gradients, init_compression
from repro.runtime.fault_tolerance import (
    FaultInjector,
    TrainSupervisor,
    WorkerFailure,
)
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.sog_compress import (
    compress_checkpoint,
    sog_compress_tensor,
    sog_decompress_tensor,
)


# ------------------------------------------------------------- checkpoint

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 8)),
            "opt": (jnp.zeros((8, 8)), jnp.int32(3)),
            "blocks": ({"a": jnp.ones((2, 3))},)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    st = _state()
    mgr.save(10, st)
    restored, step = mgr.restore(st)
    assert step == 10
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    st = _state()
    for s in (1, 2, 3, 4):
        mgr.save(s, st)
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial_on_existing(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    st = _state()
    mgr.save(1, st)
    # tmp dir from an interrupted save must not shadow a published one
    os.makedirs(tmp_path / "tmp-99", exist_ok=True)
    assert mgr.latest_step() == 1
    restored, _ = mgr.restore(st)
    assert restored is not None


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    st = _state()
    mgr.save(5, st)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_stale_tmp_swept_on_init(tmp_path):
    """A crash mid-save strands tmp-<step> staging dirs; opening a
    manager over the directory must sweep them (they never published,
    so they are garbage by definition)."""
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _state())
    os.makedirs(tmp_path / "tmp-2")
    with open(tmp_path / "tmp-2" / "arrays.npz", "w") as f:
        f.write("half-written garbage")
    mgr2 = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    assert not (tmp_path / "tmp-2").exists()
    assert mgr2.latest_step() == 1          # published steps untouched
    restored, _ = mgr2.restore(_state())
    assert restored is not None


def test_checkpoint_restore_num_leaves_mismatch_is_typed(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _state())
    like = dict(_state(), extra_leaf=jnp.zeros(2))
    with pytest.raises(ValueError, match="layout changed"):
        mgr.restore(like)


def test_checkpoint_keep_k_gc_under_async_saves(tmp_path):
    """Keep-k GC with the async writer: save() serializes one in-flight
    write at a time, so a burst of async saves must still end with
    exactly the newest k checkpoints on disk, no torn tmp dirs."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    st = _state()
    for s in range(1, 6):
        mgr.save(s, st)
    mgr.wait()
    assert mgr.all_steps() == [4, 5]
    assert not [n for n in os.listdir(tmp_path) if n.startswith("tmp-")]
    restored, step = mgr.restore(st)
    assert step == 5


def test_checkpoint_restore_casts_to_like_dtype(tmp_path):
    """restore() casts each leaf to the like-leaf's dtype when it has
    one — the mixed-precision resume path — and leaves dtype-less
    (plain int) like-leaves uncast."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    st = {"w": np.arange(4, dtype=np.float32),
          "k": np.array([1, 2], np.uint32)}
    mgr.save(1, st)
    like = {"w": np.zeros(4, np.float64), "k": 0}
    restored, _ = mgr.restore(like)
    assert restored["w"].dtype == np.float64       # cast to like
    assert restored["k"].dtype == np.uint32        # int leaf: uncast
    np.testing.assert_array_equal(restored["w"], st["w"])
    np.testing.assert_array_equal(restored["k"], st["k"])


def test_checkpoint_resharding_on_load(tmp_path):
    """Elastic restart: restore with explicit (1-device) shardings."""
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    st = _state()
    mgr.save(7, st)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda a: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), st)
    restored, step = mgr.restore(st, shardings=sh)
    assert step == 7
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf.sharding, jax.sharding.NamedSharding)


# ------------------------------------------------------------- supervisor

def _quadratic_problem():
    """Tiny convex problem so convergence is checkable."""
    target = jnp.array([1.0, -2.0, 3.0])

    @jax.jit
    def step(state, batch):
        w = state["w"]
        g = 2 * (w - target)
        w = w - 0.1 * g
        return {"w": w}, {"loss": jnp.sum((w - target) ** 2)}

    return step, {"w": jnp.zeros(3)}


def test_supervisor_runs_and_checkpoints(tmp_path):
    step, state0 = _quadratic_problem()
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    sup = TrainSupervisor(step, lambda s: None, mgr, checkpoint_every=10)
    state, step_idx = sup.run(state0, 0, 50)
    assert step_idx == 50
    assert mgr.latest_step() == 50
    assert float(jnp.sum((state["w"] - jnp.array([1., -2., 3.])) ** 2)) < 1e-3


def test_supervisor_recovers_from_failures(tmp_path):
    base_step, state0 = _quadratic_problem()
    fail_at = {15, 27}

    calls = {"n": 0}

    def flaky_step(state, batch):
        calls["n"] += 1
        if calls["n"] in fail_at:
            raise WorkerFailure(f"injected at call {calls['n']}")
        return base_step(state, batch)

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    sup = TrainSupervisor(flaky_step, lambda s: None, mgr,
                          checkpoint_every=5)
    state, step_idx = sup.run(state0, 0, 40)
    assert step_idx == 40
    assert sup.restarts == 2
    assert float(jnp.sum((state["w"] - jnp.array([1., -2., 3.])) ** 2)) < 1e-3


def test_supervisor_resumes_from_existing_checkpoint(tmp_path):
    step, state0 = _quadratic_problem()
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    sup = TrainSupervisor(step, lambda s: None, mgr, checkpoint_every=10)
    sup.run(state0, 0, 20)
    # new supervisor, same dir: must resume at 20, not redo work
    sup2 = TrainSupervisor(step, lambda s: None, mgr, checkpoint_every=10)
    _, step_idx = sup2.run(state0, 0, 30)
    assert step_idx == 30


def test_supervisor_failure_before_first_checkpoint_restores_state(tmp_path):
    """Regression: a failure BEFORE the first checkpoint used to reset
    only the step counter while keeping the partially-advanced state —
    the retried run then advanced the counter state twice for the early
    steps.  The restart must replay from the INITIAL state."""
    calls = {"n": 0}

    def counting_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:                # fail before any checkpoint
            raise WorkerFailure("early failure")
        return {"count": state["count"] + 1}, {"count": state["count"]}

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    sup = TrainSupervisor(counting_step, lambda s: None, mgr,
                          checkpoint_every=100)
    state, step_idx = sup.run({"count": jnp.int32(0)}, 0, 10)
    assert step_idx == 10
    assert sup.restarts == 1
    # 2 steps advanced + failed attempt discarded + 10 clean steps:
    # final count must equal a clean run's, not 2 + 10.
    assert int(state["count"]) == 10


def test_fault_injector_thread_safe():
    """Concurrent dispatches must draw unique call indices: the chaos
    schedule fires each injected fault exactly once, and the counters
    add up, under heavy thread contention."""
    import threading

    inj = FaultInjector(lambda: "ok", fail_calls={5, 50, 500},
                        delay_calls={10: 0.0, 100: 0.0})
    outcomes = {"faults": 0, "ok": 0}
    lock = threading.Lock()

    def worker():
        for _ in range(100):
            try:
                inj()
            except WorkerFailure:
                with lock:
                    outcomes["faults"] += 1
            else:
                with lock:
                    outcomes["ok"] += 1

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert inj.calls == 800
    assert inj.faults == 3 and outcomes["faults"] == 3
    assert inj.delays == 2
    assert outcomes["ok"] == 797


# -------------------------------------------------------------- straggler

def test_straggler_detection():
    mon = StragglerMonitor(z=3.0, min_ratio=1.5, warmup=3)
    for i in range(20):
        mon.record(i, 0.1 + 0.001 * (i % 3))
    assert mon.flagged == []
    assert mon.record(20, 0.5)          # 5x slower: flagged
    assert mon.flagged and mon.flagged[0][0] == 20
    # baseline not poisoned by the outlier
    assert mon.mean < 0.12


def test_straggler_callback_fires():
    events = []
    mon = StragglerMonitor(z=3.0, warmup=3,
                           on_straggler=lambda s, dt, m: events.append(s))
    for i in range(10):
        mon.record(i, 0.05)
    mon.record(10, 1.0)
    assert events == [10]


def test_straggler_warmup_only_stream_never_flags():
    """A stream that ends inside the warmup window primes the EWMA but
    can never flag — even a wildly slow step is just more priming."""
    mon = StragglerMonitor(z=3.0, min_ratio=1.5, warmup=5)
    dts = [0.1, 0.1, 50.0, 0.1, 0.1]       # outlier inside warmup
    assert [mon.record(i, dt) for i, dt in enumerate(dts)] == [False] * 5
    assert mon.flagged == []
    assert mon.count == 5
    # warmup priming is a plain running mean over everything seen
    np.testing.assert_allclose(mon.mean, np.mean(dts), rtol=1e-12)


def test_straggler_first_post_warmup_step_can_flag():
    """The very first step after warmup is already judged against the
    primed baseline — no grace period beyond ``warmup``."""
    mon = StragglerMonitor(z=3.0, min_ratio=1.5, warmup=3)
    for i in range(3):
        mon.record(i, 0.1)
    assert mon.record(3, 5.0)              # step warmup+1, flagged
    assert mon.flagged == [(3, 5.0)]
    # and a healthy first post-warmup step does NOT flag
    mon2 = StragglerMonitor(z=3.0, min_ratio=1.5, warmup=3)
    for i in range(3):
        mon2.record(i, 0.1)
    assert not mon2.record(3, 0.1)


def test_straggler_baseline_updates_from_healthy_steps_only():
    """Flagged steps never enter the EWMA: after a burst of stragglers
    the mean is exactly what the healthy-only stream would produce."""
    mon = StragglerMonitor(z=3.0, min_ratio=1.5, alpha=0.05, warmup=3)
    twin = StragglerMonitor(z=3.0, min_ratio=1.5, alpha=0.05, warmup=3)
    healthy = [0.1, 0.1, 0.1, 0.11, 0.09, 0.1, 0.12, 0.1]
    mixed = healthy[:4] + [2.0, 3.0, 2.5] + healthy[4:]
    for i, dt in enumerate(mixed):
        mon.record(i, dt)
    for i, dt in enumerate(healthy):
        twin.record(i, dt)
    assert len(mon.flagged) == 3
    assert mon.mean == twin.mean           # bit-identical, not approx
    assert mon.var == twin.var


# ------------------------------------------------------------ compression

def test_int8_error_feedback_reduces_bias():
    k = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(k, (256,))}
    state = init_compression(grads)
    acc_raw = jnp.zeros((256,))
    acc_deq = jnp.zeros((256,))
    for i in range(50):
        g = {"w": grads["w"] * (1.0 + 0.01 * i)}
        deq, state, _ = compress_gradients(g, state)
        acc_raw += g["w"]
        acc_deq += deq["w"]
    # error feedback: accumulated compressed grads track accumulated raw
    rel = float(jnp.linalg.norm(acc_deq - acc_raw)
                / jnp.linalg.norm(acc_raw))
    assert rel < 1e-2, rel


def test_compressed_training_converges():
    target = jnp.array([0.5, -1.5, 2.5, 0.0])
    w = jnp.zeros(4)
    state = init_compression({"w": w})
    for _ in range(300):
        g = {"w": 2 * (w - target)}
        deq, state, _ = compress_gradients(g, state)
        w = w - 0.05 * deq["w"]
    assert float(jnp.sum((w - target) ** 2)) < 1e-4


# --------------------------------------------------------------- SOG codec

def _structured_weight(d=48, f=256, seed=0):
    """Low-rank + noise: columns have correlated structure (like trained
    nets) so there is something for the sorter to exploit."""
    rng = np.random.RandomState(seed)
    u = rng.randn(d, 4)
    v = rng.randn(4, f)
    return (u @ v + 0.1 * rng.randn(d, f)).astype(np.float32)


def test_sog_tensor_roundtrip_exact_at_int8():
    w = _structured_weight()
    blob = sog_compress_tensor(w, sort_rounds=60)
    rec = sog_decompress_tensor(blob)
    q_err = np.max(np.abs(rec - w))
    # exact at the int8 quantization level
    assert q_err <= (np.max(np.abs(w)) / 127.0) * 1.01 + 1e-6


def test_sog_grid_never_degenerates_to_a_line():
    """Prime F used to collapse the sorting grid to 1 x F, starving the
    neighbor loss of its second dimension; now those F get a padded
    near-square grid (h * w >= F with fewer than one extra row)."""
    from repro.runtime.sog_compress import _grid_hw

    for n in (97, 113, 178, 254, 1009):    # primes and 2*prime shapes
        h, w = _grid_hw(n)
        assert h > 1, (n, h, w)
        assert w <= 2 * h, (n, h, w)       # near-square
        assert h * w >= n and h * w - n < h, (n, h, w)
    for n in (64, 100, 256, 12):           # composites keep exact grids
        h, w = _grid_hw(n)
        assert h * w == n, (n, h, w)


def test_sog_prime_column_count_roundtrips():
    w = _structured_weight(d=32, f=97)     # F=97 is prime
    blob = sog_compress_tensor(w, sort_rounds=30)
    assert sorted(blob["perm"].tolist()) == list(range(97))
    rec = sog_decompress_tensor(blob)
    assert rec.shape == w.shape
    q_err = np.max(np.abs(rec - w))
    assert q_err <= (np.max(np.abs(w)) / 127.0) * 1.01 + 1e-6


def test_sog_sorting_beats_unsorted_baseline():
    # larger tensor so the stored permutation (4F bytes) amortizes;
    # see EXPERIMENTS.md §SOG for the measured ~10% deflate gain
    w = _structured_weight(d=256, f=256)
    blob = sog_compress_tensor(w, sort_rounds=200)
    assert blob["bytes"] < blob["baseline_bytes"], (
        blob["bytes"], blob["baseline_bytes"])


def test_sog_checkpoint_pipeline():
    params = {
        "wq": jnp.asarray(_structured_weight(32, 128, 1)),
        "norm": jnp.ones((32,)),            # skipped (1-D)
        "emb": jnp.asarray(_structured_weight(16, 256, 2)),
    }
    out = compress_checkpoint(params, min_cols=64, sort_rounds=40)
    st = out["stats"]
    assert st["ratio_vs_raw"] > 2.0        # int8+deflate vs f32
    assert st["sog_bytes"] > 0
    blobs = [b for b in out["blobs"] if b is not None]
    assert len(blobs) == 2
