"""Banded SoftSort-apply tier tests — hypothesis-free on purpose.

Covers the three layers the banded tier ships at:

  * the windowed pure-jnp oracle ``core.softsort.softsort_apply_banded``
    vs the dense matrix (within the analytic ``band_tail_bound``) and
    the bound itself as a true upper bound on dropped mass;
  * the band-grid Pallas kernels ``kernels.ops.softsort_apply_banded``
    vs the oracle — EXACT parity (same truncated math), forward and
    gradients including the dtau cotangent, uneven N/d, B > 1, and the
    band >= N-1 fallback onto the fused dense path;
  * the tau-adaptive dispatcher: switch-round model boundary, engine
    bit-identity (sequential vs batched) across a mid-schedule
    dense->banded switch, on both the jnp and kernel tiers.

Also hosts the ``descending`` parity tests for every apply
implementation (the flag the chunked path was missing).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.softsort import (
    band_tail_bound,
    is_valid_permutation,
    softsort_apply_banded,
    softsort_apply_chunked,
    softsort_matrix,
)
from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    _band_switch_round,
    resolve_band,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.kernels.ops import softsort_apply
from repro.kernels.ops import softsort_apply_banded as kernel_banded
from repro.kernels.ref import softsort_apply_ref


def _arange_keys(key, n, bsz=None):
    """Shuffled arange — the trainer's per-round linear init, the
    operating regime the band targets (unit rank gaps, tiny tail)."""
    if bsz is None:
        return jax.random.permutation(key, jnp.arange(n, dtype=jnp.float32))
    return jax.vmap(lambda k: jax.random.permutation(
        k, jnp.arange(n, dtype=jnp.float32)))(jax.random.split(key, bsz))


def _loss_of(apply_fn, a, b):
    def f(w, x, tau):
        y, c = apply_fn(w, x, tau)
        return jnp.sum(y * a) + jnp.sum(c * b)
    return f


def _assert_close(got, want, rtol=1e-4):
    scale = float(jnp.max(jnp.abs(want))) + 1e-9
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=rtol * scale)


# ------------------------------------------------ oracle vs dense + bound

@pytest.mark.parametrize("n,d,k,tau", [(100, 3, 16, 0.5), (300, 7, 40, 0.5),
                                       (129, 5, 8, 0.3), (64, 2, 63, 1.0)])
def test_banded_oracle_within_tail_bound_of_dense(n, d, k, tau):
    w = _arange_keys(jax.random.PRNGKey(n + k), n)
    x = jax.random.normal(jax.random.PRNGKey(n + 1), (n, d))
    y_ref, c_ref = softsort_apply_ref(w, x, tau)
    y, c = softsort_apply_banded(w, x, tau, k)
    bound = float(band_tail_bound(w, tau, k))
    # Each row drops <= bound probability mass; y rows are convex-ish
    # combinations of payload rows, so the output error is bounded by
    # (dropped + renormalization) * payload scale ~ 2 * bound * max|x|.
    slack = 2.0 * bound * float(jnp.max(jnp.abs(x))) + 5e-6
    assert float(jnp.max(jnp.abs(y - y_ref))) <= slack
    assert float(jnp.max(jnp.abs(c - c_ref))) <= 2.0 * bound + 5e-6


def test_band_tail_bound_upper_bounds_dropped_mass():
    """The analytic bound must dominate the actually dropped mass on
    arbitrary (non-arange) keys, including hot taus where it is loose."""
    for seed, tau, k in [(0, 1.3, 6), (1, 0.4, 6), (2, 2.5, 12),
                         (3, 0.1, 3), (4, 0.7, 20)]:
        n = 80
        w = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * 2
        perm = jnp.argsort(w)
        ps = softsort_matrix(w, tau)[:, perm]      # columns in rank order
        ii = jnp.arange(n)
        out_of_band = jnp.abs(ii[:, None] - ii[None, :]) > k
        dropped = float(jnp.max(
            jnp.sum(jnp.where(out_of_band, ps, 0.0), axis=1)))
        bound = float(band_tail_bound(w, tau, k))
        assert dropped <= bound + 1e-6, (seed, tau, k, dropped, bound)


def test_band_tail_bound_batched_and_degenerate():
    w = _arange_keys(jax.random.PRNGKey(0), 50, bsz=3)
    b = band_tail_bound(w, 0.5, 8)
    assert b.shape == (3,) and bool(jnp.all(b >= 0))
    assert float(jnp.max(band_tail_bound(w, 0.5, 49))) == 0.0


# ------------------------------------------- kernel vs oracle (exact)

@pytest.mark.parametrize("bsz,n,d,k", [(1, 300, 7, 40), (1, 129, 17, 16),
                                       (3, 100, 2, 8), (2, 260, 5, 96)])
def test_banded_kernel_forward_matches_oracle(bsz, n, d, k):
    keys = jax.random.split(jax.random.PRNGKey(n * 13 + d + k), 2)
    w = _arange_keys(keys[0], n, bsz=bsz)
    x = jax.random.normal(keys[1], (bsz, n, d))
    if bsz == 1:
        w, x = w[0], x[0]
    yk, ck = kernel_banded(w, x, 0.5, k)
    yo, co = softsort_apply_banded(w, x, 0.5, k)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yo), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ck), np.asarray(co), atol=2e-5)


@pytest.mark.parametrize("n,d,k", [(200, 5, 24), (129, 17, 16), (300, 2, 100)])
def test_banded_kernel_gradients_match_oracle(n, d, k):
    """dw, dx AND dtau — the full cotangent surface, uneven N and d."""
    keys = jax.random.split(jax.random.PRNGKey(n + d + k), 4)
    w = _arange_keys(keys[0], n)
    x = jax.random.normal(keys[1], (n, d))
    a = jax.random.normal(keys[2], (n, d))
    b = jax.random.normal(keys[3], (n,))
    gk = jax.grad(_loss_of(lambda w, x, t: kernel_banded(w, x, t, k), a, b),
                  argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    go = jax.grad(_loss_of(
        lambda w, x, t: softsort_apply_banded(w, x, t, k), a, b),
        argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    for g1, g2 in zip(gk, go):
        _assert_close(g1, g2)


def test_banded_kernel_batched_gradients_match_per_instance():
    bsz, n, d, k = 3, 100, 4, 12
    keys = jax.random.split(jax.random.PRNGKey(9), 4)
    w = _arange_keys(keys[0], n, bsz=bsz)
    x = jax.random.normal(keys[1], (bsz, n, d))
    a = jax.random.normal(keys[2], (bsz, n, d))
    b = jax.random.normal(keys[3], (bsz, n))
    tau = jnp.float32(0.7)
    dw, dx, dtau = jax.grad(
        _loss_of(lambda w, x, t: kernel_banded(w, x, t, k), a, b),
        argnums=(0, 1, 2))(w, x, tau)
    dtau_sum = 0.0
    for bi in range(bsz):
        dwi, dxi, dti = jax.grad(
            _loss_of(lambda w, x, t: softsort_apply_banded(w, x, t, k),
                     a[bi], b[bi]),
            argnums=(0, 1, 2))(w[bi], x[bi], tau)
        _assert_close(dw[bi], dwi)
        _assert_close(dx[bi], dxi)
        dtau_sum += float(dti)
    np.testing.assert_allclose(float(dtau), dtau_sum,
                               atol=1e-4 * (abs(dtau_sum) + 1e-9))


def test_banded_kernel_colsum_cotangent_only():
    """dc alone (dy = 0) exercises the P~ @ dc~ term of the delta pass."""
    n, d, k = 200, 3, 16
    w = _arange_keys(jax.random.PRNGKey(21), n)
    x = jax.random.normal(jax.random.PRNGKey(22), (n, d))
    b = jax.random.normal(jax.random.PRNGKey(23), (n,))

    def loss(fn):
        def f(w, tau):
            _, c = fn(w, x, tau, k)
            return jnp.sum(jnp.square(c) * b)
        return f

    gk = jax.grad(loss(kernel_banded), argnums=(0, 1))(w, jnp.float32(0.4))
    go = jax.grad(loss(softsort_apply_banded), argnums=(0, 1))(
        w, jnp.float32(0.4))
    for g1, g2 in zip(gk, go):
        _assert_close(g1, g2)


def test_banded_fallback_band_covers_everything():
    """band >= N - 1 must be the exact fused dense result."""
    n, d = 96, 4
    w = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 2
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    yk, ck = kernel_banded(w, x, 0.5, n - 1)
    yd, cd = softsort_apply(w, x, 0.5)
    np.testing.assert_array_equal(np.asarray(yk), np.asarray(yd))
    np.testing.assert_array_equal(np.asarray(ck), np.asarray(cd))


# ---------------------------------------------------- descending parity

@pytest.mark.parametrize("impl", ["chunked", "fused", "banded_jnp",
                                  "banded_kernel"])
def test_descending_matches_dense_matrix(impl):
    n, d, tau = 100, 3, 0.6
    w = _arange_keys(jax.random.PRNGKey(11), n)
    x = jax.random.normal(jax.random.PRNGKey(12), (n, d))
    p = softsort_matrix(w, tau, descending=True)
    y_ref, c_ref = p @ x, p.sum(0)
    fn = {
        "chunked": lambda: softsort_apply_chunked(w, x, tau, 32,
                                                  descending=True),
        "fused": lambda: softsort_apply(w, x, tau, descending=True),
        "banded_jnp": lambda: softsort_apply_banded(w, x, tau, 24,
                                                    descending=True),
        "banded_kernel": lambda: kernel_banded(w, x, tau, 24,
                                               descending=True),
    }[impl]
    y, c = fn()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=2e-5)


def test_descending_batched_chunked():
    w = jax.random.normal(jax.random.PRNGKey(7), (2, 33))
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 33, 4))
    y, c = softsort_apply_chunked(w, x, 0.5, 16, descending=True)
    pm = jax.vmap(lambda wi: softsort_matrix(wi, 0.5, descending=True))(w)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.einsum("bij,bjd->bid", pm, x)),
        atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(pm.sum(1)),
                               atol=2e-5)


# ------------------------------------------------- dispatcher + engines

def test_band_switch_round_boundary():
    """The switch model: hot start -> dense prefix; geometric anneal is
    monotone so every round past the switch also qualifies."""
    n = 64
    cfg = ShuffleSoftSortConfig(rounds=12, inner_steps=2, tau_start=60.0,
                                tau_end=0.2, band=8)
    sw = _band_switch_round(cfg, n)
    assert 0 < sw < cfg.rounds
    k = resolve_band(cfg, n)
    taus = cfg.tau_start * (cfg.tau_end / cfg.tau_start) ** (
        np.arange(1, cfg.rounds + 1) / cfg.rounds)
    model = (n - k) * np.exp(-(k / 2.0) / taus)
    assert np.all(model[sw:] <= cfg.band_eps)
    assert model[sw - 1] > cfg.band_eps
    # Default (cold) schedule: banded from round 0; band=None: never.
    assert _band_switch_round(
        ShuffleSoftSortConfig(rounds=10, band=16), n) < 10
    cfg_none = ShuffleSoftSortConfig(rounds=10)
    assert resolve_band(cfg_none, n) is None
    assert _band_switch_round(cfg_none, n) == cfg_none.rounds


def test_resolve_band_auto_scales_with_n():
    cfg = ShuffleSoftSortConfig(band="auto")
    assert resolve_band(cfg, 4096) == 256          # N/16 floor
    assert resolve_band(cfg, 1024) == 64
    # Degenerate bands (K would cover every pair) resolve to the exact
    # dense path — same math, none of the windowed-gather overhead.
    assert resolve_band(cfg, 64) is None
    assert resolve_band(ShuffleSoftSortConfig(band=32), 1000) == 32
    assert resolve_band(ShuffleSoftSortConfig(band=2000), 100) is None
    # "auto" sizes from tau_end, so a hot tau_start inflates the DENSE
    # PREFIX (dispatcher), not K itself.
    hot = ShuffleSoftSortConfig(band="auto", tau_start=60.0, rounds=50)
    assert resolve_band(hot, 1024) == 64
    assert 0 < _band_switch_round(hot, 1024) < hot.rounds


@pytest.mark.parametrize("cfg", [
    # mid-schedule dense->banded switch, jnp tier
    ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=32, tau_start=60.0,
                          tau_end=0.2, band=8),
    # banded from round 0, jnp tier ("auto" at this tiny N resolves to
    # dense, so the whole-schedule-banded case pins an explicit K)
    ShuffleSoftSortConfig(rounds=6, inner_steps=2, chunk=32, band=30),
    # kernel tier with a mid-schedule switch
    ShuffleSoftSortConfig(rounds=6, inner_steps=2, tau_start=60.0,
                          tau_end=0.2, band=12, use_kernel=True),
], ids=["switch-jnp", "full-band-jnp", "switch-kernel"])
def test_batched_band_bit_identical_to_sequential(cfg):
    """The banded dispatcher must keep the engine contract: batched ==
    sequential per seed, with both agreeing round-by-round on which
    apply ran (the segmented scan vs the per-round Python loop)."""
    b, s, n, hw = 2, 2, 64, (8, 8)
    xs = jax.random.uniform(jax.random.PRNGKey(42), (b, n, 2))
    keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(b * s)])
    res = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
    for bi in range(b):
        for si in range(s):
            o, _, losses = shuffle_soft_sort(xs[bi], hw, cfg,
                                             key=keys[bi * s + si])
            np.testing.assert_array_equal(res.all_orders[bi, si], o)
            np.testing.assert_array_equal(res.all_losses[bi, si],
                                          np.asarray(losses))
        assert is_valid_permutation(res.order[bi])


def test_band_auto_loss_close_to_dense():
    """band="auto" must not cost quality: final loss within 1% of the
    dense path on the same seeds (acceptance bar; the full-size run is
    recorded in EXPERIMENTS.md §Perf)."""
    n, hw = 256, (16, 16)
    xs = jax.random.uniform(jax.random.PRNGKey(3), (2, n, 3))
    base = dict(rounds=30, inner_steps=4, chunk=64)
    dense = shuffle_soft_sort_batched(
        xs, hw, ShuffleSoftSortConfig(**base), key=jax.random.PRNGKey(1))
    banded = shuffle_soft_sort_batched(
        xs, hw, ShuffleSoftSortConfig(band="auto", **base),
        key=jax.random.PRNGKey(1))
    l_dense = float(np.mean(dense.losses[:, -1]))
    l_band = float(np.mean(banded.losses[:, -1]))
    assert abs(l_band - l_dense) <= 0.01 * abs(l_dense) + 1e-6, (
        l_dense, l_band)
