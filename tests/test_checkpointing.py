"""Preemption-safety suite: rung-boundary checkpoints, AnnealSupervisor
chaos, and numerical-divergence sentinels (EXPERIMENTS.md §Robustness).

The central claim is proven the same way the engine-equivalence claims
are: bit-exactly.  For every engine (sequential, vmap, shard_map mesh,
tournament, adaptive) the kill-at-any-rung sweep injects a
``WorkerFailure`` at EVERY rung index in turn — via a ``FaultInjector``
wrapped around the engine's ``rung_hook``, which fires at the top of a
rung segment BEFORE dispatch, i.e. exactly where a preemption lands —
and asserts the supervised resume finishes with results identical to an
uninterrupted run: same orders, same loss traces (NaN pattern included),
same survivor sets, same rounds executed.  No tolerance, no "close
enough": a resumed anneal IS the anneal.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.core.shufflesoftsort import (
    NumericalDivergence,
    ShuffleSoftSortConfig,
    restart_tournament,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.launch.mesh import make_sort_mesh
from repro.runtime.anneal_checkpoint import AnnealCheckpointer
from repro.runtime.fault_tolerance import (
    AnnealSupervisor,
    DivergencePolicy,
    FaultInjector,
    RetryPolicy,
    WorkerFailure,
)

N, HW, D = 16, (4, 4), 2
CFG = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
ACFG = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=16,
                             schedule="adaptive", patience=1,
                             plateau_rtol=1.0, adapt_every=2)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="elastic-resume test needs >= 8 (forced host) devices")


def _x(seed=0, b=None):
    rng = np.random.default_rng(seed)
    shape = (N, D) if b is None else (b, N, D)
    return rng.standard_normal(shape).astype(np.float32)


def _fast_retry():
    return RetryPolicy(max_retries=3, backoff_base_s=0.0)


def _count_rungs(run):
    """Number of rung_hook firings in an uninterrupted run."""
    calls = []
    run(rung_hook=calls.append)
    return len(calls)


# ------------------------------------------------- checkpointer unit tests

def test_anneal_checkpointer_roundtrip(tmp_path):
    ck = AnnealCheckpointer(str(tmp_path))
    state = {"orders": np.arange(N, dtype=np.int32),
             "keys": np.array([3, 5], np.uint32),
             "losses": np.array([1.5, np.nan], np.float32)}
    ck.save(2, state, meta={"engine": "test", "rounds": 4})
    ck.save(3, {k: v + 0 for k, v in state.items()},
            meta={"engine": "test", "rounds": 4})
    assert ck.latest_round() == 3
    got, rnd, meta = ck.restore_latest(expect={"engine": "test"})
    assert rnd == 3 and meta["rounds"] == 4
    for k in state:
        assert got[k].dtype == state[k].dtype, k   # exact dtype round-trip
        np.testing.assert_array_equal(got[k], state[k])


def test_anneal_checkpointer_empty_dir_returns_none(tmp_path):
    assert AnnealCheckpointer(str(tmp_path)).restore_latest() is None


def test_anneal_checkpointer_fingerprint_mismatch(tmp_path):
    ck = AnnealCheckpointer(str(tmp_path))
    ck.save(1, {"orders": np.arange(N)}, meta={"engine": "batched",
                                               "n": N, "rounds": 4})
    with pytest.raises(ValueError, match="does not match"):
        ck.restore_latest(expect={"rounds": 8})
    with pytest.raises(ValueError, match="does not match"):
        ck.restore_latest(expect={"engine": "sequential"})
    # matching fingerprint loads fine
    assert ck.restore_latest(expect={"engine": "batched", "n": N})


def test_resume_against_wrong_problem_is_typed_error(tmp_path):
    key = jax.random.PRNGKey(0)
    shuffle_soft_sort(_x(), HW, CFG, key=key,
                      checkpoint_dir=str(tmp_path), checkpoint_every=1)
    wrong = ShuffleSoftSortConfig(rounds=8, inner_steps=2, chunk=16)
    with pytest.raises(ValueError, match="does not match"):
        shuffle_soft_sort(_x(), HW, wrong, key=key,
                          checkpoint_dir=str(tmp_path), resume=True)


# --------------------------------------------- kill-at-any-rung sweeps

def _sweep(run, result_fields, tmp_path):
    """Reference an uninterrupted run, then kill at every rung index and
    assert the supervised resume is bit-identical on every field."""
    ref = result_fields(run())
    n_rungs = _count_rungs(run)
    assert n_rungs >= 2, n_rungs
    for k in range(n_rungs):
        hook = FaultInjector(lambda r: None, fail_calls={k})
        sup = AnnealSupervisor(
            lambda xs, hw, cfg, **kw: run(**kw),
            checkpoint_dir=str(tmp_path / f"kill{k}"), retry=_fast_retry())
        got = result_fields(sup.run(None, HW, CFG, rung_hook=hook))
        assert hook.faults == 1, (k, hook.faults)
        assert sup.stats["restarts"] == 1
        for name, a in ref.items():
            np.testing.assert_array_equal(
                a, got[name], err_msg=f"kill at rung {k}: field {name}")


def test_sequential_kill_at_every_rung(tmp_path):
    x, key = _x(), jax.random.PRNGKey(7)

    def run(**kw):
        return shuffle_soft_sort(x, HW, CFG, key=key,
                                 checkpoint_every=1, **kw)

    _sweep(run, lambda r: {"order": np.asarray(r[0]),
                           "losses": np.asarray(r[2])}, tmp_path)


@pytest.mark.parametrize("use_mesh", [False, True],
                         ids=["vmap", "mesh"])
def test_batched_kill_at_every_rung(tmp_path, use_mesh):
    xs, key = _x(1, b=3), jax.random.PRNGKey(11)
    mesh = make_sort_mesh() if use_mesh else None

    def run(**kw):
        return shuffle_soft_sort_batched(xs, HW, CFG, n_restarts=2,
                                         key=key, mesh=mesh,
                                         checkpoint_every=1, **kw)

    _sweep(run, lambda r: {"all_orders": r.all_orders,
                           "all_losses": r.all_losses,
                           "best_restart": r.best_restart}, tmp_path)


def test_adaptive_kill_at_every_rung(tmp_path):
    xs, key = _x(2, b=3), jax.random.PRNGKey(13)

    def run(**kw):
        return shuffle_soft_sort_batched(xs, HW, ACFG, n_restarts=2,
                                         key=key, **kw)

    _sweep(run, lambda r: {"all_orders": r.all_orders,
                           "all_losses": r.all_losses,
                           "rounds_executed": r.rounds_executed}, tmp_path)


@pytest.mark.parametrize("cfg,kw", [(CFG, dict(n_rungs=2)),
                                    (ACFG, dict())],
                         ids=["fixed", "adaptive"])
def test_tournament_kill_at_every_rung(tmp_path, cfg, kw):
    x, key = _x(3), jax.random.PRNGKey(17)

    def run(**extra):
        return restart_tournament(x[None], HW, cfg, n_restarts=4, key=key,
                                  **kw, **extra)

    def fields(r):
        out = {"order": r.order, "all_losses": r.all_losses,
               "rounds_run": np.asarray(r.rounds_run)}
        for i, surv in enumerate(r.survivors):
            out[f"survivors_{i}"] = surv
        return out

    _sweep(run, fields, tmp_path)


@multi_device
def test_elastic_resume_on_different_mesh_size(tmp_path):
    """Kill on a 2-device mesh, resume on a 4-device mesh: the carry is
    stored in logical layout, so the finished run must still be
    bit-identical to an uninterrupted one (on ANY mesh)."""
    xs, key = _x(4, b=3), jax.random.PRNGKey(19)
    ref = shuffle_soft_sort_batched(xs, HW, CFG, n_restarts=2, key=key)
    hook = FaultInjector(lambda r: None, fail_calls={2})
    with pytest.raises(WorkerFailure):
        shuffle_soft_sort_batched(
            xs, HW, CFG, n_restarts=2, key=key, mesh=make_sort_mesh(2),
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            rung_hook=hook)
    res = shuffle_soft_sort_batched(
        xs, HW, CFG, n_restarts=2, key=key, mesh=make_sort_mesh(4),
        checkpoint_dir=str(tmp_path), checkpoint_every=1, resume=True)
    np.testing.assert_array_equal(ref.all_orders, res.all_orders)
    np.testing.assert_array_equal(ref.all_losses, res.all_losses)


def test_resume_skips_completed_rounds(tmp_path):
    """A resume must replay only the rounds after the last committed
    rung — counted via rung_hook firings on the second run."""
    xs, key = _x(5, b=2), jax.random.PRNGKey(23)
    shuffle_soft_sort_batched(xs, HW, CFG, key=key,
                              checkpoint_dir=str(tmp_path),
                              checkpoint_every=2)
    calls = []
    res = shuffle_soft_sort_batched(xs, HW, CFG, key=key,
                                    checkpoint_dir=str(tmp_path),
                                    checkpoint_every=2, resume=True,
                                    rung_hook=calls.append)
    assert calls == []        # fully checkpointed: nothing to replay
    ref = shuffle_soft_sort_batched(xs, HW, CFG, key=key)
    np.testing.assert_array_equal(ref.all_orders, res.all_orders)


# --------------------------------------------------- divergence sentinels

def test_sentinel_raises_typed_divergence():
    bad = _x()
    bad[0, 0] = np.nan
    with pytest.raises(NumericalDivergence) as ei:
        shuffle_soft_sort(bad, HW, CFG, key=jax.random.PRNGKey(0))
    e = ei.value
    assert e.round == 0
    assert e.dtype == "float32"
    assert np.isfinite(e.tau)


def test_sentinel_fires_on_batched_and_tournament():
    bad = _x(1, b=2)
    bad[1, 3, 1] = np.inf
    with pytest.raises(NumericalDivergence):
        shuffle_soft_sort_batched(bad, HW, CFG, key=jax.random.PRNGKey(0))
    with pytest.raises(NumericalDivergence):
        restart_tournament(bad, HW, CFG, n_restarts=2, n_rungs=2,
                           key=jax.random.PRNGKey(0))


def test_sentinel_opt_out():
    bad = _x()
    bad[0, 0] = np.nan
    order, _, losses = shuffle_soft_sort(
        bad, HW, CFG, key=jax.random.PRNGKey(0), check_finite=False)
    assert len(order) == N                 # ran to completion, unguarded
    assert not np.isfinite(losses).all()


def test_divergence_policy_ladder_order():
    pol = DivergencePolicy(tau_floor=0.05)
    cfg = ShuffleSoftSortConfig(rounds=4, compute_dtype="bfloat16",
                                tau_end=0.01, band=2)
    err = NumericalDivergence("x")
    cfg, d1 = pol.apply(cfg, err)
    assert cfg.compute_dtype == "float32" and "float32" in d1
    cfg, d2 = pol.apply(cfg, err)
    assert cfg.tau_end == pytest.approx(0.05) and "tau_end" in d2
    cfg, d3 = pol.apply(cfg, err)
    assert cfg.band == 4 and "band" in d3
    # f32 + clamped tau + dense: no rung applies, ladder exhausted
    import dataclasses
    assert pol.apply(dataclasses.replace(cfg, band=None), err) is None


def test_divergence_policy_auto_band_drops_to_dense():
    pol = DivergencePolicy()
    cfg = ShuffleSoftSortConfig(rounds=4, band="auto")
    cfg, desc = pol.apply(cfg, NumericalDivergence("x"))
    assert cfg.band is None and "dense" in desc


# ------------------------------------------------------ AnnealSupervisor

def test_supervisor_applies_fallback_ladder(tmp_path):
    seen = []

    def flaky(xs, hw, cfg, **kw):
        seen.append(cfg.compute_dtype)
        if cfg.compute_dtype == "bfloat16":
            raise NumericalDivergence("overflow", round=2, tau=0.25,
                                      dtype="bfloat16")
        return {"dtype": cfg.compute_dtype}

    sup = AnnealSupervisor(flaky, checkpoint_dir=str(tmp_path),
                           degrade=DivergencePolicy())
    out = sup.run(None, HW, ShuffleSoftSortConfig(
        rounds=4, compute_dtype="bfloat16"))
    assert out["dtype"] == "float32"
    assert seen == ["bfloat16", "float32"]
    assert len(sup.stats["fallbacks"]) == 1
    assert sup.history[0]["round"] == 2


def test_supervisor_reraises_divergence_without_policy(tmp_path):
    def diverge(xs, hw, cfg, **kw):
        raise NumericalDivergence("boom")

    sup = AnnealSupervisor(diverge, checkpoint_dir=str(tmp_path))
    with pytest.raises(NumericalDivergence):
        sup.run(None, HW, CFG)


def test_supervisor_exhausts_retry_budget(tmp_path):
    def always_fail(xs, hw, cfg, **kw):
        raise WorkerFailure("down")

    sleeps = []
    sup = AnnealSupervisor(
        always_fail, checkpoint_dir=str(tmp_path),
        retry=RetryPolicy(max_retries=2, backoff_base_s=0.01),
        sleep_fn=sleeps.append)
    with pytest.raises(RuntimeError, match="exceeded 2 restarts"):
        sup.run(None, HW, CFG)
    assert sup.stats["attempts"] == 3
    assert sleeps == [0.01, 0.02]          # exponential backoff observed


def test_supervisor_divergence_mid_run_resumes_from_checkpoint(tmp_path):
    """A real (injected-NaN) divergence mid-anneal: the supervisor
    degrades the config and the retry replays only from the last finite
    rung — the engine-level restore path, not a from-scratch rerun."""
    xs, key = _x(6, b=2), jax.random.PRNGKey(29)
    state = {"fired": False}

    def hook(r):
        if r >= 2 and not state["fired"]:
            state["fired"] = True
            raise NumericalDivergence("injected", round=r, tau=0.1,
                                      dtype="float32")

    sup = AnnealSupervisor(
        checkpoint_dir=str(tmp_path),
        degrade=DivergencePolicy(tau_floor=0.05),
        retry=_fast_retry())
    res = sup.run(xs, HW, ShuffleSoftSortConfig(
        rounds=4, inner_steps=2, chunk=16, tau_end=0.01),
        key=key, rung_hook=hook, checkpoint_every=1)
    assert res.all_orders.shape == (2, 1, N)
    assert len(sup.stats["fallbacks"]) == 1
    # rounds 0-1 committed before the divergence were NOT re-run under
    # the degraded config: the stored trace must match the original
    # config's first rounds bit-exactly.
    ref = shuffle_soft_sort_batched(xs, HW, ShuffleSoftSortConfig(
        rounds=4, inner_steps=2, chunk=16, tau_end=0.01), key=key)
    np.testing.assert_array_equal(ref.all_losses[:, :, :2],
                                  res.all_losses[:, :, :2])
