"""Mesh-sharded engine + restart-tournament tests.

The sharded path's contract is bit-identity: per seed, the shard_mapped
engine must reproduce the vmap engine (and hence the sequential API)
exactly, on any mesh size, including uneven shards.  On a stock 1-device
CPU run the multi-device cases execute in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; CI additionally
runs this whole module under a forced-8-device job (see
.github/workflows/ci.yml) where the in-process multi-device tests
activate.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.core.shufflesoftsort import (
    ShuffleSoftSortConfig,
    _rung_boundaries,
    _tournament_cull,
    restart_tournament,
    shuffle_soft_sort,
    shuffle_soft_sort_batched,
)
from repro.launch.mesh import make_sort_mesh

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


# ------------------------------------------------ sharded bit-identity

def test_sharded_matches_vmap_on_one_device():
    b, s, n, hw = 3, 2, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=5, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, 2))
    keys = jax.random.split(jax.random.PRNGKey(1), b * s)
    ref = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
    shd = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys,
                                    mesh=make_sort_mesh(1))
    np.testing.assert_array_equal(ref.all_orders, shd.all_orders)
    np.testing.assert_array_equal(ref.all_losses, shd.all_losses)
    np.testing.assert_array_equal(ref.order, shd.order)
    np.testing.assert_array_equal(ref.best_restart, shd.best_restart)


def test_sharded_rejects_callback():
    xs = jax.random.uniform(jax.random.PRNGKey(0), (1, 16, 2))
    with pytest.raises(ValueError):
        shuffle_soft_sort_batched(
            xs, (4, 4), ShuffleSoftSortConfig(rounds=2, inner_steps=2),
            mesh=make_sort_mesh(1), callback=lambda r, o, l: None)


@multi_device
@pytest.mark.parametrize("b,s,nd", [(3, 2, 8),   # 6 instances, pad 2
                                    (4, 4, 8),   # even split
                                    (2, 3, 3)])  # even split, partial mesh
def test_sharded_matches_vmap_multi_device(b, s, nd):
    n, hw = 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=5, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(b), (b, n, 2))
    keys = jax.random.split(jax.random.PRNGKey(100 + s), b * s)
    ref = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
    shd = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys,
                                    mesh=make_sort_mesh(nd))
    np.testing.assert_array_equal(ref.all_orders, shd.all_orders)
    np.testing.assert_array_equal(ref.all_losses, shd.all_losses)
    np.testing.assert_array_equal(ref.order, shd.order)
    np.testing.assert_array_equal(ref.best_restart, shd.best_restart)


@multi_device
def test_sharded_banded_switch_matches_vmap():
    """The banded dispatcher on the mesh path: a mid-schedule
    dense->banded switch runs TWO shard_mapped segments whose
    keys/orders chain through — still bit-identical to the vmap engine
    on an uneven shard."""
    from repro.core.shufflesoftsort import _band_switch_round
    b, s, n, hw = 3, 2, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=6, inner_steps=2, chunk=16,
                                tau_start=30.0, tau_end=0.2, band=10)
    # Guard against a vacuous pass: the switch must land strictly inside
    # the schedule so BOTH segments actually run on the mesh.
    assert 0 < _band_switch_round(cfg, n) < cfg.rounds
    xs = jax.random.uniform(jax.random.PRNGKey(7), (b, n, 2))
    keys = jax.random.split(jax.random.PRNGKey(8), b * s)
    ref = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
    shd = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys,
                                    mesh=make_sort_mesh(8))
    np.testing.assert_array_equal(ref.all_orders, shd.all_orders)
    np.testing.assert_array_equal(ref.all_losses, shd.all_losses)
    np.testing.assert_array_equal(ref.order, shd.order)


@multi_device
def test_sharded_matches_sequential_per_seed():
    """The full contract: mesh engine == sequential API, seed by seed."""
    b, s, n, hw = 2, 2, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(3), (b, n, 2))
    keys = jax.random.split(jax.random.PRNGKey(4), b * s)
    shd = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys,
                                    mesh=make_sort_mesh(8))
    for bi in range(b):
        for si in range(s):
            o, _, losses = shuffle_soft_sort(xs[bi], hw, cfg,
                                             key=keys[bi * s + si])
            np.testing.assert_array_equal(shd.all_orders[bi, si], o)
            np.testing.assert_array_equal(shd.all_losses[bi, si],
                                          np.asarray(losses))


def test_sharded_matches_vmap_in_forced_8_device_subprocess():
    """Always-on multi-device coverage: re-run the uneven-shard identity
    check in a subprocess with 8 forced host devices, so the sharded
    path is exercised across devices even when this suite runs on a
    single-device backend."""
    script = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core.shufflesoftsort import (ShuffleSoftSortConfig,
            shuffle_soft_sort_batched)
        from repro.launch.mesh import make_sort_mesh
        b, s, n, hw = 3, 2, 16, (4, 4)      # 6 instances -> pad 2 on 8 dev
        cfg = ShuffleSoftSortConfig(rounds=3, inner_steps=2, chunk=16)
        xs = jax.random.uniform(jax.random.PRNGKey(0), (b, n, 2))
        keys = jax.random.split(jax.random.PRNGKey(1), b * s)
        ref = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys)
        shd = shuffle_soft_sort_batched(xs, hw, cfg, n_restarts=s, keys=keys,
                                        mesh=make_sort_mesh(8))
        assert np.array_equal(ref.all_orders, shd.all_orders)
        assert np.array_equal(ref.all_losses, shd.all_losses)
        assert np.array_equal(ref.order, shd.order)
        print("OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ------------------------------------------------ tournament scheduler

def test_rung_boundaries():
    assert _rung_boundaries(30, 3) == [10, 20, 30]
    assert _rung_boundaries(10, 1) == [10]
    assert _rung_boundaries(5, 2) == [2, 5]
    # more rungs than rounds: degenerate segments collapse, end stays R
    assert _rung_boundaries(2, 4)[-1] == 2


def test_tournament_cull_keeps_best_on_rigged_loss():
    """Culling must keep the per-problem best (and be deterministic on
    ties: lower slot wins)."""
    losses = np.array([
        [0.9, 0.1, 0.5, 0.7],     # best is slot 1
        [0.2, 0.2, 0.9, 0.05],    # best is slot 3; tie between 0 and 1
    ], np.float32)
    sel = _tournament_cull(losses, keep=2)
    assert sel.shape == (2, 2)
    assert 1 in sel[0] and 3 in sel[1]
    # rigged ties: stable argsort keeps slot 0 over slot 1
    np.testing.assert_array_equal(sel[1], [0, 3])
    # keep-all is the identity
    np.testing.assert_array_equal(
        _tournament_cull(losses, keep=4),
        np.tile(np.arange(4), (2, 1)))


def test_tournament_winner_bit_identical_to_full_run():
    """A restart that survives every rung finishes exactly as if it had
    never been in a tournament — and the winner is among survivors."""
    b, s, n, hw = 3, 4, 16, (4, 4)
    cfg = ShuffleSoftSortConfig(rounds=6, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(5), (b, n, 2))
    keys = jax.random.split(jax.random.PRNGKey(6), b * s)
    res = restart_tournament(xs, hw, cfg, n_restarts=s, keys=keys,
                             cull_fraction=0.5, n_rungs=3)
    assert res.rounds_run < res.rounds_full
    for bi in range(b):
        win = res.best_restart[bi]
        assert win in res.survivors[-1][bi]
        o, x_sorted, losses = shuffle_soft_sort(xs[bi], hw, cfg,
                                                key=keys[bi * s + win])
        np.testing.assert_array_equal(res.order[bi], o)
        np.testing.assert_array_equal(res.sorted[bi], x_sorted)
        np.testing.assert_array_equal(res.all_losses[bi, win],
                                      np.asarray(losses))
        assert res.final_loss[bi] == losses[-1]


def test_tournament_bookkeeping():
    b, s = 2, 8
    cfg = ShuffleSoftSortConfig(rounds=6, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(7), (b, 16, 3))
    res = restart_tournament(xs, (4, 4), cfg, n_restarts=s,
                             key=jax.random.PRNGKey(8),
                             cull_fraction=0.5, n_rungs=3)
    # 8 -> 4 -> 2 survivors across the two interior culls
    assert [sv.shape[1] for sv in res.survivors] == [4, 2, 2]
    # survivor sets nest
    for prev, nxt in zip(res.survivors, res.survivors[1:]):
        for bi in range(b):
            assert set(nxt[bi]) <= set(prev[bi])
    # culled restarts have NaN traces after their last rung, survivors
    # have complete traces
    assert np.isnan(res.all_losses).any()
    for bi in range(b):
        for si in res.survivors[-1][bi]:
            assert np.isfinite(res.all_losses[bi, si]).all()
    # rounds accounting: 8*2 + 4*2 + 2*2 per problem
    assert res.rounds_run == b * (8 * 2 + 4 * 2 + 2 * 2)
    assert res.rounds_full == b * s * cfg.rounds


def test_tournament_no_culling_matches_batched_engine():
    """cull_fraction=0 (or a single rung) degenerates to the plain
    batched engine."""
    b, s = 2, 3
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(9), (b, 16, 2))
    keys = jax.random.split(jax.random.PRNGKey(10), b * s)
    ref = shuffle_soft_sort_batched(xs, (4, 4), cfg, n_restarts=s, keys=keys)
    for kwargs in ({"cull_fraction": 0.0, "n_rungs": 2}, {"n_rungs": 1}):
        res = restart_tournament(xs, (4, 4), cfg, n_restarts=s, keys=keys,
                                 **kwargs)
        np.testing.assert_array_equal(res.order, ref.order)
        np.testing.assert_array_equal(res.best_restart, ref.best_restart)
        np.testing.assert_array_equal(res.all_losses, ref.all_losses)
        assert res.rounds_run == res.rounds_full


@multi_device
def test_tournament_sharded_matches_vmap_tournament():
    b, s = 2, 6
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
    xs = jax.random.uniform(jax.random.PRNGKey(11), (b, 16, 3))
    keys = jax.random.split(jax.random.PRNGKey(12), b * s)
    ref = restart_tournament(xs, (4, 4), cfg, n_restarts=s, keys=keys,
                             n_rungs=2)
    shd = restart_tournament(xs, (4, 4), cfg, n_restarts=s, keys=keys,
                             n_rungs=2, mesh=make_sort_mesh(8))
    np.testing.assert_array_equal(ref.order, shd.order)
    np.testing.assert_array_equal(ref.best_restart, shd.best_restart)
    np.testing.assert_array_equal(np.nan_to_num(ref.all_losses),
                                  np.nan_to_num(shd.all_losses))


# ------------------------------------------------ serving integration

def test_sort_server_mesh_and_tournament_dispatch():
    from repro.launch.serve import SortServer

    n, hw, d = 16, (4, 4), 2
    cfg = ShuffleSoftSortConfig(rounds=4, inner_steps=2, chunk=16)
    rng = np.random.RandomState(0)
    xs = rng.rand(3, n, d).astype(np.float32)

    # mesh dispatch keeps the sequential-identity contract
    server = SortServer(hw, d=d, cfg=cfg, max_batch=4, max_wait_ms=200.0,
                        mesh=make_sort_mesh(1))
    try:
        futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
                for i in range(3)]
        results = [f.result(timeout=300) for f in futs]
    finally:
        server.close()
    for i, (order, _, _) in enumerate(results):
        o_ref, _, _ = shuffle_soft_sort(xs[i], hw, cfg,
                                        key=jax.random.PRNGKey(i))
        np.testing.assert_array_equal(order, o_ref)

    # tournament dispatch returns valid, complete winners
    server = SortServer(hw, d=d, cfg=cfg, max_batch=4, max_wait_ms=200.0,
                        n_restarts=4, tournament_rungs=2,
                        mesh=make_sort_mesh(1))
    try:
        futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
                for i in range(3)]
        results = [f.result(timeout=300) for f in futs]
    finally:
        server.close()
    for order, _, losses in results:
        np.testing.assert_array_equal(np.sort(order), np.arange(n))
        assert np.isfinite(np.asarray(losses)).all()


def test_sort_server_kernel_dispatch():
    """--use-kernel serving path: the coalesced batch runs the fused
    Pallas apply (fwd+bwd, interpret mode on CPU) end to end and keeps
    the sequential-identity contract against a kernel-config run."""
    from repro.launch.serve import SortServer, main

    n, hw, d = 16, (4, 4), 2
    cfg = ShuffleSoftSortConfig(rounds=2, inner_steps=2, chunk=16,
                                use_kernel=True)
    rng = np.random.RandomState(1)
    xs = rng.rand(2, n, d).astype(np.float32)
    server = SortServer(hw, d=d, cfg=cfg, max_batch=2, max_wait_ms=200.0)
    try:
        futs = [server.submit(xs[i], key=jax.random.PRNGKey(i))
                for i in range(2)]
        results = [f.result(timeout=300) for f in futs]
    finally:
        server.close()
    for i, (order, _, losses) in enumerate(results):
        np.testing.assert_array_equal(np.sort(order), np.arange(n))
        assert np.isfinite(np.asarray(losses)).all()
        o_ref, _, _ = shuffle_soft_sort(xs[i], hw, cfg,
                                        key=jax.random.PRNGKey(i))
        np.testing.assert_array_equal(order, o_ref)

    # CLI smoke: --use-kernel threads into the coalesced batch config.
    out = main(["--workload", "sort", "--requests", "2", "--sort-n", "16",
                "--sort-hw", "4", "--sort-d", "2", "--rounds", "2",
                "--use-kernel"])
    assert out["batches"] >= 1


# ------------------------------------------------ mesh validation

def test_make_sort_mesh_rejects_nonpositive():
    with pytest.raises(RuntimeError, match="must be >= 1"):
        make_sort_mesh(0)
    with pytest.raises(RuntimeError, match="must be >= 1"):
        make_sort_mesh(-3)


def test_make_sort_mesh_rejects_oversubscription():
    """Asking for more devices than exist must fail loudly, naming the
    XLA_FLAGS workaround (like make_production_mesh)."""
    too_many = len(jax.devices()) + 1
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_sort_mesh(too_many)


def test_make_sort_mesh_devices_kwarg():
    """The elastic re-shard path builds meshes over explicit device
    lists (survivors of an eviction); the list bounds the budget."""
    devs = list(jax.devices())
    m = make_sort_mesh(1, devices=devs[:1])
    assert list(m.devices.flat) == devs[:1]
    # the explicit list is the availability budget, not jax.devices()
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_sort_mesh(2, devices=devs[:1])
    # n_devices=None sizes the mesh to the whole list
    assert make_sort_mesh(devices=devs[:1]).shape["data"] == 1
