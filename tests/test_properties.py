"""Property-based determinism suite (hypothesis; ISSUE 7 satellite).

Randomized-input statements of the invariants the unit suites check
pointwise, drawn over keys / shapes / tau schedules (strategies shared
from tests/conftest.py):

  1. ``hard_permutation`` returns a valid permutation for ANY finite
     key vector, duplicates included.
  2. ``band_tail_bound`` upper-bounds the mass a banded apply actually
     drops from the exact SoftSort matrix.
  3. Chaining ``run_round_segment`` across ANY ordered partition of the
     round schedule is bit-identical to one uninterrupted run — the
     join/leave contract continuous batching and fault recovery rest on.
  4. ``schedule="adaptive"`` whose controller never fires is
     bit-identical to the fixed schedule per seed.

The suite self-skips when hypothesis is not installed (the tier-1
container image does not ship it); tests/test_annealing.py carries the
hypothesis-free coverage.  CI runs this file in the `properties` job
under the pinned, derandomized "ci" profile (see conftest.py).
"""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import conftest as strat  # noqa: E402  (shared strategies)
from repro.core.shufflesoftsort import (  # noqa: E402
    ShuffleSoftSortConfig,
    _tau_schedule,
    run_round_segment,
    shuffle_soft_sort,
)
from repro.core.softsort import (  # noqa: E402
    band_tail_bound,
    hard_permutation,
    is_valid_permutation,
    softsort_matrix,
)
from repro.core.losses import mean_pairwise_distance  # noqa: E402


def _problem(hw, d=2, seed=0):
    n = hw[0] * hw[1]
    return np.random.RandomState(seed).rand(n, d).astype(np.float32)


def _instance_arrays(x, seed):
    """Initial (orders, keys, norms) for one flattened instance — the
    state a scheduler would hold before the first dispatched segment."""
    n = x.shape[0]
    orders = np.arange(n, dtype=np.int32)[None]
    keys = np.asarray(jax.random.PRNGKey(seed), np.uint32).reshape(1, 2)
    norms = np.float32([mean_pairwise_distance(jnp.asarray(x))])
    return orders, keys, norms


@given(w=strat.key_vectors())
def test_hard_permutation_is_always_valid(w):
    assert is_valid_permutation(hard_permutation(jnp.float32(w)))


@given(w=strat.key_vectors(min_n=5), seed=strat.prng_seeds())
def test_band_tail_bound_dominates_true_dropped_mass(w, seed):
    w = jnp.float32(w)
    n = w.shape[0]
    rng = np.random.RandomState(seed % 2**31)
    tau = np.float32(rng.uniform(0.01, 2.0))
    band = int(rng.randint(1, n))
    p = np.asarray(softsort_matrix(w, tau), np.float64)   # (N, N) exact-ish
    # Row i keeps keys within `band` RANKS of i; everything else is the
    # mass the banded apply drops.
    ranks = np.argsort(np.argsort(np.asarray(w), kind="stable"),
                       kind="stable")                     # key j -> rank
    out_of_band = np.abs(ranks[None, :] - np.arange(n)[:, None]) > band
    dropped = (p * out_of_band).sum(axis=1).max()
    bound = float(band_tail_bound(w, tau, band))
    # Exact-arithmetic bound; float32 softmax adds a few ULP of noise.
    assert dropped <= bound * (1 + 1e-5) + 1e-6


@given(w=strat.key_vectors(min_n=5), seed=strat.prng_seeds())
def test_band_tail_bound_dominates_descending_dropped_mass(w, seed):
    """The bound is rank-symmetric: it holds unchanged when row i
    targets rank N-1-i (``descending=True``) — the gap statistic g_K
    does not care which end of the sort the rows count from."""
    w = jnp.float32(w)
    n = w.shape[0]
    rng = np.random.RandomState(seed % 2**31)
    tau = np.float32(rng.uniform(0.01, 2.0))
    band = int(rng.randint(1, n))
    p = np.asarray(softsort_matrix(w, tau, descending=True), np.float64)
    ranks = np.argsort(np.argsort(np.asarray(w), kind="stable"),
                       kind="stable")
    # Row i of the descending matrix targets ascending rank n-1-i.
    targets = n - 1 - np.arange(n)
    out_of_band = np.abs(ranks[None, :] - targets[:, None]) > band
    dropped = (p * out_of_band).sum(axis=1).max()
    bound = float(band_tail_bound(w, tau, band))
    assert dropped <= bound * (1 + 1e-5) + 1e-6


@given(w=strat.key_vectors(min_n=5), seed=strat.prng_seeds())
def test_band_tail_bound_dominates_bf16_rounded_scoring(w, seed):
    """bf16 keys-rounded scoring: the kernel tier scores with keys
    rounded to bfloat16 while the stored f32 keys feed the analytic
    bound.  Rounding perturbs every |sort(w)_i - w_j| by at most
    ``eps = 2^-8 * max|w|`` (8-bit mantissa), which inflates the
    dropped mass by at most ``exp(2 eps / tau)`` — each out-of-band
    numerator term grows by <= exp(eps/tau) and the >= 1 softmax
    denominator shrinks by >= exp(-eps/tau).  The f32-keys bound times
    that analytic slack still dominates."""
    w = jnp.float32(w)
    n = w.shape[0]
    rng = np.random.RandomState(seed % 2**31)
    tau = np.float32(rng.uniform(0.05, 2.0))   # slack ~ exp(eps/tau)
    band = int(rng.randint(1, n))
    w_r = jnp.asarray(w, jnp.bfloat16).astype(jnp.float32)
    p = np.asarray(softsort_matrix(w_r, tau), np.float64)
    ranks = np.argsort(np.argsort(np.asarray(w_r), kind="stable"),
                       kind="stable")
    out_of_band = np.abs(ranks[None, :] - np.arange(n)[:, None]) > band
    dropped = (p * out_of_band).sum(axis=1).max()
    bound = float(band_tail_bound(w, tau, band))
    eps = float(np.max(np.abs(np.asarray(w)))) * 2.0 ** -8
    slack = float(np.exp(2.0 * eps / float(tau)))
    assert dropped <= bound * slack * (1 + 1e-5) + 1e-6


@given(hw=strat.grid_shapes(max_side=3), seed=strat.prng_seeds(),
       cfg_draw=strat.tau_schedule_cfgs())
def test_chained_segments_bit_identical_to_uninterrupted_run(
        hw, seed, cfg_draw):
    rounds, tau_start, tau_end = cfg_draw
    cfg = ShuffleSoftSortConfig(rounds=rounds, inner_steps=1,
                                chunk=hw[0] * hw[1], tau_start=tau_start,
                                tau_end=tau_end)
    x = _problem(hw, seed=seed % 1000)
    orders0, keys0, norms0 = _instance_arrays(x, seed)
    full = run_round_segment(x[None], orders0, keys0, norms0,
                             np.zeros(1, np.int64), rounds, hw=hw, cfg=cfg)
    # Re-run the same schedule under every drawn partition.
    for split in ([1] * rounds, [rounds]):
        _assert_chain_matches(x, hw, cfg, seed, split, full)


@given(hw=strat.grid_shapes(max_side=3), seed=strat.prng_seeds(),
       split_seed=strat.prng_seeds())
def test_arbitrary_segment_splits_bit_identical(hw, seed, split_seed):
    rounds = 6
    cfg = ShuffleSoftSortConfig(rounds=rounds, inner_steps=1,
                                chunk=hw[0] * hw[1])
    x = _problem(hw, seed=seed % 1000)
    orders0, keys0, norms0 = _instance_arrays(x, seed)
    full = run_round_segment(x[None], orders0, keys0, norms0,
                             np.zeros(1, np.int64), rounds, hw=hw, cfg=cfg)
    rng = np.random.RandomState(split_seed % 2**31)
    split, left = [], rounds
    while left:
        take = int(rng.randint(1, left + 1))
        split.append(take)
        left -= take
    _assert_chain_matches(x, hw, cfg, seed, split, full)


def _assert_chain_matches(x, hw, cfg, seed, split, full):
    assert sum(split) == cfg.rounds
    orders, keys, norms = _instance_arrays(x, seed)
    pos, losses = 0, []
    for seg in split:
        orders, keys, l = run_round_segment(
            x[None], orders, keys, norms, np.full(1, pos, np.int64), seg,
            hw=hw, cfg=cfg)
        losses.append(np.asarray(l))
        pos += seg
    np.testing.assert_array_equal(np.asarray(orders), np.asarray(full[0]),
                                  err_msg=f"split={split}")
    np.testing.assert_array_equal(np.asarray(keys), np.asarray(full[1]))
    np.testing.assert_array_equal(np.concatenate(losses, axis=0),
                                  np.asarray(full[2]))


@given(hw=strat.grid_shapes(max_side=3), seed=strat.prng_seeds())
def test_adaptive_equals_fixed_when_controller_never_fires(hw, seed):
    n = hw[0] * hw[1]
    fixed = ShuffleSoftSortConfig(rounds=4, inner_steps=1, chunk=n)
    adapt = ShuffleSoftSortConfig(rounds=4, inner_steps=1, chunk=n,
                                  schedule="adaptive", patience=10**6)
    x = _problem(hw, seed=seed % 1000)
    key = jax.random.PRNGKey(seed)
    o_f, s_f, l_f = shuffle_soft_sort(x, hw, fixed, key=key)
    o_a, s_a, l_a = shuffle_soft_sort(x, hw, adapt, key=key)
    np.testing.assert_array_equal(o_f, o_a)
    np.testing.assert_array_equal(s_f, s_a)
    np.testing.assert_array_equal(np.float32(l_f), np.float32(l_a))


def test_tau_schedule_is_float32_and_monotone_smoke():
    # Anchor for the property file even when hypothesis examples shrink
    # to nothing: the schedule both engines consume is float32 and
    # non-increasing for tau_start >= tau_end.
    cfg = ShuffleSoftSortConfig(rounds=16)
    taus = _tau_schedule(cfg)
    assert taus.dtype == np.float32
    assert (np.diff(taus) <= 0).all()
