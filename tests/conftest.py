import os
import sys

# Make `repro` importable without an install step.  NOTE: deliberately no
# XLA_FLAGS here — smoke tests and benches must see 1 device; only the
# dry-run entrypoint forces 512 host devices (see repro/launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# Shared hypothesis strategies (tests/test_properties.py).
#
# hypothesis is an OPTIONAL dependency: the container tier-1 image does
# not ship it, so everything below is guarded and the property suite
# self-skips via ``pytest.importorskip`` — the adaptive determinism
# contract keeps hypothesis-free coverage in tests/test_annealing.py.
# The CI `properties` job runs with a pinned profile: derandomized, no
# deadline (jit compile time would trip any wall-clock budget), small
# example counts (each example traces a full anneal).
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, settings
    from hypothesis import strategies as st

    settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=20,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.register_profile(
        "dev", deadline=None, max_examples=8,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))

    def grid_shapes(max_side: int = 4):
        """(h, w) grid shapes — N = h * w stays small enough that every
        example's full anneal traces in test time."""
        side = st.integers(min_value=2, max_value=max_side)
        return st.tuples(side, side)

    def prng_seeds():
        return st.integers(min_value=0, max_value=2**31 - 1)

    def key_vectors(min_n: int = 4, max_n: int = 24):
        """(N,) float32 sort-key vectors, finite, duplicates allowed —
        the raw input of hard_permutation / band_tail_bound."""
        return st.integers(min_value=min_n, max_value=max_n).flatmap(
            lambda n: st.lists(
                st.floats(min_value=-1e3, max_value=1e3, width=32,
                          allow_nan=False, allow_infinity=False),
                min_size=n, max_size=n))

    def tau_schedule_cfgs():
        """(rounds, tau_start, tau_end) draws spanning hot->cold anneals
        including degenerate flat schedules."""
        return st.tuples(
            st.integers(min_value=1, max_value=8),
            st.floats(min_value=0.05, max_value=4.0, width=32),
            st.floats(min_value=0.005, max_value=0.5, width=32))

    def segment_splits(rounds: int):
        """Partitions of ``rounds`` into ordered positive segment
        lengths — every way a scheduler could chop one anneal."""
        def build(draw_lens):
            out, left = [], rounds
            for v in draw_lens:
                if left == 0:
                    break
                take = 1 + v % left
                out.append(take)
                left -= take
            if left:
                out.append(left)
            return out
        return st.lists(st.integers(min_value=0, max_value=rounds - 1),
                        min_size=0, max_size=rounds).map(build)
except ImportError:                                    # pragma: no cover
    pass
