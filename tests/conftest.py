import os
import sys

# Make `repro` importable without an install step.  NOTE: deliberately no
# XLA_FLAGS here — smoke tests and benches must see 1 device; only the
# dry-run entrypoint forces 512 host devices (see repro/launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
