"""Pallas kernel validation: shape/dtype sweeps + gradients vs ref.py oracle.

Kernels run in interpret mode on CPU (TPU is the compile target); every
assertion is against the pure-jnp O(N^2) oracle.
"""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # degrade gracefully where absent
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.ops import softsort_apply
from repro.kernels.ref import softsort_apply_ref


SHAPES = [
    (8, 1), (64, 3), (100, 2), (256, 3), (300, 7), (511, 5),
    (1024, 50), (128, 130), (96, 256),
]


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("tau", [0.1, 0.7, 3.0])
def test_forward_matches_ref(n, d, tau):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 7 + d))
    w = jax.random.normal(k1, (n,)) * 2.0
    x = jax.random.normal(k2, (n, d))
    y, c = softsort_apply(w, x, tau)
    yr, cr = softsort_apply_ref(w, x, tau)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forward_dtypes(dtype):
    n, d = 128, 9
    w = (jax.random.normal(jax.random.PRNGKey(0), (n,)) * 2).astype(dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d)).astype(dtype)
    y, c = softsort_apply(w, x, 0.5)
    yr, cr = softsort_apply_ref(w.astype(jnp.float32),
                                x.astype(jnp.float32), 0.5)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(c, np.float32), np.asarray(cr),
                               atol=tol)


@pytest.mark.parametrize("blocks", [(64, 128), (256, 256), (8, 128)])
def test_forward_block_shape_sweep(blocks):
    br, bc = blocks
    n, d = 384, 5
    w = jax.random.normal(jax.random.PRNGKey(2), (n,))
    x = jax.random.normal(jax.random.PRNGKey(3), (n, d))
    y, c = softsort_apply(w, x, 0.4, br, bc)
    yr, cr = softsort_apply_ref(w, x, 0.4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=2e-5)


@pytest.mark.parametrize("n,d", [(64, 3), (300, 7), (129, 17)])
@pytest.mark.parametrize("chunk", [32, 64, 256])
def test_gradients_match_ref(n, d, chunk):
    keys = jax.random.split(jax.random.PRNGKey(n + d + chunk), 4)
    w = jax.random.normal(keys[0], (n,)) * 3
    x = jax.random.normal(keys[1], (n, d))
    a = jax.random.normal(keys[2], (n, d))
    b = jax.random.normal(keys[3], (n,))

    def loss(apply_fn):
        def f(w, x, tau):
            y, c = apply_fn(w, x, tau)
            return jnp.sum(y * a) + jnp.sum(c * b)
        return f

    lk = loss(lambda w, x, t: softsort_apply(w, x, t, 256, 256, chunk))
    lr = loss(softsort_apply_ref)
    gk = jax.grad(lk, argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    gr = jax.grad(lr, argnums=(0, 1, 2))(w, x, jnp.float32(0.6))
    for kk, rr in zip(gk, gr):
        scale = float(jnp.max(jnp.abs(rr))) + 1e-9
        np.testing.assert_allclose(np.asarray(kk), np.asarray(rr),
                                   atol=2e-3 * scale)


def test_colsum_of_valid_permutation_is_one():
    # With tiny tau, P ~ a hard permutation: column sums ~ 1.
    n = 256
    w = jax.random.permutation(jax.random.PRNGKey(5),
                               jnp.arange(n, dtype=jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(6), (n, 4))
    _, c = softsort_apply(w, x, 1e-3)
    np.testing.assert_allclose(np.asarray(c), np.ones(n), atol=1e-4)


def test_apply_of_tiny_tau_is_hard_sort():
    n = 200
    w = jax.random.normal(jax.random.PRNGKey(7), (n,)) * 10
    x = jax.random.normal(jax.random.PRNGKey(8), (n, 6))
    y, _ = softsort_apply(w, x, 1e-5)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x)[np.argsort(np.asarray(w))],
                               atol=1e-4)


@given(st.integers(2, 6), st.integers(1, 4),
       st.floats(0.05, 4.0, allow_nan=False))
@settings(max_examples=20, deadline=None)
def test_property_rowsum_one(log2n, d, tau):
    """P_soft rows always sum to 1 => sum(colsum) == N and sum(y) stats."""
    n = 2 ** log2n
    w = jax.random.normal(jax.random.PRNGKey(n), (n,))
    x = jnp.ones((n, d))
    y, c = softsort_apply(w, x, tau)
    # Each row of P sums to 1 so y == 1 exactly and colsum sums to N.
    np.testing.assert_allclose(np.asarray(y), np.ones((n, d)), atol=1e-5)
    np.testing.assert_allclose(float(c.sum()), n, rtol=1e-5)


@given(st.floats(0.05, 2.0), st.floats(0.05, 2.0))
@settings(max_examples=10, deadline=None)
def test_property_shift_invariance(tau, shift):
    """SoftSort is invariant to adding a constant to all keys."""
    n, d = 64, 3
    w = jax.random.normal(jax.random.PRNGKey(11), (n,))
    x = jax.random.normal(jax.random.PRNGKey(12), (n, d))
    y1, c1 = softsort_apply(w, x, tau)
    y2, c2 = softsort_apply(w + shift, x, tau)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-4)
